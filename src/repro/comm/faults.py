"""Deterministic in-path fault injection for the socket federation tier.

The simulated channel (``comm.channel``) converts bytes into *seconds* under
a Gilbert–Elliott bursty-loss chain; this module injects the same chain's
weather into the REAL socket path: a ``ChaosProxy`` sits between the client
processes and the federation server, forwarding TCP bytes and — per the
chain's per-chunk state — delaying them, throttling them, truncating them
mid-frame, refusing connections, and resetting established ones. Transport
chaos and the simulated channel therefore share ONE fault model: the same
``ge_p_good_bad`` / ``ge_p_bad_good`` step probabilities, the same
"per-chunk misfortune while the link is in the bad state" semantics.

Determinism contract (what the chaos tests and ``benchmarks/bench_chaos.py``
rely on): every fault decision is drawn from a ``FaultSchedule`` keyed by
``(seed, client_id, attempt)`` and applied at absolute *byte offsets* of the
client→server stream (chunk ``i`` covers bytes ``[i·chunk_bytes,
(i+1)·chunk_bytes)``), never at recv() boundaries. TCP segmentation, thread
scheduling, and wall-clock timing therefore cannot change WHICH bytes of an
attempt survive — a given (seed, client, attempt) either always delivers its
frames or always dies at the same offset, so the surviving-client set of a
chaos round is a pure function of the fault seed.

The schedule is consulted lazily and in chunk-index order, so the action
stream for a key is reproducible regardless of how far a connection gets
before dying. With both state fault rates zero the schedule draws nothing
and the proxy degenerates to a transparent byte pump (the ``disabled``
fast path mirrors the channel's zero-draw guarantee).
"""

from __future__ import annotations

import dataclasses
import socket
import struct
import threading
import time

import numpy as np

from repro.comm.transport import FrameDecoder, TransportError

# action codes in a schedule's lazily-filled stream
OK = "ok"
DELAY = "delay"
KILL = "kill"
REFUSE = "refuse"

_LINGER_RST = struct.pack("ii", 1, 0)   # SO_LINGER(on, 0s) → close sends RST


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs for the in-path fault injector.

    The two-state chain reuses the channel's Gilbert–Elliott semantics:
    the link hops good↔bad per ``chunk_bytes`` of client→server traffic
    with ``ge_p_good_bad`` / ``ge_p_bad_good``; while in state *s* each
    chunk independently suffers a fault with probability ``fault_good`` /
    ``fault_bad``. A faulted chunk is either a KILL (both sides of the
    connection are reset — mid-frame truncation as seen by the server,
    ECONNRESET as seen by the client) with probability ``p_kill``, or a
    DELAY of ``delay_s`` seconds. A connection arriving while the chain
    starts in the bad state is refused outright with ``p_refuse``.

    ``throttle_bytes`` > 0 additionally paces ALL forwarding (good chunks
    included) to that granularity with ``throttle_delay_s`` sleeps — a
    slow-sender mode that stresses incremental decoders without changing
    any outcome.

    ``crash_clients`` / ``bad_proto_clients`` are client-side injections
    (the proxy cannot crash a process): members of ``crash_clients`` send a
    ``crash_after_frac`` prefix of their upload then hard-exit; members of
    ``bad_proto_clients`` announce an unsupported protocol version and get
    rejected. Both make the corresponding outcome taxonomy entries
    (``crashed`` / ``rejected``) deterministically reachable in tests.

    ``corrupt_clients`` turns the proxy into a Byzantine man-in-the-middle
    for those client ids: their UPDATE frames are decoded in-path, the wire
    payload is poisoned with the seeded ``corrupt_kind`` attacker model
    (``fed.attackers``), the wire CRC is recomputed by the re-encode, and
    the frame is re-packed — so the poisoned traffic is WIRE-VALID and
    sails past every byte-level defense; only the content gate or a robust
    aggregation rule can stop it. Corrupted clients bypass the byte-offset
    chunk schedule (frames must arrive whole to be poisoned), so corruption
    and kill/delay weather are mutually exclusive per client by design.
    """

    seed: int = 0
    chunk_bytes: int = 4096
    ge_p_good_bad: float = 0.1
    ge_p_bad_good: float = 0.5
    fault_good: float = 0.0
    fault_bad: float = 0.5
    p_kill: float = 0.5
    p_refuse: float = 0.5
    delay_s: float = 0.02
    throttle_bytes: int = 0
    throttle_delay_s: float = 0.0
    crash_clients: tuple = ()
    crash_after_frac: float = 0.5
    bad_proto_clients: tuple = ()
    corrupt_clients: tuple = ()
    corrupt_kind: str = "sign_flip"
    corrupt_seed: int = 0

    def __post_init__(self):
        for name in ("ge_p_good_bad", "ge_p_bad_good", "fault_good",
                     "fault_bad", "p_kill", "p_refuse"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.chunk_bytes < 1:
            raise ValueError(f"chunk_bytes must be ≥ 1, got {self.chunk_bytes}")
        if self.corrupt_clients:
            from repro.fed.attackers import ATTACKS  # lazy: fed layer

            if self.corrupt_kind not in ATTACKS:
                raise ValueError(
                    f"corrupt_kind must be one of {ATTACKS}, "
                    f"got {self.corrupt_kind!r}"
                )

    @property
    def disabled(self) -> bool:
        """True ⇒ no fault randomness is drawn at all (transparent proxy)."""
        return self.fault_good <= 0.0 and self.fault_bad <= 0.0

    @property
    def stationary_p_bad(self) -> float:
        gb, bg = self.ge_p_good_bad, self.ge_p_bad_good
        return gb / (gb + bg) if gb + bg > 0 else 0.0


class FaultSchedule:
    """The deterministic per-(client, attempt) action stream.

    One instance = one connection attempt's weather. ``connect_action()``
    is drawn first (refusal happens before any byte moves); ``action_at(i)``
    then gives chunk ``i``'s fate, filling the stream lazily IN ORDER so a
    partially-consumed schedule is a prefix of the fully-consumed one.
    """

    def __init__(self, cfg: FaultConfig, client_id: int, attempt: int):
        self.cfg = cfg
        self.key = (int(cfg.seed), int(client_id), int(attempt))
        self._rng = np.random.default_rng(
            [int(cfg.seed), 0x5EED_FA17, int(client_id), int(attempt)]
        )
        self._actions: list[tuple[str, float]] = []
        if cfg.disabled:
            self._bad = False
            self._connect: tuple[str, float] = (OK, 0.0)
            return
        self._bad = bool(self._rng.random() < cfg.stationary_p_bad)
        refused = (self._bad and self._rng.random() < cfg.p_refuse)
        self._connect = (REFUSE, 0.0) if refused else (OK, 0.0)

    def connect_action(self) -> str:
        """``OK`` or ``REFUSE`` — decided before any byte is forwarded."""
        return self._connect[0]

    def action_at(self, chunk_idx: int) -> tuple[str, float]:
        """Fate of the chunk covering bytes [idx·chunk, (idx+1)·chunk)."""
        if self.cfg.disabled:
            return (OK, 0.0)
        while len(self._actions) <= chunk_idx:
            self._actions.append(self._step())
        return self._actions[chunk_idx]

    def _step(self) -> tuple[str, float]:
        cfg = self.cfg
        p_fault = cfg.fault_bad if self._bad else cfg.fault_good
        act: tuple[str, float] = (OK, 0.0)
        if p_fault > 0.0 and self._rng.random() < p_fault:
            if self._rng.random() < cfg.p_kill:
                act = (KILL, 0.0)
            else:
                act = (DELAY, cfg.delay_s)
        # chain hop AFTER the chunk, like the channel's per-chunk step
        u = self._rng.random()
        self._bad = (u >= cfg.ge_p_bad_good) if self._bad \
            else (u < cfg.ge_p_good_bad)
        return act

    def first_kill_offset(self, nbytes: int) -> int | None:
        """Byte offset where a ``nbytes``-long upstream would be truncated
        (None ⇒ it survives). Pure — used to predict survivors in tests."""
        n_chunks = (nbytes + self.cfg.chunk_bytes - 1) // self.cfg.chunk_bytes
        for i in range(n_chunks):
            if self.action_at(i)[0] == KILL:
                return i * self.cfg.chunk_bytes
        return None


def abort_socket(sock: socket.socket) -> None:
    """Hard-close: RST instead of FIN, so the peer sees ECONNRESET (a torn
    connection), never a clean half-close it could mistake for EOF-at-a-
    frame-boundary."""
    try:
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER, _LINGER_RST)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


class ChaosProxy:
    """A TCP forwarder that injects the schedule's faults in-path.

    Clients connect to ``proxy.port`` instead of the server. Each accepted
    connection: (1) the first transport frame (the HELLO) is read off the
    client to learn ``(client_id, attempt)`` — the schedule key — without
    trusting timing; (2) the schedule's connect action may refuse (RST)
    immediately; (3) otherwise an upstream connection opens and two pumps
    move bytes. The client→server pump applies the schedule at absolute
    byte offsets (the HELLO bytes themselves are offset 0 — a kill in
    chunk 0 means the server never hears the client at all); the
    server→client pump is transparent, but a KILL resets BOTH directions,
    so a mid-BCAST abort surfaces client-side too.

    A connection whose first bytes are not a parseable frame is reset
    (garbage in → RST out) — the proxy never forwards traffic it cannot
    attribute to a schedule key.
    """

    def __init__(self, upstream: tuple[str, int], cfg: FaultConfig,
                 host: str = "127.0.0.1", accept_timeout_s: float = 0.1):
        self.upstream = upstream
        self.cfg = cfg
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._lock = threading.Lock()
        self.stats = {
            "connections": 0, "refused": 0, "killed": 0,
            "delayed_chunks": 0, "delay_s": 0.0,
            "bytes_up": 0, "bytes_down": 0, "corrupted_frames": 0,
        }
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, 0))
        self._srv.listen(64)
        self._srv.settimeout(accept_timeout_s)
        self.host, self.port = self._srv.getsockname()[:2]
        self._acceptor = threading.Thread(
            target=self._accept_loop, name="chaos-accept", daemon=True
        )
        self._acceptor.start()

    # -- lifecycle ---------------------------------------------------------

    def __enter__(self) -> "ChaosProxy":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass
        end = time.monotonic() + 5.0
        self._acceptor.join(timeout=5)
        for t in list(self._threads):
            t.join(timeout=max(0.0, end - time.monotonic()))

    def _count(self, key: str, v: float = 1) -> None:
        with self._lock:
            self.stats[key] += v

    # -- the pumps ---------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            self._count("connections")
            t = threading.Thread(
                target=self._handle, args=(conn,), daemon=True
            )
            t.start()
            self._threads.append(t)

    def _peek_hello(self, conn: socket.socket) -> tuple[bytes, dict]:
        """Read client bytes until the first frame parses; returns (all raw
        bytes consumed so far, hello meta). Raises TransportError on
        garbage or EOF before a frame."""
        dec = FrameDecoder(max_payload_bytes=1 << 20)
        raw = bytearray()
        conn.settimeout(0.25)   # short poll: must notice close() fast
        deadline = time.monotonic() + 30.0
        while True:
            if self._stop.is_set() or time.monotonic() > deadline:
                raise TransportError("no HELLO before proxy shutdown/deadline")
            try:
                chunk = conn.recv(4096)
            except socket.timeout:
                continue
            if not chunk:
                raise TransportError("client closed before HELLO")
            raw += chunk
            frames = dec.feed(chunk)         # raises on malformed header
            if frames:
                return bytes(raw), frames[0].meta
            if len(raw) > (1 << 20):
                raise TransportError("first frame too large to attribute")

    def _handle(self, conn: socket.socket) -> None:
        up: socket.socket | None = None
        try:
            raw, meta = self._peek_hello(conn)
            sched = FaultSchedule(
                self.cfg,
                int(meta.get("client_id", -1)),
                int(meta.get("attempt", 0)),
            )
            if sched.connect_action() == REFUSE:
                self._count("refused")
                abort_socket(conn)
                return
            up = socket.create_connection(self.upstream, timeout=30.0)
            killed = threading.Event()
            down = threading.Thread(
                target=self._pump_down, args=(up, conn, killed), daemon=True
            )
            down.start()
            cid = int(meta.get("client_id", -1))
            if cid in self.cfg.corrupt_clients:
                self._pump_up_corrupt(conn, up, cid, bytes(raw), killed)
            else:
                self._pump_up(conn, up, sched, bytes(raw), killed)
            down.join(timeout=30)
        except (TransportError, OSError):
            abort_socket(conn)
            if up is not None:
                abort_socket(up)
        finally:
            for s in (conn, up):
                if s is not None:
                    try:
                        s.close()
                    except OSError:
                        pass

    def _forward(self, dst: socket.socket, block: bytes) -> None:
        """One good block downstream of the schedule, optionally throttled
        byte-for-byte (slow-sender pacing; outcomes unaffected)."""
        tb = self.cfg.throttle_bytes
        if tb <= 0:
            dst.sendall(block)
            return
        for i in range(0, len(block), tb):
            dst.sendall(block[i:i + tb])
            if self.cfg.throttle_delay_s > 0:
                time.sleep(self.cfg.throttle_delay_s)

    def _pump_up(self, conn: socket.socket, up: socket.socket,
                 sched: FaultSchedule, first: bytes,
                 killed: threading.Event) -> None:
        """Client→server, schedule applied at absolute byte offsets."""
        chunk_b = self.cfg.chunk_bytes
        offset = 0
        pending = bytearray(first)
        conn.settimeout(0.25)   # short poll: must notice killed/stop fast
        while True:
            # flush everything buffered, chunk-aligned to absolute offsets
            while pending:
                idx = offset // chunk_b
                boundary = (idx + 1) * chunk_b
                take = min(len(pending), boundary - offset)
                act, secs = sched.action_at(idx)
                if act == KILL and offset == idx * chunk_b:
                    # truncate exactly at the chunk start: nothing of this
                    # chunk is forwarded, both sides reset
                    self._count("killed")
                    killed.set()
                    abort_socket(up)
                    abort_socket(conn)
                    return
                if act == DELAY and offset == idx * chunk_b:
                    self._count("delayed_chunks")
                    self._count("delay_s", secs)
                    time.sleep(secs)
                block = bytes(pending[:take])
                del pending[:take]
                self._forward(up, block)
                offset += take
                self._count("bytes_up", take)
            if killed.is_set() or self._stop.is_set():
                return
            try:
                chunk = conn.recv(1 << 16)
            except socket.timeout:
                continue        # idle — re-check killed/stop and poll again
            except OSError:
                return
            if not chunk:
                try:                     # forward the client's half-close
                    up.shutdown(socket.SHUT_WR)
                except OSError:
                    pass
                return
            pending += chunk

    def _pump_up_corrupt(self, conn: socket.socket, up: socket.socket,
                         cid: int, first: bytes,
                         killed: threading.Event) -> None:
        """Client→server for a Byzantine-proxied client: every frame is
        reassembled, UPDATE payloads are poisoned with the seeded attacker
        model and re-encoded (fresh wire CRC), and the frame is re-packed —
        the server sees a perfectly well-formed, content-poisoned stream."""
        from repro.comm.transport import FT_UPDATE, pack_frame
        from repro.fed.attackers import AttackConfig, poison_blob  # lazy: fed

        acfg = AttackConfig(kind=self.cfg.corrupt_kind, n_attackers=1,
                            seed=self.cfg.corrupt_seed)
        dec = FrameDecoder()
        conn.settimeout(0.25)   # short poll: must notice killed/stop fast

        def emit(chunk: bytes) -> None:
            for frame in dec.feed(chunk):
                if frame.ftype == FT_UPDATE:
                    payload = poison_blob(frame.payload, acfg, cid)
                    self._count("corrupted_frames")
                else:
                    payload = frame.payload
                out = pack_frame(frame.ftype, payload, frame.meta)
                self._forward(up, out)
                self._count("bytes_up", len(out))

        try:
            emit(first)
            while not killed.is_set() and not self._stop.is_set():
                try:
                    chunk = conn.recv(1 << 16)
                except socket.timeout:
                    continue
                except OSError:
                    return
                if not chunk:
                    try:             # forward the client's half-close
                        up.shutdown(socket.SHUT_WR)
                    except OSError:
                        pass
                    return
                emit(chunk)
        except (TransportError, OSError):
            abort_socket(up)
            abort_socket(conn)

    def _pump_down(self, up: socket.socket, conn: socket.socket,
                   killed: threading.Event) -> None:
        """Server→client, transparent (a KILL elsewhere resets this side)."""
        try:
            up.settimeout(0.25)  # short poll: must notice killed/stop fast
        except OSError:
            return              # a KILL already closed the upstream socket
        while not killed.is_set() and not self._stop.is_set():
            try:
                chunk = up.recv(1 << 16)
            except socket.timeout:
                continue
            except OSError:
                return
            if not chunk:
                try:
                    conn.shutdown(socket.SHUT_WR)
                except OSError:
                    pass
                return
            try:
                conn.sendall(chunk)
                self._count("bytes_down", len(chunk))
            except OSError:
                return
