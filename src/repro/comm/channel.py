"""Simulated transport: payload bytes → wall-clock transfer times.

Each client gets a ``ClientLink`` with bandwidth and latency drawn once
from log-normal / normal distributions (heterogeneous edge fleet: a few
fast links, a long slow tail — the shape WAN measurements show). A
transfer of ``nbytes`` over a link costs

    t = latency + nbytes / bandwidth        (+ optional jitter per transfer)

so *stragglers are emergent*: a client is late because its payload is
large or its link is slow, not because a coin flip said so. Ternary
compression therefore shows up directly as shorter transfer times — the
paper's Table IV claim expressed in seconds instead of bytes.

Concurrent transfers additionally contend for the SERVER's NIC
(``ChannelConfig.server_bandwidth_bytes_s``): ``transfer_concurrent``
runs a fluid max-min fair-share model where simultaneous flows split the
server's capacity (each still capped by its own client link), so a
broadcast to N clients through a saturated NIC takes ~N× longer than a
single download — the shared-bottleneck effect a per-link model misses.
The default cap is infinite, which reduces exactly to independent links.
``transfer_timed`` applies the same NIC cap to event-driven ASYNC uploads:
transfers registered with absolute start times degrade each other's rate
by their overlap count, so a burst of simultaneous async arrivals shares
the server ingress instead of each enjoying the full pipe.

Lossy links (``loss_rate`` > 0): a transfer moves in ``chunk_bytes``
chunks, each lost independently with probability ``loss_rate`` and
retransmitted after a timeout with exponential backoff until it lands.
Retransmissions cost real wire bytes and real seconds; the ledger keeps
*goodput* (``TransferEvent.nbytes``, the payload the receiver decodes) and
*overhead* (``TransferEvent.retrans_bytes``) separate, so effective
goodput under loss reads directly out of ``summary()``. With
``loss_rate == 0`` no loss randomness is drawn at all — byte counts,
times, AND the rng stream are identical to the loss-free model, so seeded
runs reproduce bit-exactly.

Bursty loss (``loss_model="gilbert_elliott"``): real radio/WAN links lose
packets in RUNS, not independent coin flips. The two-state Gilbert–Elliott
chain captures that: the link sits in a *good* state (loss
``ge_loss_good``, usually 0) or a *bad* state (loss ``ge_loss_bad``),
hopping between them per chunk with ``ge_p_good_bad`` / ``ge_p_bad_good``.
Each transfer starts from the chain's stationary distribution, so the
MARGINAL chunk-loss rate is ``π_bad·ge_loss_bad + π_good·ge_loss_good``
with ``π_bad = p_gb/(p_gb+p_bg)`` — matched-marginal comparisons against
iid isolate pure burstiness. Same zero-draw guarantee: with both state
loss rates 0 the rng stream is untouched, bit-identical to lossless.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Fleet-level link distribution + round deadline.

    Attributes:
      mean_bandwidth_bytes_s: median link bandwidth, bytes/second (default ≈ a
        1 MB/s uplink — the paper targets exactly this regime of limited
        upstream capacity).
      bandwidth_sigma: σ of the log-normal bandwidth draw (0 → homogeneous).
      base_latency_s: mean one-way link latency (propagation + handshake).
      latency_jitter_s: per-transfer uniform jitter in [0, jitter).
      deadline_s: round deadline for the SYNC server — a client whose
        download + compute + upload exceeds it is dropped as a straggler
        (0 or inf → never drop).
      compute_speed_sigma: σ of the log-normal per-client compute speed
        multiplier (device heterogeneity; 1.0 = nominal).
      server_bandwidth_bytes_s: total server NIC capacity shared by
        SIMULTANEOUS transfers (0 or inf → no shared bottleneck, like
        ``deadline_s``). Applied by ``transfer_concurrent`` with max-min
        fairness and by ``transfer_timed`` via overlap counting.
      loss_rate: per-chunk Bernoulli loss probability (0 → lossless and
        rng-stream-identical to the pre-loss model). Only read when
        ``loss_model == "iid"``.
      chunk_bytes: loss granularity — payloads move as ceil(n/chunk)
        chunks, each lost/retransmitted independently.
      retransmit_timeout_s: wait before the first retransmission of a lost
        chunk; consecutive losses of the same chunk back off by
        ``retransmit_backoff``×.
      retransmit_backoff: exponential backoff factor (≥ 1).
      loss_model: "iid" (Bernoulli per chunk, via ``loss_rate``) or
        "gilbert_elliott" (two-state bursty chain, via the ``ge_*`` knobs;
        ``loss_rate`` is ignored).
      ge_p_good_bad: P(good → bad) per chunk step.
      ge_p_bad_good: P(bad → good) per chunk step (small ⇒ long loss
        bursts).
      ge_loss_good: chunk loss probability while in the good state
        (0 = classic Gilbert model).
      ge_loss_bad: chunk loss probability while in the bad state. Both
        state loss rates 0 ⇒ lossless AND rng-stream-untouched, exactly
        like ``loss_rate=0`` in iid mode.
    """

    mean_bandwidth_bytes_s: float = 1e6
    bandwidth_sigma: float = 0.5
    base_latency_s: float = 0.05
    latency_jitter_s: float = 0.01
    deadline_s: float = float("inf")
    compute_speed_sigma: float = 0.3
    server_bandwidth_bytes_s: float = float("inf")
    loss_rate: float = 0.0
    chunk_bytes: int = 64 * 1024
    retransmit_timeout_s: float = 0.05
    retransmit_backoff: float = 2.0
    loss_model: str = "iid"
    ge_p_good_bad: float = 0.05
    ge_p_bad_good: float = 0.5
    ge_loss_good: float = 0.0
    ge_loss_bad: float = 0.5


@dataclasses.dataclass(frozen=True)
class ClientLink:
    """One client's drawn link + device characteristics."""

    client_id: int
    bandwidth_bytes_s: float
    latency_s: float
    compute_speed: float  # multiplier on nominal examples/sec

    def transfer_time(self, nbytes: int, jitter: float = 0.0) -> float:
        return self.latency_s + jitter + nbytes / self.bandwidth_bytes_s


@dataclasses.dataclass
class TransferEvent:
    """Log entry for one wire transfer (used by FedResult.transfer_log).

    ``nbytes`` is GOODPUT — the payload the receiver decodes; lost-chunk
    retransmissions add ``retrans_bytes`` of overhead on top (``retries``
    chunk retransmissions), all inside ``seconds``.
    """

    client_id: int
    direction: str  # "down" | "up"
    nbytes: int
    seconds: float
    retrans_bytes: int = 0
    retries: int = 0


def _fair_share_completion(
    starts: list[float], nbytes: list[int], caps: list[float], total_cap: float
) -> list[float]:
    """Fluid processor-sharing model: completion time of each flow.

    Flow i becomes active at ``starts[i]`` with ``nbytes[i]`` to move, its
    rate capped by its own link ``caps[i]``; active flows share
    ``total_cap`` with max-min fairness (water-filling). Returns absolute
    completion times. With ``total_cap`` = inf every flow runs at its own
    cap and this degenerates to latency + bytes/bandwidth.
    """
    n = len(starts)
    remaining = [float(b) for b in nbytes]
    done = [0.0] * n
    finished = [False] * n
    t = 0.0
    while not all(finished):
        active = [i for i in range(n) if not finished[i] and starts[i] <= t]
        if not active:
            t = min(s for i, s in enumerate(starts) if not finished[i] and s > t)
            continue
        # --- max-min water-filling over the active flows ------------------
        rates = {}
        pool = total_cap
        todo = list(active)
        while todo:
            share = pool / len(todo) if pool != float("inf") else float("inf")
            capped = [i for i in todo if caps[i] <= share]
            if not capped:
                for i in todo:
                    rates[i] = share
                todo = []
            else:
                for i in capped:
                    rates[i] = caps[i]
                    if pool != float("inf"):
                        pool -= caps[i]
                todo = [i for i in todo if i not in capped]
        # --- advance to the next event (completion or a flow starting) ----
        dt_complete = min(
            remaining[i] / rates[i] if rates[i] > 0 else float("inf")
            for i in active
        )
        upcoming = [s for i, s in enumerate(starts) if not finished[i] and s > t]
        dt = min(dt_complete, min(upcoming) - t) if upcoming else dt_complete
        for i in active:
            remaining[i] -= rates[i] * dt
            if remaining[i] <= 1e-9:
                finished[i] = True
                done[i] = t + dt
        t += dt
    return done


class _LinkView:
    """O(1) mutable sequence facade over the channel's per-client ARRAYS.

    The fleet's links are stored as three numpy arrays (bandwidth, latency,
    compute speed) so a million-client channel costs ~24 MB instead of a
    million ``ClientLink`` objects; this view keeps the historical
    ``channel.links[k]`` API alive — reads build a ``ClientLink`` on the
    fly, writes (``channel.links[0] = ClientLink(...)``, used by
    ``launch.serve`` and the tests) store back into the arrays.
    """

    def __init__(self, channel: "Channel"):
        self._ch = channel

    def __len__(self) -> int:
        return self._ch.n_clients

    def __getitem__(self, k: int) -> ClientLink:
        ch = self._ch
        return ClientLink(int(k), float(ch._bw[k]), float(ch._lat[k]),
                          float(ch._speed[k]))

    def __setitem__(self, k: int, link: ClientLink) -> None:
        ch = self._ch
        ch._bw[k] = link.bandwidth_bytes_s
        ch._lat[k] = link.latency_s
        ch._speed[k] = link.compute_speed

    def __iter__(self):
        return (self[k] for k in range(len(self)))


class Channel:
    """Holds the fleet's links and meters transfers through them."""

    def __init__(self, cfg: ChannelConfig, n_clients: int, seed: int = 0):
        self.cfg = cfg
        self.n_clients = int(n_clients)
        rng = np.random.default_rng(seed)
        # the SAME vectorized draws as ever (stream-identical): the fleet's
        # links live as arrays, not per-client Python objects — O(10⁶)
        # clients cost three float64 arrays.
        self._bw = cfg.mean_bandwidth_bytes_s * rng.lognormal(
            mean=0.0, sigma=cfg.bandwidth_sigma, size=n_clients
        )
        self._lat = np.maximum(
            rng.normal(cfg.base_latency_s, cfg.base_latency_s * 0.2, size=n_clients),
            1e-4,
        )
        self._speed = rng.lognormal(
            mean=0.0, sigma=cfg.compute_speed_sigma, size=n_clients
        )
        self.links = _LinkView(self)
        self._rng = rng
        self.log: list[TransferEvent] = []
        # batched-transfer ledger (``transfer_batch`` meters counters plus a
        # per-batch seconds array instead of one TransferEvent per client).
        self._batch_secs: list[np.ndarray] = []
        self._batch_bytes = 0
        self._batch_retrans = 0
        self._batch_retries = 0
        # in-flight (data_start, data_end) windows per direction, used by
        # ``transfer_timed`` for the async-upload overlap count. Only
        # populated when the NIC cap is finite.
        self._inflight: dict[str, list[tuple[float, float]]] = {}

    # -- loss / retransmission --------------------------------------------

    def _chunk_sizes(self, nbytes: int) -> np.ndarray:
        chunk = max(1, int(self.cfg.chunk_bytes))
        n_chunks = (nbytes + chunk - 1) // chunk
        sizes = np.full(n_chunks, chunk, dtype=np.int64)
        sizes[-1] = nbytes - chunk * (n_chunks - 1)
        return sizes

    def _penalty_from_extra(
        self, extra: np.ndarray, sizes: np.ndarray
    ) -> tuple[int, float, int]:
        """Fold per-chunk retransmission counts into the (retrans_bytes,
        timeout_delay_s, retries) triple; every failed attempt of a chunk
        waits ``retransmit_timeout_s`` growing by ``retransmit_backoff``×
        (per chunk: t0·(b^extra − 1)/(b − 1))."""
        retrans_bytes = int(np.sum(extra * sizes))
        retries = int(extra.sum())
        if retries == 0:
            return 0, 0.0, 0
        t0, b = self.cfg.retransmit_timeout_s, self.cfg.retransmit_backoff
        if b == 1.0:
            delay = t0 * retries
        else:
            delay = float(t0 * np.sum((b ** extra[extra > 0] - 1.0) / (b - 1.0)))
        return retrans_bytes, delay, retries

    def _ge_loss_penalty(self, nbytes: int) -> tuple[int, float, int]:
        """Gilbert–Elliott penalty for one transfer: the good/bad state
        chain steps once per chunk (so consecutive chunks share fate —
        bursts), each chunk then needs a geometric number of transmissions
        at its state's loss rate. The chain starts from its stationary
        distribution, making the marginal loss rate a closed form the tests
        (and matched-marginal comparisons) rely on. Draws NOTHING when both
        state loss rates are 0."""
        cfg = self.cfg
        pg, pb = cfg.ge_loss_good, cfg.ge_loss_bad
        if (pg <= 0.0 and pb <= 0.0) or nbytes == 0:
            return 0, 0.0, 0
        for name, v in (("ge_loss_good", pg), ("ge_loss_bad", pb)):
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")
        gb, bg = cfg.ge_p_good_bad, cfg.ge_p_bad_good
        for name, v in (("ge_p_good_bad", gb), ("ge_p_bad_good", bg)):
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        sizes = self._chunk_sizes(nbytes)
        n_chunks = len(sizes)
        # stationary start (degenerate chain ⇒ good): one uniform, then one
        # uniform per chunk step, plus a geometric per chunk whose state
        # loss rate is > 0.
        pi_bad = gb / (gb + bg) if gb + bg > 0 else 0.0
        bad = bool(self._rng.random() < pi_bad)
        steps = self._rng.random(size=n_chunks)
        extra = np.zeros(n_chunks, dtype=np.int64)
        for i in range(n_chunks):
            p = pb if bad else pg
            if p > 0.0:
                extra[i] = self._rng.geometric(1.0 - p) - 1
            bad = (steps[i] >= bg) if bad else (steps[i] < gb)
        return self._penalty_from_extra(extra, sizes)

    def _loss_penalty(self, nbytes: int) -> tuple[int, float, int]:
        """(retrans_bytes, timeout_delay_s, retries) for one transfer.

        ``loss_model="iid"``: chunked Bernoulli loss — each of the
        ceil(n/chunk) chunks needs a geometric number of transmissions.
        ``loss_model="gilbert_elliott"``: bursty two-state chain
        (``_ge_loss_penalty``). Either way draws NOTHING when loss is off —
        the rng stream (and therefore any seeded run) is identical to the
        pre-loss channel.
        """
        model = self.cfg.loss_model
        if model == "gilbert_elliott":
            return self._ge_loss_penalty(nbytes)
        if model != "iid":
            raise ValueError(
                f"loss_model must be 'iid' or 'gilbert_elliott', got {model!r}")
        p = self.cfg.loss_rate
        if p <= 0.0 or nbytes == 0:
            return 0, 0.0, 0
        if not p < 1.0:
            raise ValueError(f"loss_rate must be < 1, got {p}")
        sizes = self._chunk_sizes(nbytes)
        # transmissions per chunk ~ Geometric(success = 1-p), support ≥ 1
        tx = self._rng.geometric(1.0 - p, size=len(sizes))
        return self._penalty_from_extra(tx - 1, sizes)

    def transfer(self, client_id: int, nbytes: int, direction: str) -> float:
        """Seconds to move ``nbytes`` over this client's link (logged)."""
        jitter = float(self._rng.uniform(0.0, self.cfg.latency_jitter_s))
        retrans, delay, retries = self._loss_penalty(nbytes)
        dt = self.links[client_id].transfer_time(nbytes + retrans, jitter) + delay
        self.log.append(
            TransferEvent(client_id, direction, nbytes, dt, retrans, retries)
        )
        return dt

    def _loss_penalty_batch(
        self, nbytes: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized ``_loss_penalty`` over a batch of transfers: ONE
        geometric draw covering every chunk of every transfer (the batched
        draw produces the same value stream as per-transfer draws laid end
        to end), segment-summed back per transfer. Draws NOTHING when loss
        is off, like the scalar path."""
        n = len(nbytes)
        zeros = np.zeros(n, dtype=np.int64)
        if self.cfg.loss_model == "gilbert_elliott":
            # the chain is sequential per transfer; each transfer's chain is
            # independent, so the batch is exactly the scalar penalties laid
            # end to end (unlike iid there is no draw-order fold to differ).
            if self.cfg.ge_loss_good <= 0.0 and self.cfg.ge_loss_bad <= 0.0:
                return zeros, np.zeros(n), zeros
            pens = [self._ge_loss_penalty(int(b)) for b in np.asarray(nbytes)]
            return (np.array([p[0] for p in pens], dtype=np.int64),
                    np.array([p[1] for p in pens]),
                    np.array([p[2] for p in pens], dtype=np.int64))
        if self.cfg.loss_model != "iid":
            raise ValueError("loss_model must be 'iid' or 'gilbert_elliott', "
                             f"got {self.cfg.loss_model!r}")
        p = self.cfg.loss_rate
        if p <= 0.0 or n == 0:
            return zeros, np.zeros(n), zeros
        if not p < 1.0:
            raise ValueError(f"loss_rate must be < 1, got {p}")
        chunk = max(1, int(self.cfg.chunk_bytes))
        nb = np.asarray(nbytes, dtype=np.int64)
        n_chunks = (nb + chunk - 1) // chunk          # 0 chunks for 0 bytes
        total = int(n_chunks.sum())
        if total == 0:
            return zeros, np.zeros(n), zeros
        tx = self._rng.geometric(1.0 - p, size=total)
        extra = tx - 1
        sizes = np.full(total, chunk, dtype=np.int64)
        ends = np.cumsum(n_chunks)
        starts = ends - n_chunks
        nz = n_chunks > 0
        sizes[ends[nz] - 1] = nb[nz] - chunk * (n_chunks[nz] - 1)
        csum_b = np.concatenate([[0], np.cumsum(extra * sizes)])
        retrans = csum_b[ends] - csum_b[starts]
        csum_r = np.concatenate([[0], np.cumsum(extra)])
        retries = csum_r[ends] - csum_r[starts]
        t0, b = self.cfg.retransmit_timeout_s, self.cfg.retransmit_backoff
        if b == 1.0:
            delay = t0 * retries.astype(np.float64)
        else:
            term = np.where(extra > 0, (b ** extra - 1.0) / (b - 1.0), 0.0)
            csum_d = np.concatenate([[0.0], np.cumsum(term)])
            delay = t0 * (csum_d[ends] - csum_d[starts])
        return retrans, delay, retries

    def transfer_batch(
        self, client_ids: np.ndarray, nbytes: np.ndarray, direction: str,
        *, share_nic: bool = False, compat: bool = False,
    ) -> np.ndarray:
        """Vectorized per-link transfers for FLEET-scale batches.

        One rng fold per batch (uniform jitters + one geometric array),
        one closed-form seconds vector — no per-client Python objects.
        With ``loss_rate == 0`` the jitter draw consumes the rng stream
        EXACTLY like ``len(client_ids)`` sequential ``transfer`` calls
        (numpy's batched uniforms equal scalar draws laid end to end), so
        lossless fleet runs are stream-compatible with the scalar path by
        construction; under loss the batched geometric draw is folded once
        per batch instead of interleaved per transfer, so ``compat=True``
        forces the scalar call order (bit-exact legacy streams, small
        fleets only).

        ``share_nic=True`` applies the causal fleet approximation of the
        server NIC cap — every flow in the batch is simultaneous, so each
        runs at min(link, NIC / batch) — the closed-form stand-in for
        ``transfer_concurrent``'s O(flows²) water-filling, which a 10⁵-flow
        broadcast cannot afford. The ledger meters counters plus one
        seconds array per batch (``summary()`` merges both ledgers).
        """
        ids = np.asarray(client_ids, dtype=np.int64)
        nb = np.broadcast_to(np.asarray(nbytes, dtype=np.int64), ids.shape)
        if compat:
            return np.array([
                self.transfer(int(k), int(b), direction)
                for k, b in zip(ids, nb)
            ])
        jitter = self._rng.uniform(0.0, self.cfg.latency_jitter_s, size=ids.size)
        retrans, delay, retries = self._loss_penalty_batch(nb)
        wire = nb + retrans
        rate = self._bw[ids]
        nic = self.cfg.server_bandwidth_bytes_s
        if share_nic and 0 < nic < float("inf") and ids.size:
            rate = np.minimum(rate, nic / ids.size)
        secs = self._lat[ids] + jitter + wire / rate + delay
        self._batch_secs.append(secs)
        self._batch_bytes += int(nb.sum())
        self._batch_retrans += int(retrans.sum())
        self._batch_retries += int(retries.sum())
        return secs

    def compute_time_batch(
        self, client_ids: np.ndarray, n_examples: np.ndarray,
        nominal_examples_per_s: float = 5000.0,
    ) -> np.ndarray:
        """Vectorized ``compute_time`` (same expression, batched)."""
        ids = np.asarray(client_ids, dtype=np.int64)
        return np.asarray(n_examples) / (
            nominal_examples_per_s * self._speed[ids]
        )

    def transfer_concurrent(
        self, client_ids: list[int], nbytes: list[int], direction: str
    ) -> list[float]:
        """Seconds for SIMULTANEOUS transfers contending for the server NIC.

        Each flow starts after its own link latency (+jitter), then the data
        phases share ``cfg.server_bandwidth_bytes_s`` max-min fairly, each
        flow still capped by its client link; lost chunks re-enter the pipe
        (wire bytes = goodput + retransmissions) and their timeouts extend
        the flow. Per-client times are logged and returned in
        ``client_ids`` order. With an infinite server cap and no loss this
        is numerically identical to N independent ``transfer`` calls.
        """
        jitters = [
            float(self._rng.uniform(0.0, self.cfg.latency_jitter_s))
            for _ in client_ids
        ]
        penalties = [self._loss_penalty(b) for b in nbytes]
        starts = [self.links[k].latency_s + j for k, j in zip(client_ids, jitters)]
        caps = [self.links[k].bandwidth_bytes_s for k in client_ids]
        wire = [b + pen[0] for b, pen in zip(nbytes, penalties)]
        # 0-or-inf = uncapped, matching the deadline_s convention above
        nic = self.cfg.server_bandwidth_bytes_s
        done = _fair_share_completion(
            starts, wire, caps, nic if nic > 0 else float("inf")
        )
        done = [d + pen[1] for d, pen in zip(done, penalties)]
        for k, b, dt, pen in zip(client_ids, nbytes, done, penalties):
            self.log.append(TransferEvent(k, direction, b, dt, pen[0], pen[2]))
        return done

    def transfer_timed(self, client_id: int, nbytes: int, start_s: float,
                       direction: str, *, now_s: float | None = None) -> float:
        """One transfer STARTING at absolute simulated time ``start_s``,
        contending with other in-flight ``transfer_timed`` flows in the
        same direction for the server NIC (async-upload contention).

        Event-driven servers discover transfers one at a time, so the exact
        fluid solution is not computable at dispatch; instead the flow's
        rate is degraded by its overlap count — rate = min(link,
        NIC / (1 + #overlapping flows)), iterated to a fixed point — which
        captures the burst-of-arrivals slowdown while staying causal.
        ``now_s`` is the caller's event clock (non-decreasing across calls;
        defaults to ``start_s``): flows finished before it are pruned, so
        pass it when transfer start times may arrive out of order. With an
        infinite NIC cap and no loss this is numerically identical to
        ``transfer``. Returns the DURATION from ``start_s`` to completion
        (logged).
        """
        jitter = float(self._rng.uniform(0.0, self.cfg.latency_jitter_s))
        retrans, delay, retries = self._loss_penalty(nbytes)
        link = self.links[client_id]
        wire = nbytes + retrans
        nic = self.cfg.server_bandwidth_bytes_s
        if nic <= 0 or nic == float("inf"):
            # bit-identical to ``transfer`` (same float expression), so an
            # uncapped async run reproduces the per-link model exactly.
            dt = link.transfer_time(wire, jitter) + delay
            self.log.append(
                TransferEvent(client_id, direction, nbytes, dt, retrans, retries)
            )
            return dt
        data_start = start_s + link.latency_s + jitter
        flows = self._inflight.setdefault(direction, [])
        # the event clock is non-decreasing: flows already finished by now
        # can never overlap this or any later transfer.
        prune_t = now_s if now_s is not None else data_start
        flows[:] = [f for f in flows if f[1] > prune_t]
        dur = wire / min(link.bandwidth_bytes_s, nic)
        for _ in range(2):  # fixed point on the overlap count
            end = data_start + dur
            overlap = sum(1 for s, e in flows if s < end and e > data_start)
            rate = min(link.bandwidth_bytes_s, nic / (1 + overlap))
            dur = wire / rate
        flows.append((data_start, data_start + dur))
        dt = (data_start + dur + delay) - start_s
        self.log.append(
            TransferEvent(client_id, direction, nbytes, dt, retrans, retries)
        )
        return dt

    def compute_time(self, client_id: int, n_examples: int,
                     nominal_examples_per_s: float = 5000.0) -> float:
        """Local-training wall time for ``n_examples`` processed examples."""
        return n_examples / (nominal_examples_per_s * self.links[client_id].compute_speed)

    def summary(self) -> dict:
        """Aggregate transfer statistics for reporting. ``total_bytes`` is
        goodput; retransmission overhead is reported separately so the
        effective-goodput fraction under loss is a one-line division.
        Merges the per-event log with the batched-transfer ledger."""
        n_batch = sum(a.size for a in self._batch_secs)
        if not self.log and n_batch == 0:
            return {"n_transfers": 0, "total_bytes": 0, "total_seconds": 0.0,
                    "mean_seconds": 0.0, "p95_seconds": 0.0,
                    "retrans_bytes": 0, "retries": 0, "goodput_fraction": 1.0}
        parts = []
        if self.log:
            parts.append(np.array([e.seconds for e in self.log]))
        parts.extend(self._batch_secs)
        secs = np.concatenate(parts)
        goodput = int(sum(e.nbytes for e in self.log)) + self._batch_bytes
        retrans = (int(sum(e.retrans_bytes for e in self.log))
                   + self._batch_retrans)
        return {
            "n_transfers": len(self.log) + n_batch,
            "total_bytes": goodput,
            "total_seconds": float(secs.sum()),
            "mean_seconds": float(secs.mean()),
            "p95_seconds": float(np.percentile(secs, 95)),
            "retrans_bytes": retrans,
            "retries": int(sum(e.retries for e in self.log)) + self._batch_retries,
            "goodput_fraction": goodput / max(goodput + retrans, 1),
        }
