"""Simulated transport: payload bytes → wall-clock transfer times.

Each client gets a ``ClientLink`` with bandwidth and latency drawn once
from log-normal / normal distributions (heterogeneous edge fleet: a few
fast links, a long slow tail — the shape WAN measurements show). A
transfer of ``nbytes`` over a link costs

    t = latency + nbytes / bandwidth        (+ optional jitter per transfer)

so *stragglers are emergent*: a client is late because its payload is
large or its link is slow, not because a coin flip said so. Ternary
compression therefore shows up directly as shorter transfer times — the
paper's Table IV claim expressed in seconds instead of bytes.

Concurrent transfers additionally contend for the SERVER's NIC
(``ChannelConfig.server_bandwidth_bytes_s``): ``transfer_concurrent``
runs a fluid max-min fair-share model where simultaneous flows split the
server's capacity (each still capped by its own client link), so a
broadcast to N clients through a saturated NIC takes ~N× longer than a
single download — the shared-bottleneck effect a per-link model misses.
The default cap is infinite, which reduces exactly to independent links.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Fleet-level link distribution + round deadline.

    Attributes:
      mean_bandwidth_bytes_s: median link bandwidth, bytes/second (default ≈ a
        1 MB/s uplink — the paper targets exactly this regime of limited
        upstream capacity).
      bandwidth_sigma: σ of the log-normal bandwidth draw (0 → homogeneous).
      base_latency_s: mean one-way link latency (propagation + handshake).
      latency_jitter_s: per-transfer uniform jitter in [0, jitter).
      deadline_s: round deadline for the SYNC server — a client whose
        download + compute + upload exceeds it is dropped as a straggler
        (0 or inf → never drop).
      compute_speed_sigma: σ of the log-normal per-client compute speed
        multiplier (device heterogeneity; 1.0 = nominal).
      server_bandwidth_bytes_s: total server NIC capacity shared by
        SIMULTANEOUS transfers (0 or inf → no shared bottleneck, like
        ``deadline_s``). Applied by ``transfer_concurrent`` with max-min
        fairness.
    """

    mean_bandwidth_bytes_s: float = 1e6
    bandwidth_sigma: float = 0.5
    base_latency_s: float = 0.05
    latency_jitter_s: float = 0.01
    deadline_s: float = float("inf")
    compute_speed_sigma: float = 0.3
    server_bandwidth_bytes_s: float = float("inf")


@dataclasses.dataclass(frozen=True)
class ClientLink:
    """One client's drawn link + device characteristics."""

    client_id: int
    bandwidth_bytes_s: float
    latency_s: float
    compute_speed: float  # multiplier on nominal examples/sec

    def transfer_time(self, nbytes: int, jitter: float = 0.0) -> float:
        return self.latency_s + jitter + nbytes / self.bandwidth_bytes_s


@dataclasses.dataclass
class TransferEvent:
    """Log entry for one wire transfer (used by FedResult.transfer_log)."""

    client_id: int
    direction: str  # "down" | "up"
    nbytes: int
    seconds: float


def _fair_share_completion(
    starts: list[float], nbytes: list[int], caps: list[float], total_cap: float
) -> list[float]:
    """Fluid processor-sharing model: completion time of each flow.

    Flow i becomes active at ``starts[i]`` with ``nbytes[i]`` to move, its
    rate capped by its own link ``caps[i]``; active flows share
    ``total_cap`` with max-min fairness (water-filling). Returns absolute
    completion times. With ``total_cap`` = inf every flow runs at its own
    cap and this degenerates to latency + bytes/bandwidth.
    """
    n = len(starts)
    remaining = [float(b) for b in nbytes]
    done = [0.0] * n
    finished = [False] * n
    t = 0.0
    while not all(finished):
        active = [i for i in range(n) if not finished[i] and starts[i] <= t]
        if not active:
            t = min(s for i, s in enumerate(starts) if not finished[i] and s > t)
            continue
        # --- max-min water-filling over the active flows ------------------
        rates = {}
        pool = total_cap
        todo = list(active)
        while todo:
            share = pool / len(todo) if pool != float("inf") else float("inf")
            capped = [i for i in todo if caps[i] <= share]
            if not capped:
                for i in todo:
                    rates[i] = share
                todo = []
            else:
                for i in capped:
                    rates[i] = caps[i]
                    if pool != float("inf"):
                        pool -= caps[i]
                todo = [i for i in todo if i not in capped]
        # --- advance to the next event (completion or a flow starting) ----
        dt_complete = min(
            remaining[i] / rates[i] if rates[i] > 0 else float("inf")
            for i in active
        )
        upcoming = [s for i, s in enumerate(starts) if not finished[i] and s > t]
        dt = min(dt_complete, min(upcoming) - t) if upcoming else dt_complete
        for i in active:
            remaining[i] -= rates[i] * dt
            if remaining[i] <= 1e-9:
                finished[i] = True
                done[i] = t + dt
        t += dt
    return done


class Channel:
    """Holds the fleet's links and meters transfers through them."""

    def __init__(self, cfg: ChannelConfig, n_clients: int, seed: int = 0):
        self.cfg = cfg
        rng = np.random.default_rng(seed)
        bw = cfg.mean_bandwidth_bytes_s * rng.lognormal(
            mean=0.0, sigma=cfg.bandwidth_sigma, size=n_clients
        )
        lat = np.maximum(
            rng.normal(cfg.base_latency_s, cfg.base_latency_s * 0.2, size=n_clients),
            1e-4,
        )
        speed = rng.lognormal(mean=0.0, sigma=cfg.compute_speed_sigma, size=n_clients)
        self.links = [
            ClientLink(k, float(bw[k]), float(lat[k]), float(speed[k]))
            for k in range(n_clients)
        ]
        self._rng = rng
        self.log: list[TransferEvent] = []

    def transfer(self, client_id: int, nbytes: int, direction: str) -> float:
        """Seconds to move ``nbytes`` over this client's link (logged)."""
        jitter = float(self._rng.uniform(0.0, self.cfg.latency_jitter_s))
        dt = self.links[client_id].transfer_time(nbytes, jitter)
        self.log.append(TransferEvent(client_id, direction, nbytes, dt))
        return dt

    def transfer_concurrent(
        self, client_ids: list[int], nbytes: list[int], direction: str
    ) -> list[float]:
        """Seconds for SIMULTANEOUS transfers contending for the server NIC.

        Each flow starts after its own link latency (+jitter), then the data
        phases share ``cfg.server_bandwidth_bytes_s`` max-min fairly, each
        flow still capped by its client link. Per-client times are logged
        and returned in ``client_ids`` order. With an infinite server cap
        this is numerically identical to N independent ``transfer`` calls.
        """
        jitters = [
            float(self._rng.uniform(0.0, self.cfg.latency_jitter_s))
            for _ in client_ids
        ]
        starts = [self.links[k].latency_s + j for k, j in zip(client_ids, jitters)]
        caps = [self.links[k].bandwidth_bytes_s for k in client_ids]
        # 0-or-inf = uncapped, matching the deadline_s convention above
        nic = self.cfg.server_bandwidth_bytes_s
        done = _fair_share_completion(
            starts, nbytes, caps, nic if nic > 0 else float("inf")
        )
        for k, b, dt in zip(client_ids, nbytes, done):
            self.log.append(TransferEvent(k, direction, b, dt))
        return done

    def compute_time(self, client_id: int, n_examples: int,
                     nominal_examples_per_s: float = 5000.0) -> float:
        """Local-training wall time for ``n_examples`` processed examples."""
        return n_examples / (nominal_examples_per_s * self.links[client_id].compute_speed)

    def summary(self) -> dict:
        """Aggregate transfer statistics for reporting."""
        if not self.log:
            return {"n_transfers": 0, "total_bytes": 0, "total_seconds": 0.0,
                    "mean_seconds": 0.0, "p95_seconds": 0.0}
        secs = np.array([e.seconds for e in self.log])
        return {
            "n_transfers": len(self.log),
            "total_bytes": int(sum(e.nbytes for e in self.log)),
            "total_seconds": float(secs.sum()),
            "mean_seconds": float(secs.mean()),
            "p95_seconds": float(np.percentile(secs, 95)),
        }
