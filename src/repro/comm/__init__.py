"""repro.comm — the communication subsystem.

Everything the federated protocol puts "on the wire" goes through this
package:

  - ``wire``:    byte-level serialization of update pytrees (versioned
                 header, per-leaf records dispatched through the codec
                 record registry, CRC32 integrity; v2 with a v1 decode
                 path). Upload / download bytes are measured as
                 ``len(encode_update(...))`` — real serialized buffers,
                 never analytic formulas.
  - ``channel``: a simulated transport that converts payload bytes into
                 wall-clock transfer times from per-client bandwidth /
                 latency distributions — stragglers emerge from
                 bytes ÷ bandwidth instead of a coin flip — with optional
                 server-NIC contention across concurrent transfers.
"""

from repro.comm.channel import Channel, ChannelConfig, ClientLink, TransferEvent
from repro.comm.wire import (
    SUPPORTED_VERSIONS,
    WIRE_VERSION,
    WireError,
    WireRecord,
    decode_tensor,
    decode_update,
    encode_tensor,
    encode_update,
    register_record,
    update_nbytes,
)

__all__ = [
    "WIRE_VERSION", "SUPPORTED_VERSIONS", "WireError",
    "WireRecord", "register_record",
    "encode_update", "decode_update", "encode_tensor", "decode_tensor",
    "update_nbytes",
    "Channel", "ChannelConfig", "ClientLink", "TransferEvent",
]
