"""repro.comm — the communication subsystem.

Everything the federated protocol puts "on the wire" goes through this
package:

  - ``wire``:    byte-level serialization of update pytrees (versioned
                 header, per-leaf records dispatched through the codec
                 record registry, CRC32 integrity; v2 with a v1 decode
                 path). Upload / download bytes are measured as
                 ``len(encode_update(...))`` — real serialized buffers,
                 never analytic formulas.
  - ``channel``: a simulated transport that converts payload bytes into
                 wall-clock transfer times from per-client bandwidth /
                 latency distributions — stragglers emerge from
                 bytes ÷ bandwidth instead of a coin flip — with optional
                 server-NIC contention across concurrent transfers.
  - ``transport``: length-prefixed TCP framing for the REAL process
                 boundary (``fed.mp_server``): incremental recv into the
                 zero-copy wire decode, partial-read tolerant, byte counts
                 metered from actual socket traffic, typed failure
                 taxonomy + retry/backoff policy, resumable uploads.
  - ``faults``:  deterministic in-path chaos (``ChaosProxy``): the
                 Gilbert–Elliott chain from ``channel`` applied to REAL
                 sockets — drops, delays, throttling, mid-frame
                 truncation, connection resets — keyed by
                 (seed, client_id, attempt) at absolute byte offsets.
"""

from repro.comm.channel import Channel, ChannelConfig, ClientLink, TransferEvent
from repro.comm.faults import ChaosProxy, FaultConfig, FaultSchedule
from repro.comm.transport import (
    FT_BCAST,
    FT_DONE,
    FT_ERR,
    FT_HELLO,
    FT_RESUME,
    FT_UPDATE,
    PROTO_VERSION,
    SUPPORTED_PROTOS,
    Frame,
    FrameDecoder,
    FrameError,
    ProtocolError,
    RetryExhausted,
    RetryPolicy,
    TornConnectionError,
    TransportError,
    TransportTimeout,
    call_with_retries,
    pack_frame,
    recv_frame,
    send_frame,
)
from repro.comm.wire import (
    MAX_BODY_BYTES,
    SUPPORTED_VERSIONS,
    WIRE_VERSION,
    StreamDecoder,
    WireError,
    WireRecord,
    decode_tensor,
    decode_update,
    decode_update_chunks,
    encode_tensor,
    encode_update,
    register_record,
    update_nbytes,
)

__all__ = [
    "WIRE_VERSION", "SUPPORTED_VERSIONS", "WireError",
    "WireRecord", "register_record",
    "encode_update", "decode_update", "encode_tensor", "decode_tensor",
    "update_nbytes",
    "StreamDecoder", "decode_update_chunks", "MAX_BODY_BYTES",
    "Channel", "ChannelConfig", "ClientLink", "TransferEvent",
    "Frame", "FrameDecoder", "TransportError", "FrameError",
    "TornConnectionError", "TransportTimeout", "ProtocolError",
    "RetryExhausted", "RetryPolicy", "call_with_retries",
    "pack_frame", "send_frame", "recv_frame",
    "FT_HELLO", "FT_BCAST", "FT_UPDATE", "FT_DONE", "FT_ERR", "FT_RESUME",
    "PROTO_VERSION", "SUPPORTED_PROTOS",
    "ChaosProxy", "FaultConfig", "FaultSchedule",
]
