"""Length-prefixed TCP framing for federated wire traffic.

This is the first layer of the repo that moves bytes across a REAL process
boundary: everything below (``comm.wire``) serializes to buffers, everything
above (``fed.mp_server``, the socket federation demo) speaks in frames.

A frame is

    FRAME HEADER (16 B, little-endian):
      magic        4s  b"TFT1"
      ftype        u8  message type (HELLO / BCAST / UPDATE / DONE / ERR)
      flags        u8  reserved (0)
      meta_len     u16 JSON metadata length
      payload_len  u64 payload length
    META     meta_len bytes of UTF-8 JSON (client_id, weight, ...)
    PAYLOAD  payload_len bytes — for UPDATE/BCAST this is a complete
             ``comm.wire`` buffer, whose own CRC32 is re-verified when the
             receiver decodes it (``decode_update`` /
             ``decode_update_leaves``), so a torn or corrupted transfer is
             caught at the wire boundary even if TCP delivered it "intact".

``FrameDecoder`` mirrors ``wire.StreamDecoder``: feed arbitrary recv()
chunks, complete frames pop out, malformed headers fail fast (never wait
for a body a garbage length field promised), and ``close()`` at EOF raises
on a partial frame — a dropped connection surfaces as ``TransportError``,
never a hang or a silent short read.

Byte metering: ``send_frame`` returns the exact framed byte count and
``FrameDecoder.bytes_in`` counts every byte taken off the socket, so the
federation ledger's "upload bytes" are measured from actual socket traffic,
not from payload lengths.

Failures carry a typed taxonomy under ``TransportError`` — ``FrameError``
(malformed bytes), ``TornConnectionError`` (peer died mid-conversation),
``TransportTimeout`` (also a ``TimeoutError``), ``ProtocolError`` (valid
frames in an invalid order / unsupported protocol version) and
``RetryExhausted`` — so the federation ledger can book WHY a client was
lost, not just that it was. ``RetryPolicy`` + ``call_with_retries`` give
clients deterministic exponential backoff with seeded jitter; the HELLO
(protocol version 2) carries a client nonce + attempt counter so a
re-connected client can RESUME its upload at the server's byte offset
instead of re-sending (see ``fed.mp_server``).
"""

from __future__ import annotations

import dataclasses
import json
import socket
import struct
import time
from collections import deque
from typing import Any, Callable

TRANSPORT_MAGIC = b"TFT1"
_FRAME = struct.Struct("<4sBBHQ")  # magic, ftype, flags, meta_len, payload_len

# frame types
FT_HELLO = 1    # client → server: {"client_id": int, "proto": int, ...}
FT_BCAST = 2    # server → client: payload = global-model wire buffer
FT_UPDATE = 3   # client → server: payload = update wire buffer, meta weight
FT_DONE = 4     # either direction: orderly end of conversation
FT_ERR = 5      # either direction: meta = {"error": str}
FT_RESUME = 6   # server → client: {"have": int} — resume upload at offset
_KNOWN_TYPES = frozenset((FT_HELLO, FT_BCAST, FT_UPDATE, FT_DONE, FT_ERR,
                          FT_RESUME))

# HELLO protocol version: v1 = PR-7 one-shot conversation (no nonce, no
# resume); v2 adds {proto, nonce, attempt} and the RESUME frame. A server
# answers a v2 HELLO with v2 frames only — a v1 peer never sees FT_RESUME.
PROTO_V1 = 1
PROTO_VERSION = 2
SUPPORTED_PROTOS = frozenset((PROTO_V1, PROTO_VERSION))

# a frame larger than this is a corrupted length field, not an update
MAX_PAYLOAD_BYTES = 1 << 34  # 16 GiB
RECV_CHUNK = 1 << 16


class TransportError(ConnectionError):
    """Malformed frame or torn connection at the transport layer."""


class FrameError(TransportError):
    """Bytes that are not a valid frame: bad magic, unknown type, corrupted
    length field, malformed JSON meta, or feeding a closed decoder."""


class TornConnectionError(TransportError):
    """The peer vanished mid-conversation: EOF inside a frame, reset, or a
    clean close where a frame was still owed."""


class TransportTimeout(TransportError, TimeoutError):
    """The peer went silent past the deadline (socket timeout surfaced
    through the transport taxonomy; still catchable as ``TimeoutError``)."""


class ProtocolError(TransportError):
    """Well-formed frames in an order the protocol forbids — wrong frame
    type for the conversation state, unsupported protocol version,
    duplicate or mismatched client identity."""


class RetryExhausted(TransportError):
    """A retrying client gave up: every attempt failed. ``attempts`` counts
    them; ``__cause__`` is the last attempt's error."""

    def __init__(self, msg: str, attempts: int = 0):
        super().__init__(msg)
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class Frame:
    ftype: int
    meta: dict
    payload: bytes

    @property
    def nbytes_framed(self) -> int:
        """Exact on-wire size of this frame."""
        return _FRAME.size + len(_meta_bytes(self.meta)) + len(self.payload)


def _meta_bytes(meta: dict | None) -> bytes:
    if not meta:
        return b""
    return json.dumps(meta, sort_keys=True, separators=(",", ":")).encode("utf-8")


def pack_frame(ftype: int, payload: bytes = b"", meta: dict | None = None) -> bytes:
    """Serialize one frame (header + JSON meta + payload)."""
    if ftype not in _KNOWN_TYPES:
        raise FrameError(f"unknown frame type {ftype}")
    mb = _meta_bytes(meta)
    if len(mb) > 0xFFFF:
        raise FrameError(f"frame meta too large: {len(mb)} B")
    if len(payload) > MAX_PAYLOAD_BYTES:
        raise FrameError(f"frame payload too large: {len(payload)} B")
    return b"".join([
        _FRAME.pack(TRANSPORT_MAGIC, ftype, 0, len(mb), len(payload)),
        mb,
        payload,
    ])


class FrameDecoder:
    """Incremental frame reassembly over recv() chunks (one per connection).

    Same failure discipline as ``wire.StreamDecoder``: header problems
    (magic, unknown type, oversized lengths) raise ``TransportError`` the
    moment the 16 header bytes are in; ``close()`` on a partial frame
    raises instead of dropping it.
    """

    def __init__(self, *, max_payload_bytes: int = MAX_PAYLOAD_BYTES):
        self._buf = bytearray()
        self._need: int | None = None
        self._max_payload = int(max_payload_bytes)
        self._ready: deque[Frame] = deque()
        self.bytes_in = 0          # every byte fed, the socket-traffic meter
        self.closed = False

    def _header_check(self) -> int:
        magic, ftype, _flags, meta_len, payload_len = _FRAME.unpack_from(self._buf)
        if magic != TRANSPORT_MAGIC:
            raise FrameError(
                f"bad frame magic {magic!r} (expected {TRANSPORT_MAGIC!r})"
            )
        if ftype not in _KNOWN_TYPES:
            raise FrameError(f"unknown frame type {ftype}")
        if payload_len > self._max_payload:
            raise FrameError(
                f"payload_len {payload_len} exceeds cap {self._max_payload} — "
                "corrupted length field"
            )
        return _FRAME.size + meta_len + payload_len

    def feed(self, chunk: bytes) -> list[Frame]:
        """Absorb one chunk; returns the frames it completed (they are ALSO
        queued internally — drain with ``pop()`` OR consume the return
        value, not both)."""
        if self.closed:
            raise FrameError("feed() after close(): decoder is finished")
        self._buf += chunk
        self.bytes_in += len(chunk)
        out: list[Frame] = []
        while True:
            if self._need is None:
                if len(self._buf) < _FRAME.size:
                    break
                self._need = self._header_check()
            if len(self._buf) < self._need:
                break
            raw = bytes(self._buf[: self._need])
            del self._buf[: self._need]
            self._need = None
            _, ftype, _flags, meta_len, payload_len = _FRAME.unpack_from(raw)
            meta_raw = raw[_FRAME.size : _FRAME.size + meta_len]
            try:
                meta = json.loads(meta_raw.decode("utf-8")) if meta_len else {}
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise FrameError(f"malformed frame meta: {e}") from e
            if not isinstance(meta, dict):
                raise FrameError(
                    f"frame meta must be a JSON object, got {type(meta).__name__}"
                )
            out.append(Frame(ftype, meta, raw[_FRAME.size + meta_len :]))
        self._ready.extend(out)
        return out

    def pop(self) -> Frame | None:
        """Take the oldest queued complete frame (None if none pending)."""
        return self._ready.popleft() if self._ready else None

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def take_buffer(self) -> bytes:
        """Hand off the raw undecoded tail (bytes of a frame still in
        flight) and leave the decoder clean. The resume path uses this to
        move bytes that over-read past a handshake frame into the
        session's long-lived decoder; ``bytes_in`` keeps counting them
        here (they WERE read off this socket)."""
        out = bytes(self._buf)
        self._buf.clear()
        self._need = None
        return out

    def close(self) -> None:
        self.closed = True
        if self._buf:
            need = "?" if self._need is None else str(self._need)
            raise TornConnectionError(
                f"connection closed mid-frame: {len(self._buf)} bytes pending "
                f"of {need}"
            )


# --------------------------------------------------------------------------
# Blocking socket helpers (the client side of the federation demo).
# --------------------------------------------------------------------------


def send_frame(
    sock: socket.socket, ftype: int, payload: bytes = b"",
    meta: dict | None = None,
) -> int:
    """Send one frame; returns the exact framed byte count put on the wire."""
    buf = pack_frame(ftype, payload, meta)
    sock.sendall(buf)
    return len(buf)


def recv_frame(
    sock: socket.socket, decoder: FrameDecoder | None = None,
    timeout_s: float | None = None,
) -> Frame:
    """Block until one complete frame arrives (partial-read tolerant).

    Pass a persistent ``decoder`` when the connection carries several
    frames — bytes of the NEXT frame that rode in on the same recv() stay
    buffered in it. EOF mid-frame raises ``TornConnectionError``; a socket
    timeout surfaces as ``TransportTimeout``. A ``timeout_s`` applies only
    to THIS call — the socket's prior timeout is restored on the way out,
    never left mutated as a side effect.
    """
    dec = decoder if decoder is not None else FrameDecoder()
    prior = sock.gettimeout()
    if timeout_s is not None:
        sock.settimeout(timeout_s)
    try:
        while True:
            # frames buffered by an earlier recv() drain first (pop, so a
            # chunk carrying several frames never loses the extras)
            frame = dec.pop()
            if frame is not None:
                return frame
            try:
                chunk = sock.recv(RECV_CHUNK)
            except socket.timeout as e:
                raise TransportTimeout(
                    f"no frame within {timeout_s if timeout_s is not None else prior}s"
                ) from e
            except ConnectionResetError as e:
                raise TornConnectionError(f"connection reset: {e}") from e
            if not chunk:
                dec.close()   # raises TornConnectionError on partial frame
                raise TornConnectionError(
                    "connection closed before a frame arrived")
            dec.feed(chunk)
    finally:
        if timeout_s is not None:
            try:
                sock.settimeout(prior)
            except OSError:
                pass   # socket already dead — nothing to restore


# --------------------------------------------------------------------------
# Retry policy (reconnect/backoff for flaky links).
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with seeded jitter for reconnecting clients.

    ``backoff_s(attempt)`` grows ``base_backoff_s · factor^attempt`` capped
    at ``max_backoff_s``; jitter multiplies by U[1-jitter_frac, 1+jitter_frac]
    drawn from the CALLER's rng, so a seeded client backs off identically
    run to run (chaos determinism) while distinct clients decorrelate.
    """

    max_attempts: int = 5
    base_backoff_s: float = 0.05
    backoff_factor: float = 2.0
    max_backoff_s: float = 2.0
    jitter_frac: float = 0.1
    connect_timeout_s: float = 10.0
    io_timeout_s: float = 60.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be ≥ 1, got {self.max_attempts}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be ≥ 1, got {self.backoff_factor}")

    def backoff_s(self, attempt: int, rng=None) -> float:
        base = min(self.base_backoff_s * self.backoff_factor ** attempt,
                   self.max_backoff_s)
        if rng is None or self.jitter_frac <= 0:
            return base
        lo, hi = 1.0 - self.jitter_frac, 1.0 + self.jitter_frac
        return base * float(rng.uniform(lo, hi))


def call_with_retries(
    fn: Callable[[int], Any], policy: RetryPolicy, rng=None, *,
    retryable: tuple = (TransportError, OSError),
    fatal: tuple = (),
    sleep: Callable[[float], None] = time.sleep,
) -> Any:
    """Run ``fn(attempt)`` until it returns, retrying ``retryable`` failures
    with the policy's backoff. ``fatal`` exception types (checked first)
    propagate immediately — a server REJECTION must not be retried into.
    Exhaustion raises ``RetryExhausted`` chaining the last error."""
    last: BaseException | None = None
    for attempt in range(policy.max_attempts):
        try:
            return fn(attempt)
        except fatal:
            raise
        except retryable as e:
            last = e
            if attempt + 1 < policy.max_attempts:
                sleep(policy.backoff_s(attempt, rng))
    raise RetryExhausted(
        f"gave up after {policy.max_attempts} attempts: {last}",
        attempts=policy.max_attempts,
    ) from last


Pytree = Any
