"""Length-prefixed TCP framing for federated wire traffic.

This is the first layer of the repo that moves bytes across a REAL process
boundary: everything below (``comm.wire``) serializes to buffers, everything
above (``fed.mp_server``, the socket federation demo) speaks in frames.

A frame is

    FRAME HEADER (16 B, little-endian):
      magic        4s  b"TFT1"
      ftype        u8  message type (HELLO / BCAST / UPDATE / DONE / ERR)
      flags        u8  reserved (0)
      meta_len     u16 JSON metadata length
      payload_len  u64 payload length
    META     meta_len bytes of UTF-8 JSON (client_id, weight, ...)
    PAYLOAD  payload_len bytes — for UPDATE/BCAST this is a complete
             ``comm.wire`` buffer, whose own CRC32 is re-verified when the
             receiver decodes it (``decode_update`` /
             ``decode_update_leaves``), so a torn or corrupted transfer is
             caught at the wire boundary even if TCP delivered it "intact".

``FrameDecoder`` mirrors ``wire.StreamDecoder``: feed arbitrary recv()
chunks, complete frames pop out, malformed headers fail fast (never wait
for a body a garbage length field promised), and ``close()`` at EOF raises
on a partial frame — a dropped connection surfaces as ``TransportError``,
never a hang or a silent short read.

Byte metering: ``send_frame`` returns the exact framed byte count and
``FrameDecoder.bytes_in`` counts every byte taken off the socket, so the
federation ledger's "upload bytes" are measured from actual socket traffic,
not from payload lengths.
"""

from __future__ import annotations

import dataclasses
import json
import socket
import struct
from collections import deque
from typing import Any

TRANSPORT_MAGIC = b"TFT1"
_FRAME = struct.Struct("<4sBBHQ")  # magic, ftype, flags, meta_len, payload_len

# frame types
FT_HELLO = 1    # client → server: {"client_id": int}
FT_BCAST = 2    # server → client: payload = global-model wire buffer
FT_UPDATE = 3   # client → server: payload = update wire buffer, meta weight
FT_DONE = 4     # either direction: orderly end of conversation
FT_ERR = 5      # either direction: meta = {"error": str}
_KNOWN_TYPES = frozenset((FT_HELLO, FT_BCAST, FT_UPDATE, FT_DONE, FT_ERR))

# a frame larger than this is a corrupted length field, not an update
MAX_PAYLOAD_BYTES = 1 << 34  # 16 GiB
RECV_CHUNK = 1 << 16


class TransportError(ConnectionError):
    """Malformed frame or torn connection at the transport layer."""


@dataclasses.dataclass(frozen=True)
class Frame:
    ftype: int
    meta: dict
    payload: bytes

    @property
    def nbytes_framed(self) -> int:
        """Exact on-wire size of this frame."""
        return _FRAME.size + len(_meta_bytes(self.meta)) + len(self.payload)


def _meta_bytes(meta: dict | None) -> bytes:
    if not meta:
        return b""
    return json.dumps(meta, sort_keys=True, separators=(",", ":")).encode("utf-8")


def pack_frame(ftype: int, payload: bytes = b"", meta: dict | None = None) -> bytes:
    """Serialize one frame (header + JSON meta + payload)."""
    if ftype not in _KNOWN_TYPES:
        raise TransportError(f"unknown frame type {ftype}")
    mb = _meta_bytes(meta)
    if len(mb) > 0xFFFF:
        raise TransportError(f"frame meta too large: {len(mb)} B")
    if len(payload) > MAX_PAYLOAD_BYTES:
        raise TransportError(f"frame payload too large: {len(payload)} B")
    return b"".join([
        _FRAME.pack(TRANSPORT_MAGIC, ftype, 0, len(mb), len(payload)),
        mb,
        payload,
    ])


class FrameDecoder:
    """Incremental frame reassembly over recv() chunks (one per connection).

    Same failure discipline as ``wire.StreamDecoder``: header problems
    (magic, unknown type, oversized lengths) raise ``TransportError`` the
    moment the 16 header bytes are in; ``close()`` on a partial frame
    raises instead of dropping it.
    """

    def __init__(self, *, max_payload_bytes: int = MAX_PAYLOAD_BYTES):
        self._buf = bytearray()
        self._need: int | None = None
        self._max_payload = int(max_payload_bytes)
        self._ready: deque[Frame] = deque()
        self.bytes_in = 0          # every byte fed, the socket-traffic meter

    def _header_check(self) -> int:
        magic, ftype, _flags, meta_len, payload_len = _FRAME.unpack_from(self._buf)
        if magic != TRANSPORT_MAGIC:
            raise TransportError(
                f"bad frame magic {magic!r} (expected {TRANSPORT_MAGIC!r})"
            )
        if ftype not in _KNOWN_TYPES:
            raise TransportError(f"unknown frame type {ftype}")
        if payload_len > self._max_payload:
            raise TransportError(
                f"payload_len {payload_len} exceeds cap {self._max_payload} — "
                "corrupted length field"
            )
        return _FRAME.size + meta_len + payload_len

    def feed(self, chunk: bytes) -> list[Frame]:
        """Absorb one chunk; returns the frames it completed (they are ALSO
        queued internally — drain with ``pop()`` OR consume the return
        value, not both)."""
        self._buf += chunk
        self.bytes_in += len(chunk)
        out: list[Frame] = []
        while True:
            if self._need is None:
                if len(self._buf) < _FRAME.size:
                    break
                self._need = self._header_check()
            if len(self._buf) < self._need:
                break
            raw = bytes(self._buf[: self._need])
            del self._buf[: self._need]
            self._need = None
            _, ftype, _flags, meta_len, payload_len = _FRAME.unpack_from(raw)
            meta_raw = raw[_FRAME.size : _FRAME.size + meta_len]
            try:
                meta = json.loads(meta_raw.decode("utf-8")) if meta_len else {}
            except (UnicodeDecodeError, json.JSONDecodeError) as e:
                raise TransportError(f"malformed frame meta: {e}") from e
            if not isinstance(meta, dict):
                raise TransportError(
                    f"frame meta must be a JSON object, got {type(meta).__name__}"
                )
            out.append(Frame(ftype, meta, raw[_FRAME.size + meta_len :]))
        self._ready.extend(out)
        return out

    def pop(self) -> Frame | None:
        """Take the oldest queued complete frame (None if none pending)."""
        return self._ready.popleft() if self._ready else None

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def close(self) -> None:
        if self._buf:
            need = "?" if self._need is None else str(self._need)
            raise TransportError(
                f"connection closed mid-frame: {len(self._buf)} bytes pending "
                f"of {need}"
            )


# --------------------------------------------------------------------------
# Blocking socket helpers (the client side of the federation demo).
# --------------------------------------------------------------------------


def send_frame(
    sock: socket.socket, ftype: int, payload: bytes = b"",
    meta: dict | None = None,
) -> int:
    """Send one frame; returns the exact framed byte count put on the wire."""
    buf = pack_frame(ftype, payload, meta)
    sock.sendall(buf)
    return len(buf)


def recv_frame(
    sock: socket.socket, decoder: FrameDecoder | None = None,
    timeout_s: float | None = None,
) -> Frame:
    """Block until one complete frame arrives (partial-read tolerant).

    Pass a persistent ``decoder`` when the connection carries several
    frames — bytes of the NEXT frame that rode in on the same recv() stay
    buffered in it. EOF mid-frame raises ``TransportError``; a socket
    timeout surfaces as the standard ``socket.timeout`` (an ``OSError``).
    """
    dec = decoder if decoder is not None else FrameDecoder()
    if timeout_s is not None:
        sock.settimeout(timeout_s)
    while True:
        # frames buffered by an earlier recv() drain first (pop, so a chunk
        # carrying several frames never loses the extras)
        frame = dec.pop()
        if frame is not None:
            return frame
        chunk = sock.recv(RECV_CHUNK)
        if not chunk:
            dec.close()   # raises on partial frame
            raise TransportError("connection closed before a frame arrived")
        dec.feed(chunk)


Pytree = Any
