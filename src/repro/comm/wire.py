"""Byte-level wire codec for federated update payloads.

An *update* is a pytree of leaves (raw arrays and/or registered wire leaves:
``TernaryTensor``, ``DowncastTensor``, ``TopKTensor``) as produced by
``core.tfedavg.client_update_payload`` / ``server_requantize`` /
``core.compression.compress_pytree``. ``encode_update`` serializes it into
one self-describing buffer; ``decode_update`` rebuilds the pytree
bit-exactly. All byte accounting in the repo is ``len(encode_update(tree))``
— measured from the actual buffer, never estimated.

Buffer layout (all little-endian):

    HEADER (24 B):
      magic      4s   b"TFW1"  (format family; the version field increments)
      version    u16  lowest version able to carry the payload's records
      flags      u16  reserved (0)
      n_records  u32  number of leaf records
      crc32      u32  zlib.crc32 of the record section
      body_len   u64  length of the record section in bytes

    RECORD (one per pytree leaf, in tree_flatten order):
      path_len   u16  + path bytes (utf-8; entries joined by "\\x1f",
                        each entry "d:<key>" for dict keys or
                        "i:<index>" for sequence indices)
      kind       u8   dispatched through the record registry:
        0 RAW      (v1) dtype/ndim/dims, data_len u64 + raw array bytes
        1 TERNARY  (v1) a ``TernaryTensor``: logical dtype/ndim/dims, scale
                   array (dtype/ndim/dims + bytes), packed_len u64 + packed
                   2-bit codes (4 codes/byte, ``kernels.pack2bit`` layout)
        2 DOWNCAST (v2) a ``DowncastTensor``: orig dtype string + the
                   downcast payload as a RAW-style array
        3 TOPK     (v2) a ``TopKTensor``: logical dtype/ndim/dims + indices
                   array (uint32) + values array, both RAW-style
                   (decode-only since v3 — encoders emit TOPK_DELTA)
        4 TOPK_DELTA (v3) a ``TopKTensor`` with DELTA-VARINT indices: the
                   sorted uint32 flat indices ship as LEB128 varints (first
                   index absolute, then strictly-positive gaps) + the values
                   array RAW-style — ~4× fewer index bytes at 10% density

Record kinds are a REGISTRY (``register_record``): each entry binds a kind
byte to a wire-leaf class and its pack/unpack functions, plus the minimum
wire version that may carry it. ``WIRE_VERSION`` is 3; encoders stamp the
LOWEST version whose record set covers the payload (RAW/TERNARY-only
buffers stay v1 so deployed v1-only readers keep working; downcast bumps to
v2, delta-top-k to v3), and decoders accept every ``SUPPORTED_VERSIONS``
buffer — stored v1/v2 checkpoints and captures stay readable forever.

``encode_update`` is STREAMING: a size pre-pass walks the records
(``WireRecord.prepare`` returns each body's exact size plus a writer), one
buffer of the final length is allocated, and every record writes its header
fields and array payloads straight into it (numpy-view memcpy, no
intermediate per-record ``bytes``) — serializing a ResNet payload is one
allocation instead of O(records) concatenations. Records registered with
only the legacy ``pack`` still work: a fallback ``prepare`` materializes
their body once and copies it in.

The CRC covers the whole record section; ``decode_update`` raises
``WireError`` on magic/version/CRC mismatch, truncation, or any malformed
record — a corrupted or torn transfer never silently yields wrong weights
and never escapes as a non-``WireError`` exception.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    KIND_DOWNCAST,
    KIND_RAW,
    KIND_TERNARY,
    KIND_TOPK,
    KIND_TOPK_DELTA,
    DowncastTensor,
    TopKTensor,
    wire_leaf_types,
)
from repro.core.ternary import TernaryTensor

Pytree = Any

WIRE_MAGIC = b"TFW1"
WIRE_VERSION = 3
SUPPORTED_VERSIONS = (1, 2, 3)

_HEADER = struct.Struct("<4sHHIIQ")   # magic, version, flags, n_records, crc, body_len
_PATH_SEP = "\x1f"


class WireError(ValueError):
    """Malformed / corrupted / incompatible wire buffer."""


# --------------------------------------------------------------------------
# Low-level field packers.
# --------------------------------------------------------------------------


def _np(leaf) -> np.ndarray:
    return np.asarray(leaf)


# dtype-name prefixes are a tiny closed set but ``np.dtype.name`` is a
# surprisingly slow computed property — cache the encoded field per dtype
# (and per name string for the string-keyed callers).
_DTYPE_FIELD_CACHE: dict = {}


def _dtype_field(name: str) -> bytes:
    field = _DTYPE_FIELD_CACHE.get(name)
    if field is None:
        dt = name.encode("ascii")
        field = struct.pack("<B", len(dt)) + dt
        _DTYPE_FIELD_CACHE[name] = field
    return field


def _pack_array_meta(arr: np.ndarray) -> bytes:
    field = _DTYPE_FIELD_CACHE.get(arr.dtype)
    if field is None:
        field = _dtype_field(arr.dtype.name)
        _DTYPE_FIELD_CACHE[arr.dtype] = field
    return field + _pack_shape(arr.shape)


def _pack_shape(shape: tuple) -> bytes:
    if not shape:
        return b"\x00"
    return struct.pack(f"<B{len(shape)}I", len(shape), *shape)


def _pack_meta(dtype: str, shape: tuple) -> bytes:
    return _dtype_field(dtype) + _pack_shape(shape)


def _pack_arr(arr: np.ndarray) -> bytes:
    """RAW-style array field: meta + u64 length + raw little-endian bytes."""
    return b"".join(
        [_pack_array_meta(arr), struct.pack("<Q", arr.nbytes), arr.tobytes()]
    )


# --------------------------------------------------------------------------
# Streaming record writers (the encode_update fast path).
# --------------------------------------------------------------------------


# One record body, measured: (exact byte size, emitter). The emitter is
# either the body itself as ``bytes`` (small records — one slice assign in
# the write loop, no closure) or a writer callable that memcpys large array
# payloads into the preallocated buffer and returns the new offset. A plain
# tuple, not a dataclass: encode_update builds one per record and
# object-construction overhead is measurable at that rate.
_Prepared = tuple  # (int, bytes | Callable[[memoryview, int], int])


def _write_array_bytes(view: memoryview, off: int, arr: np.ndarray) -> int:
    """memcpy a C-contiguous array's raw little-endian bytes into the
    buffer — no intermediate ``tobytes`` allocation."""
    end = off + arr.nbytes
    if arr.nbytes:
        view[off:end] = arr.reshape(-1).view(np.uint8).data
    return end


def _contig(leaf) -> np.ndarray:
    arr = _np(leaf)
    # NOT np.ascontiguousarray unconditionally: it promotes 0-d to 1-d,
    # which would corrupt scalar w_q metadata on the wire.
    return arr if arr.flags["C_CONTIGUOUS"] else np.ascontiguousarray(arr)


# payloads at or below this fold into the record's head bytes at prepare
# time: for the many tiny fields (scalar w_q, biases) one small ``tobytes``
# beats the ~4-object numpy-view chain per array; large payloads (packed
# code streams, fp32 weights) keep the zero-copy memcpy into the buffer.
_INLINE_BYTES = 4096


def _head_writer(head: bytes, *arrays: np.ndarray) -> _Prepared:
    """Record body = fixed head bytes followed by raw array payloads."""
    while arrays and arrays[0].nbytes <= _INLINE_BYTES:
        head += arrays[0].tobytes()
        arrays = arrays[1:]
    size = len(head) + sum(a.nbytes for a in arrays)
    if not arrays:
        return (size, head)   # fully inlined: body IS the bytes

    def write(view: memoryview, off: int) -> int:
        end = off + len(head)
        view[off:end] = head
        for a in arrays:
            end = _write_array_bytes(view, end, a)
        return end

    return (size, write)


def _raw_prepare(leaf) -> _Prepared:
    arr = _contig(leaf)
    head = _pack_array_meta(arr) + struct.pack("<Q", arr.nbytes)
    return _head_writer(head, arr)


def _ternary_prepare(t: TernaryTensor) -> _Prepared:
    scale = _contig(t.w_q)
    packed = _contig(t.packed)
    if packed.dtype != np.uint8:
        raise WireError(f"TernaryTensor.packed must be uint8, got {packed.dtype}")
    head = _pack_meta(str(t.dtype), tuple(int(s) for s in t.shape)) \
        + _pack_array_meta(scale)
    mid = struct.pack("<Q", packed.size)
    if scale.nbytes <= _INLINE_BYTES:   # scalar / per-layer scales: tiny
        return _head_writer(head + scale.tobytes() + mid, packed)
    size = len(head) + scale.nbytes + len(mid) + packed.size

    def write(view: memoryview, off: int) -> int:
        end = off + len(head)
        view[off:end] = head
        end = _write_array_bytes(view, end, scale)
        view[end:end + len(mid)] = mid
        return _write_array_bytes(view, end + len(mid), packed)

    return (size, write)


def _downcast_prepare(t: "DowncastTensor") -> _Prepared:
    arr = _contig(t.data)
    dt = str(t.orig_dtype).encode("ascii")
    head = struct.pack("<B", len(dt)) + dt \
        + _pack_array_meta(arr) + struct.pack("<Q", arr.nbytes)
    return _head_writer(head, arr)


def _topk_delta_prepare(t: "TopKTensor") -> _Prepared:
    idx = _np(t.indices)
    if idx.dtype != np.uint32:
        raise WireError(f"TopKTensor.indices must be uint32, got {idx.dtype}")
    stream = _varint_pack(idx)
    values = _contig(t.values)
    head = _pack_meta(str(t.dtype), tuple(int(s) for s in t.shape)) \
        + struct.pack("<I", idx.size) + struct.pack("<Q", len(stream)) + stream \
        + _pack_array_meta(values) + struct.pack("<Q", values.nbytes)
    return _head_writer(head, values)


class _Reader:
    def __init__(self, buf: bytes, zero_copy: bool = False):
        self.buf = buf
        self.pos = 0
        # zero-copy mode: array payloads come back as numpy views aliasing
        # ``buf`` (read-only, no device transfer) — the streaming
        # aggregator's ingest path. Default returns jax arrays as before.
        self.zero_copy = zero_copy

    def arr(self, np_arr: np.ndarray):
        return np_arr if self.zero_copy else jnp.asarray(np_arr)

    def take(self, n: int) -> bytes:
        if n < 0 or self.pos + n > len(self.buf):
            raise WireError(
                f"truncated wire buffer: need {n} bytes at offset {self.pos}, "
                f"have {len(self.buf) - self.pos}"
            )
        out = self.buf[self.pos : self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self.take(8))[0]

    def meta(self) -> tuple[str, tuple]:
        dt = self.take(self.u8()).decode("ascii")
        ndim = self.u8()
        shape = struct.unpack(f"<{ndim}I", self.take(4 * ndim)) if ndim else ()
        return dt, tuple(shape)


def _resolve_dtype(dtype: str) -> np.dtype:
    try:
        return np.dtype(jnp.dtype(dtype))
    except TypeError as e:
        raise WireError(f"unknown dtype {dtype!r} in wire record") from e


def _decode_array(r: _Reader) -> jax.Array:
    dtype, shape = r.meta()
    data = r.take(r.u64())
    np_dt = _resolve_dtype(dtype)
    n = int(np.prod(shape)) if shape else 1
    if len(data) != n * np_dt.itemsize:
        raise WireError(
            f"record data length {len(data)} != {n}×{np_dt.itemsize} "
            f"for dtype={dtype} shape={shape}"
        )
    return r.arr(np.frombuffer(data, dtype=np_dt).reshape(shape))


# --------------------------------------------------------------------------
# Record bodies, one pair of pack/unpack per wire kind.
# --------------------------------------------------------------------------


def _raw_body(leaf) -> bytes:
    return _pack_arr(_np(leaf))


def _ternary_body(t: TernaryTensor) -> bytes:
    scale = _np(t.w_q)
    packed = _np(t.packed)
    if packed.dtype != np.uint8:
        raise WireError(f"TernaryTensor.packed must be uint8, got {packed.dtype}")
    parts = [
        _pack_meta(str(t.dtype), tuple(int(s) for s in t.shape)),
        _pack_array_meta(scale),
        scale.tobytes(),
        struct.pack("<Q", packed.size),
        packed.tobytes(),
    ]
    return b"".join(parts)


def _decode_ternary_body(r: _Reader) -> TernaryTensor:
    dtype, shape = r.meta()
    s_dtype, s_shape = r.meta()
    s_np = _resolve_dtype(s_dtype)
    s_n = int(np.prod(s_shape)) if s_shape else 1
    scale = np.frombuffer(r.take(s_n * s_np.itemsize), dtype=s_np).reshape(s_shape)
    packed = np.frombuffer(r.take(r.u64()), dtype=np.uint8)
    n = int(np.prod(shape)) if shape else 1
    if packed.size != (n + 3) // 4:
        raise WireError(
            f"packed size {packed.size} inconsistent with logical shape {shape}"
        )
    return TernaryTensor(
        packed=r.arr(packed), w_q=r.arr(scale),
        shape=tuple(shape), dtype=dtype,
    )


def _downcast_body(t: DowncastTensor) -> bytes:
    dt = str(t.orig_dtype).encode("ascii")
    return b"".join([struct.pack("<B", len(dt)), dt, _pack_arr(_np(t.data))])


def _decode_downcast_body(r: _Reader) -> DowncastTensor:
    orig = r.take(r.u8()).decode("ascii")
    _resolve_dtype(orig)  # validate before it reaches restore()
    return DowncastTensor(data=_decode_array(r), orig_dtype=orig)


def _topk_body(t: TopKTensor) -> bytes:
    idx = _np(t.indices)
    if idx.dtype != np.uint32:
        raise WireError(f"TopKTensor.indices must be uint32, got {idx.dtype}")
    parts = [
        _pack_meta(str(t.dtype), tuple(int(s) for s in t.shape)),
        _pack_arr(idx),
        _pack_arr(_np(t.values)),
    ]
    return b"".join(parts)


def _decode_topk_body(r: _Reader) -> TopKTensor:
    dtype, shape = r.meta()
    _resolve_dtype(dtype)
    indices = _decode_array(r)
    values = _decode_array(r)
    n = int(np.prod(shape)) if shape else 1
    if indices.shape != values.shape or indices.ndim != 1:
        raise WireError(
            f"topk indices/values shapes differ: {indices.shape} vs {values.shape}"
        )
    if indices.size and int(jnp.max(indices)) >= n:
        raise WireError(f"topk index out of range for logical shape {shape}")
    return TopKTensor(
        indices=indices, values=values, shape=tuple(shape), dtype=dtype
    )


# --------------------------------------------------------------------------
# TOPK_DELTA (v3): sorted u32 indices as LEB128 varint deltas.
# --------------------------------------------------------------------------


def _varint_pack(values: np.ndarray) -> bytes:
    """Ascending uint32 indices → LEB128 stream: first absolute, then gaps.

    Strictly ascending is the TopKTensor contract (unique sorted top-k
    indices) — violated input is rejected HERE rather than producing a
    stream no decoder will accept. Fully vectorized (server encode path).
    """
    if values.size == 0:
        return b""
    v = values.astype(np.uint64)
    if v.size > 1 and not np.all(values[1:] > values[:-1]):
        raise WireError("TopKTensor indices must be strictly ascending")
    d = np.empty(v.shape, np.uint64)
    d[0] = v[0]
    d[1:] = v[1:] - v[:-1]
    nbytes = np.ones(d.shape, np.int64)          # LEB128 length per gap
    for j in range(1, 6):                        # u32 gaps need ≤ 5 bytes
        nbytes += (d >> np.uint64(7 * j)) > 0
    offsets = np.concatenate([[0], np.cumsum(nbytes)])
    out = np.zeros(int(offsets[-1]), np.uint8)
    for j in range(int(nbytes.max())):
        mask = nbytes > j
        byte = ((d[mask] >> np.uint64(7 * j)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (nbytes[mask] - 1 > j).astype(np.uint8) << 7
        out[offsets[:-1][mask] + j] = byte | cont
    return out.tobytes()


def _varint_unpack(stream: bytes, k: int) -> np.ndarray:
    """LEB128 stream → k uint64 values (the gap sequence). Vectorized: the
    continuation bits delimit groups; ``np.add.reduceat`` folds each group's
    7-bit limbs — no per-index Python loop on the server ingest path."""
    b = np.frombuffer(stream, np.uint8)
    if k == 0:
        if b.size:
            raise WireError(f"{b.size} trailing bytes in empty varint stream")
        return np.zeros((0,), np.uint64)
    is_end = (b & 0x80) == 0
    if b.size == 0 or not is_end[-1]:
        raise WireError("unterminated varint in topk delta stream")
    if int(is_end.sum()) != k:
        raise WireError(
            f"varint stream carries {int(is_end.sum())} values, expected {k}"
        )
    starts = np.flatnonzero(np.concatenate([[True], is_end[:-1]]))
    gid = np.cumsum(np.concatenate([[0], is_end[:-1].astype(np.int64)]))
    pos = np.arange(b.size) - starts[gid]        # limb index within varint
    if int(pos.max()) > 4:                       # u32 gaps need ≤ 5 limbs
        raise WireError("varint overflows uint32 index range")
    limbs = (b & 0x7F).astype(np.uint64) << (7 * pos).astype(np.uint64)
    return np.add.reduceat(limbs, starts)


def _topk_delta_body(t: TopKTensor) -> bytes:
    idx = _np(t.indices)
    if idx.dtype != np.uint32:
        raise WireError(f"TopKTensor.indices must be uint32, got {idx.dtype}")
    stream = _varint_pack(idx)
    parts = [
        _pack_meta(str(t.dtype), tuple(int(s) for s in t.shape)),
        struct.pack("<I", idx.size),
        struct.pack("<Q", len(stream)),
        stream,
        _pack_arr(_np(t.values)),
    ]
    return b"".join(parts)


def _decode_topk_delta_body(r: _Reader) -> TopKTensor:
    dtype, shape = r.meta()
    _resolve_dtype(dtype)
    k = struct.unpack("<I", r.take(4))[0]
    stream = r.take(r.u64())
    n = int(np.prod(shape)) if shape else 1
    gaps = _varint_unpack(stream, k)
    if gaps.size > 1 and not np.all(gaps[1:] > 0):
        raise WireError("topk delta stream not strictly ascending")
    idx64 = np.cumsum(gaps)
    if idx64.size and (int(idx64[-1]) >= n or int(idx64[-1]) > 0xFFFFFFFF):
        # the explicit u32 bound matters when n itself exceeds u32 (huge
        # multi-dim leaves): astype(uint32) must never silently wrap.
        raise WireError(
            f"topk index {int(idx64[-1])} out of range for shape {shape}"
        )
    idx = idx64.astype(np.uint32)
    values = _decode_array(r)
    if _np(values).ndim != 1 or _np(values).shape != (k,):
        raise WireError(
            f"topk values shape {_np(values).shape} != index count {k}"
        )
    return TopKTensor(
        indices=r.arr(idx), values=values, shape=tuple(shape), dtype=dtype
    )


# --------------------------------------------------------------------------
# The record registry: kind byte ↔ wire-leaf class ↔ pack/unpack.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireRecord:
    kind: int
    name: str
    leaf_type: type | None          # None = RAW fallback for plain arrays
    pack: Callable[[Any], bytes]
    unpack: Callable[[_Reader], Any]
    min_version: int = WIRE_VERSION  # oldest wire version that may carry it
    encode: bool = True              # False = legacy: decoded forever, never
                                     # emitted (a newer record supersedes it)
    # streaming writer: size pre-pass + in-place emit (see module docstring).
    # None → fallback: the body is built once via ``pack`` and copied in.
    prepare: Callable[[Any], _Prepared] | None = None

    def prepared(self, leaf) -> _Prepared:
        if self.prepare is not None:
            return self.prepare(leaf)
        body = self.pack(leaf)   # legacy fallback: one build, one copy-in
        return (len(body), body)


_RECORDS: dict[int, WireRecord] = {}


def register_record(record: WireRecord) -> WireRecord:
    """Register a record kind (new codecs plug in here; see compression.py)."""
    if not 0 <= record.kind <= 0xFF:
        raise ValueError(f"record kind {record.kind} does not fit the u8 field")
    if record.kind in _RECORDS:
        raise ValueError(
            f"record kind {record.kind} already registered "
            f"as {_RECORDS[record.kind].name!r}"
        )
    _RECORDS[record.kind] = record
    return record


register_record(WireRecord(KIND_RAW, "RAW", None, _raw_body, _decode_array,
                           min_version=1, prepare=_raw_prepare))
register_record(WireRecord(KIND_TERNARY, "TERNARY", TernaryTensor,
                           _ternary_body, _decode_ternary_body, min_version=1,
                           prepare=_ternary_prepare))
register_record(WireRecord(KIND_DOWNCAST, "DOWNCAST", DowncastTensor,
                           _downcast_body, _decode_downcast_body,
                           min_version=2, prepare=_downcast_prepare))
# raw-u32-index top-k is legacy: stored v2 captures decode forever, but
# encoders emit the delta-varint record below instead.
register_record(WireRecord(KIND_TOPK, "TOPK", TopKTensor,
                           _topk_body, _decode_topk_body,
                           min_version=2, encode=False))
register_record(WireRecord(KIND_TOPK_DELTA, "TOPK_DELTA", TopKTensor,
                           _topk_delta_body, _decode_topk_delta_body,
                           min_version=3, prepare=_topk_delta_prepare))


def _leaf_types() -> tuple[type, ...]:
    # union of the record registry's leaf classes and the codec registry's
    # (so a codec registered without a wire record is SEEN as a leaf here
    # and _record_for_leaf can refuse it loudly instead of tree-flattening
    # through it and silently serializing its children as containers).
    own = {r.leaf_type for r in _RECORDS.values() if r.leaf_type is not None}
    return tuple(own | set(wire_leaf_types()))


def _record_for_leaf(leaf, codec_leaf_types: tuple[type, ...] | None = None) -> WireRecord:
    for rec in _RECORDS.values():
        if rec.encode and rec.leaf_type is not None and isinstance(leaf, rec.leaf_type):
            return rec
    if codec_leaf_types is None:
        codec_leaf_types = tuple(wire_leaf_types())
    if isinstance(leaf, codec_leaf_types):
        raise WireError(
            f"wire leaf {type(leaf).__name__} has a registered codec but no "
            f"record kind — call comm.wire.register_record for it"
        )
    return _RECORDS[KIND_RAW]


# --------------------------------------------------------------------------
# Single-tensor codec (used by TernaryTensor.to_bytes / from_bytes).
# --------------------------------------------------------------------------


def encode_tensor(t: TernaryTensor) -> bytes:
    """Serialize one TernaryTensor (header + single TERNARY record body,
    stamped v1 — the TERNARY body is unchanged since v1)."""
    body = _ternary_body(t)
    v = _RECORDS[KIND_TERNARY].min_version
    return _HEADER.pack(WIRE_MAGIC, v, 0, 1, zlib.crc32(body), len(body)) + body


def decode_tensor(data: bytes) -> TernaryTensor:
    body, _, _ = _check_header(data, expect_records=1)
    r = _Reader(body)
    t = _decode_ternary_body(r)
    if r.pos != len(body):
        raise WireError(f"{len(body) - r.pos} trailing bytes after tensor record")
    return t


# --------------------------------------------------------------------------
# Pytree path encoding (dicts + sequences).
# --------------------------------------------------------------------------


def _path_entries(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            if isinstance(p.key, str):
                out.append(f"d:{p.key}")
            elif isinstance(p.key, (int, np.integer)):
                out.append(f"k:{int(p.key)}")   # int dict key ≠ sequence index
            else:
                raise WireError(f"unsupported dict key type {type(p.key).__name__}")
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(f"i:{p.idx}")
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(f"d:{p.name}")
        else:  # pragma: no cover - exotic custom nodes
            raise WireError(f"unsupported pytree path entry {p!r}")
    return out


def _parse_entry(e: str) -> tuple[str, Any]:
    if e.startswith("d:"):
        return ("d", e[2:])
    if e.startswith("k:"):
        return ("k", _parse_int(e[2:]))
    if e.startswith("i:"):
        return ("i", _parse_int(e[2:]))
    raise WireError(f"bad path entry {e!r}")


def _parse_int(s: str) -> int:
    try:
        return int(s)
    except ValueError as e:
        raise WireError(f"bad integer path entry {s!r}") from e


def _insert(root: dict, entries: list[str], leaf) -> None:
    node = root
    for i, e in enumerate(entries):
        key = _parse_entry(e)
        if i == len(entries) - 1:
            if key in node and isinstance(node[key], dict):
                raise WireError(f"path collision at {e!r}: leaf under container")
            node[key] = leaf
        else:
            nxt = node.setdefault(key, {})
            if not isinstance(nxt, dict):
                raise WireError(f"path collision at {e!r}: container under leaf")
            node = nxt


def _containerize(node):
    """Rebuild containers from typed keys: ('i', n) nodes → lists,
    ('d', s)/('k', n) nodes → dicts (string / int keys)."""
    if not isinstance(node, dict):
        return node
    tags = {t for t, _ in node}
    if "i" in tags:
        if tags != {"i"}:
            raise WireError("mixed sequence and dict entries at one node")
        idxs = sorted(k for _, k in node)
        if idxs != list(range(len(idxs))):
            raise WireError(f"non-contiguous sequence indices {idxs}")
        return [_containerize(node[("i", i)]) for i in idxs]
    return {k: _containerize(v) for (_, k), v in node.items()}


# --------------------------------------------------------------------------
# Update codec.
# --------------------------------------------------------------------------


def encode_update(tree: Pytree) -> bytes:
    """Serialize an update pytree into one framed, CRC-protected buffer.

    STREAMING: pass 1 prepares every record (exact body size + writer), then
    ONE buffer of the final length is allocated and each record writes its
    framing and array payloads straight into it — no per-record ``bytes``
    concatenation (output is byte-identical to the old join-based builder).

    The header is stamped with the LOWEST wire version able to carry the
    payload's record kinds (v1 for RAW/TERNARY-only traffic — byte-identical
    to what a v1 encoder produced, so old decoders stay compatible; v2 once
    a downcast/top-k record appears)."""
    lt = _leaf_types()  # hoisted: rebuilt per call, not per pytree node
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, lt)
    )[0]
    version = min(SUPPORTED_VERSIONS)
    codec_lt = tuple(wire_leaf_types())
    prepared: list = []  # (record prefix: len+path+kind, body bytes | writer)
    total = _HEADER.size
    for path, leaf in leaves:
        p = _PATH_SEP.join(_path_entries(path)).encode("utf-8")
        rec = _record_for_leaf(leaf, codec_lt)
        version = max(version, rec.min_version)
        size, emit = rec.prepared(leaf)
        pfx = struct.pack("<H", len(p)) + p + struct.pack("<B", rec.kind)
        total += len(pfx) + size
        prepared.append((pfx, emit))
    buf = bytearray(total)
    view = memoryview(buf)
    off = _HEADER.size
    for pfx, emit in prepared:
        end = off + len(pfx)
        view[off:end] = pfx
        off = end
        if type(emit) is bytes:       # small record: body is the bytes
            end = off + len(emit)
            view[off:end] = emit
            off = end
        else:                         # large record: memcpy writer
            off = emit(view, off)
    if off != total:  # pragma: no cover - writer/size contract violation
        raise WireError(
            f"record writer emitted {off - _HEADER.size} bytes, "
            f"sized {total - _HEADER.size}"
        )
    _HEADER.pack_into(
        buf, 0, WIRE_MAGIC, version, 0, len(prepared),
        zlib.crc32(view[_HEADER.size:]), total - _HEADER.size,
    )
    return bytes(buf)


def _check_header(
    data: bytes, expect_records: int | None = None
) -> tuple[bytes, int, int]:
    """Validate framing and integrity; returns (record section, n_records,
    buffer wire version)."""
    if len(data) < _HEADER.size:
        raise WireError(f"buffer too short for header: {len(data)} B")
    magic, version, _flags, n_records, crc, body_len = _HEADER.unpack_from(data)
    if magic != WIRE_MAGIC:
        raise WireError(f"bad magic {magic!r} (expected {WIRE_MAGIC!r})")
    if version not in SUPPORTED_VERSIONS:
        raise WireError(
            f"wire version {version} not supported (have {SUPPORTED_VERSIONS})"
        )
    body = data[_HEADER.size :]
    if len(body) != body_len:
        raise WireError(f"body length {len(body)} != header body_len {body_len}")
    if zlib.crc32(body) != crc:
        raise WireError("CRC32 mismatch: payload corrupted in transit")
    if expect_records is not None and n_records != expect_records:
        raise WireError(f"expected {expect_records} records, header says {n_records}")
    return body, n_records, version


def decode_update(data: bytes) -> Pytree:
    """Inverse of ``encode_update``: rebuild the pytree bit-exactly.

    Dict containers round-trip as dicts (string and int keys preserved);
    list/tuple containers come back as lists (index paths carry no
    tuple-vs-list distinction), and attr-style custom nodes (GetAttrKey
    paths) come back as plain dicts keyed by attribute name — leaves are
    always bit-exact, containers normalize to dict/list. A single-leaf
    tree with an empty path decodes to the bare leaf.
    """
    try:
        return _decode_update(data)
    except WireError:
        raise
    except (struct.error, ValueError, TypeError, OverflowError,
            UnicodeDecodeError) as e:
        # any parse failure surfaces as WireError — never a stray exception
        raise WireError(f"malformed wire buffer: {e}") from e


def _decode_records(data: bytes, *, zero_copy: bool = False) -> list[tuple[str, Any]]:
    body, n_records, version = _check_header(data)
    r = _Reader(body, zero_copy=zero_copy)
    out: list[tuple[str, Any]] = []
    for _ in range(n_records):
        path = r.take(r.u16()).decode("utf-8")
        kind = r.u8()
        rec = _RECORDS.get(kind)
        if rec is None:
            raise WireError(f"unknown record kind {kind}")
        if version < rec.min_version:
            raise WireError(
                f"record kind {rec.name} requires wire v{rec.min_version}, "
                f"buffer is v{version}"
            )
        out.append((path, rec.unpack(r)))
    if r.pos != len(body):
        raise WireError(f"{len(body) - r.pos} trailing bytes after last record")
    return out


def decode_update_leaves(
    data: bytes, *, zero_copy: bool = False
) -> list[tuple[str, Any]]:
    """Batched record decode: the flat (path, leaf) list in record order,
    WITHOUT rebuilding containers — the streaming aggregator consumes records
    straight off the buffer. With ``zero_copy=True``, array payloads are
    read-only numpy views aliasing ``data`` (no copy, no device transfer);
    ``tree_from_records`` rebuilds the pytree when one is needed."""
    try:
        return _decode_records(data, zero_copy=zero_copy)
    except WireError:
        raise
    except (struct.error, ValueError, TypeError, OverflowError,
            UnicodeDecodeError) as e:
        raise WireError(f"malformed wire buffer: {e}") from e


def tree_leaf_paths(tree: Pytree) -> list[tuple[str, Any]]:
    """Flatten a pytree to (wire path, leaf) pairs — the exact path strings
    ``encode_update`` stamps on records, so a decoded update's record paths
    can be structure-checked against a reference tree without re-encoding
    it (the defense gate's treedef match)."""
    lt = _leaf_types()
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, lt)
    )[0]
    return [(_PATH_SEP.join(_path_entries(p)), leaf) for p, leaf in leaves]


def tree_from_records(pairs: list[tuple[str, Any]]) -> Pytree:
    """Rebuild the pytree from (path, leaf) record pairs (the inverse of the
    flatten ``encode_update`` performed; same container normalization as
    ``decode_update``)."""
    root: dict = {}
    bare_leaf = None
    for path, leaf in pairs:
        if not path:
            if len(pairs) != 1:
                raise WireError("empty path in multi-record update")
            bare_leaf = leaf
        else:
            _insert(root, path.split(_PATH_SEP), leaf)
    if bare_leaf is not None:
        return bare_leaf
    return _containerize(root)


def _decode_update(data: bytes) -> Pytree:
    return tree_from_records(_decode_records(data))


# --------------------------------------------------------------------------
# Incremental / chunked reading (the transport boundary).
# --------------------------------------------------------------------------


# A wire buffer larger than this is a corrupted or hostile length field, not
# a model update — even a full-size fp32 LLM checkpoint stays far below it.
MAX_BODY_BYTES = 1 << 34  # 16 GiB


class StreamDecoder:
    """Incremental wire-buffer framing over an arbitrary chunk stream.

    ``decode_update`` assumes it holds one COMPLETE buffer; a socket hands
    you partial reads. ``feed(chunk)`` accumulates bytes and returns every
    complete wire buffer the stream has finished so far (possibly several
    per chunk, possibly none) — each returned ``bytes`` object is exactly
    one ``encode_update`` output, ready for ``decode_update`` /
    ``decode_update_leaves`` (which re-verify the CRC; this class only
    frames and fail-fasts on the header).

    Failure discipline: a bad magic, unsupported version, or oversized
    ``body_len`` raises ``WireError`` as soon as the 24 header bytes are
    in — the reader never waits for a body it already knows is garbage,
    so a corrupted length field cannot make the caller hang on a recv
    that will never complete. ``close()`` (call at EOF/disconnect) raises
    ``WireError`` if bytes of an unfinished buffer are pending — a torn
    stream surfaces as an error, never as a silent short read.
    """

    def __init__(self, *, max_body_bytes: int = MAX_BODY_BYTES):
        self._buf = bytearray()
        self._need: int | None = None   # total frame length once header known
        self._max_body = int(max_body_bytes)
        self.frames_out = 0
        self.bytes_in = 0

    def _header_check(self) -> int:
        """Validate the buffered header; returns the full frame length."""
        magic, version, _flags, _n, _crc, body_len = _HEADER.unpack_from(
            self._buf
        )
        if magic != WIRE_MAGIC:
            raise WireError(f"bad magic {magic!r} in stream (expected {WIRE_MAGIC!r})")
        if version not in SUPPORTED_VERSIONS:
            raise WireError(
                f"wire version {version} not supported (have {SUPPORTED_VERSIONS})"
            )
        if body_len > self._max_body:
            raise WireError(
                f"body_len {body_len} exceeds stream cap {self._max_body} — "
                "corrupted length field"
            )
        return _HEADER.size + body_len

    def feed(self, chunk: bytes) -> list[bytes]:
        """Absorb one chunk (any size, including empty); return the wire
        buffers completed by it, in stream order."""
        self._buf += chunk
        self.bytes_in += len(chunk)
        out: list[bytes] = []
        while True:
            if self._need is None:
                if len(self._buf) < _HEADER.size:
                    break
                self._need = self._header_check()
            if len(self._buf) < self._need:
                break
            out.append(bytes(self._buf[: self._need]))
            del self._buf[: self._need]
            self._need = None
        self.frames_out += len(out)
        return out

    @property
    def pending_bytes(self) -> int:
        """Bytes buffered toward an incomplete wire buffer."""
        return len(self._buf)

    def close(self) -> None:
        """Declare EOF: a partially-received buffer is a truncation error."""
        if self._buf:
            need = "?" if self._need is None else str(self._need)
            raise WireError(
                f"stream ended mid-buffer: {len(self._buf)} bytes pending "
                f"of {need}"
            )


def decode_update_chunks(chunks) -> Pytree:
    """Decode ONE update delivered as an iterable of byte chunks (the
    chunked-reader convenience over ``StreamDecoder``): raises ``WireError``
    on truncation, trailing garbage, or more than one buffer in the
    stream — never hangs, never returns a short read."""
    dec = StreamDecoder()
    frames: list[bytes] = []
    for chunk in chunks:
        frames.extend(dec.feed(chunk))
        if len(frames) > 1:
            raise WireError("multiple wire buffers in a single-update stream")
    dec.close()
    if len(frames) != 1:
        raise WireError("stream ended before a complete wire buffer arrived")
    return decode_update(frames[0])


def update_nbytes(tree: Pytree) -> int:
    """Measured wire size of a pytree: ``len(encode_update(tree))``."""
    return len(encode_update(tree))
