"""Byte-level wire codec for federated update payloads.

An *update* is a pytree of leaves (raw arrays and/or ``TernaryTensor``)
as produced by ``core.tfedavg.client_update_payload`` /
``server_requantize``. ``encode_update`` serializes it into one
self-describing buffer; ``decode_update`` rebuilds the pytree bit-exactly.
All byte accounting in the repo is ``len(encode_update(tree))`` — measured
from the actual buffer, never estimated.

Buffer layout (all little-endian):

    HEADER (24 B):
      magic      4s   b"TFW1"
      version    u16  WIRE_VERSION
      flags      u16  reserved (0)
      n_records  u32  number of leaf records
      crc32      u32  zlib.crc32 of the record section
      body_len   u64  length of the record section in bytes

    RECORD (one per pytree leaf, in tree_flatten order):
      path_len   u16  + path bytes (utf-8; entries joined by "\\x1f",
                        each entry "d:<key>" for dict keys or
                        "i:<index>" for sequence indices)
      kind       u8   0 = RAW, 1 = TERNARY
      RAW:
        dtype_len u8 + dtype ascii, ndim u8, dims u32×ndim,
        data_len  u64 + raw little-endian array bytes
      TERNARY (a ``TernaryTensor``):
        logical dtype/ndim/dims as above (the unpacked tensor),
        scale   dtype/ndim/dims + scale bytes (w_q, length derived),
        packed_len u64 + packed 2-bit code bytes (4 codes/byte,
        ``kernels.pack2bit`` layout)

The CRC covers the whole record section; ``decode_update`` raises
``WireError`` on magic/version/CRC mismatch or truncation, so a corrupted
or torn transfer never silently yields wrong weights.
"""

from __future__ import annotations

import struct
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ternary import TernaryTensor

Pytree = Any

WIRE_MAGIC = b"TFW1"
WIRE_VERSION = 1

_HEADER = struct.Struct("<4sHHIIQ")   # magic, version, flags, n_records, crc, body_len
_KIND_RAW = 0
_KIND_TERNARY = 1
_PATH_SEP = "\x1f"


class WireError(ValueError):
    """Malformed / corrupted / incompatible wire buffer."""


# --------------------------------------------------------------------------
# Low-level field packers.
# --------------------------------------------------------------------------


def _np(leaf) -> np.ndarray:
    return np.asarray(leaf)


def _pack_array_meta(arr: np.ndarray) -> bytes:
    return _pack_meta(arr.dtype.name, arr.shape)


def _pack_meta(dtype: str, shape: tuple) -> bytes:
    dt = dtype.encode("ascii")
    out = [struct.pack("<B", len(dt)), dt, struct.pack("<B", len(shape))]
    out.append(struct.pack(f"<{len(shape)}I", *shape) if shape else b"")
    return b"".join(out)


class _Reader:
    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if self.pos + n > len(self.buf):
            raise WireError(
                f"truncated wire buffer: need {n} bytes at offset {self.pos}, "
                f"have {len(self.buf) - self.pos}"
            )
        out = self.buf[self.pos : self.pos + n]
        self.pos += n
        return out

    def u8(self) -> int:
        return self.take(1)[0]

    def u16(self) -> int:
        return struct.unpack("<H", self.take(2))[0]

    def u64(self) -> int:
        return struct.unpack("<Q", self.take(8))[0]

    def meta(self) -> tuple[str, tuple]:
        dt = self.take(self.u8()).decode("ascii")
        ndim = self.u8()
        shape = struct.unpack(f"<{ndim}I", self.take(4 * ndim)) if ndim else ()
        return dt, tuple(shape)


def _decode_array(r: _Reader) -> jax.Array:
    dtype, shape = r.meta()
    data = r.take(r.u64())
    np_dt = np.dtype(jnp.dtype(dtype))
    n = int(np.prod(shape)) if shape else 1
    if len(data) != n * np_dt.itemsize:
        raise WireError(
            f"record data length {len(data)} != {n}×{np_dt.itemsize} "
            f"for dtype={dtype} shape={shape}"
        )
    arr = np.frombuffer(data, dtype=np_dt).reshape(shape)
    return jnp.asarray(arr)


# --------------------------------------------------------------------------
# Single-tensor codec (used by TernaryTensor.to_bytes / from_bytes).
# --------------------------------------------------------------------------


def _ternary_body(t: TernaryTensor) -> bytes:
    scale = _np(t.w_q)
    packed = _np(t.packed)
    if packed.dtype != np.uint8:
        raise WireError(f"TernaryTensor.packed must be uint8, got {packed.dtype}")
    parts = [
        _pack_meta(str(t.dtype), tuple(int(s) for s in t.shape)),
        _pack_array_meta(scale),
        scale.tobytes(),
        struct.pack("<Q", packed.size),
        packed.tobytes(),
    ]
    return b"".join(parts)


def _decode_ternary_body(r: _Reader) -> TernaryTensor:
    dtype, shape = r.meta()
    s_dtype, s_shape = r.meta()
    s_np = np.dtype(jnp.dtype(s_dtype))
    s_n = int(np.prod(s_shape)) if s_shape else 1
    scale = np.frombuffer(r.take(s_n * s_np.itemsize), dtype=s_np).reshape(s_shape)
    packed = np.frombuffer(r.take(r.u64()), dtype=np.uint8)
    n = int(np.prod(shape)) if shape else 1
    if packed.size != (n + 3) // 4:
        raise WireError(
            f"packed size {packed.size} inconsistent with logical shape {shape}"
        )
    return TernaryTensor(
        packed=jnp.asarray(packed), w_q=jnp.asarray(scale),
        shape=tuple(shape), dtype=dtype,
    )


def encode_tensor(t: TernaryTensor) -> bytes:
    """Serialize one TernaryTensor (header + single TERNARY record body)."""
    body = _ternary_body(t)
    return _HEADER.pack(WIRE_MAGIC, WIRE_VERSION, 0, 1, zlib.crc32(body), len(body)) + body


def decode_tensor(data: bytes) -> TernaryTensor:
    body, _ = _check_header(data, expect_records=1)
    r = _Reader(body)
    t = _decode_ternary_body(r)
    if r.pos != len(body):
        raise WireError(f"{len(body) - r.pos} trailing bytes after tensor record")
    return t


# --------------------------------------------------------------------------
# Pytree path encoding (dicts + sequences).
# --------------------------------------------------------------------------


def _path_entries(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            if isinstance(p.key, str):
                out.append(f"d:{p.key}")
            elif isinstance(p.key, (int, np.integer)):
                out.append(f"k:{int(p.key)}")   # int dict key ≠ sequence index
            else:
                raise WireError(f"unsupported dict key type {type(p.key).__name__}")
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(f"i:{p.idx}")
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(f"d:{p.name}")
        else:  # pragma: no cover - exotic custom nodes
            raise WireError(f"unsupported pytree path entry {p!r}")
    return out


def _parse_entry(e: str) -> tuple[str, Any]:
    if e.startswith("d:"):
        return ("d", e[2:])
    if e.startswith("k:"):
        return ("k", int(e[2:]))
    if e.startswith("i:"):
        return ("i", int(e[2:]))
    raise WireError(f"bad path entry {e!r}")


def _insert(root: dict, entries: list[str], leaf) -> None:
    node = root
    for i, e in enumerate(entries):
        key = _parse_entry(e)
        if i == len(entries) - 1:
            node[key] = leaf
        else:
            node = node.setdefault(key, {})


def _containerize(node):
    """Rebuild containers from typed keys: ('i', n) nodes → lists,
    ('d', s)/('k', n) nodes → dicts (string / int keys)."""
    if not isinstance(node, dict):
        return node
    tags = {t for t, _ in node}
    if "i" in tags:
        if tags != {"i"}:
            raise WireError("mixed sequence and dict entries at one node")
        idxs = sorted(k for _, k in node)
        if idxs != list(range(len(idxs))):
            raise WireError(f"non-contiguous sequence indices {idxs}")
        return [_containerize(node[("i", i)]) for i in idxs]
    return {k: _containerize(v) for (_, k), v in node.items()}


# --------------------------------------------------------------------------
# Update codec.
# --------------------------------------------------------------------------


def encode_update(tree: Pytree) -> bytes:
    """Serialize an update pytree into one framed, CRC-protected buffer."""
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, TernaryTensor)
    )[0]
    records = []
    for path, leaf in leaves:
        p = _PATH_SEP.join(_path_entries(path)).encode("utf-8")
        rec = [struct.pack("<H", len(p)), p]
        if isinstance(leaf, TernaryTensor):
            rec.append(struct.pack("<B", _KIND_TERNARY))
            rec.append(_ternary_body(leaf))
        else:
            arr = _np(leaf)
            rec.append(struct.pack("<B", _KIND_RAW))
            rec.append(_pack_array_meta(arr))
            rec.append(struct.pack("<Q", arr.nbytes))
            rec.append(arr.tobytes())
        records.append(b"".join(rec))
    body = b"".join(records)
    header = _HEADER.pack(
        WIRE_MAGIC, WIRE_VERSION, 0, len(records), zlib.crc32(body), len(body)
    )
    return header + body


def _check_header(data: bytes, expect_records: int | None = None) -> tuple[bytes, int]:
    """Validate framing and integrity; returns (record section, n_records)."""
    if len(data) < _HEADER.size:
        raise WireError(f"buffer too short for header: {len(data)} B")
    magic, version, _flags, n_records, crc, body_len = _HEADER.unpack_from(data)
    if magic != WIRE_MAGIC:
        raise WireError(f"bad magic {magic!r} (expected {WIRE_MAGIC!r})")
    if version != WIRE_VERSION:
        raise WireError(f"wire version {version} not supported (have {WIRE_VERSION})")
    body = data[_HEADER.size :]
    if len(body) != body_len:
        raise WireError(f"body length {len(body)} != header body_len {body_len}")
    if zlib.crc32(body) != crc:
        raise WireError("CRC32 mismatch: payload corrupted in transit")
    if expect_records is not None and n_records != expect_records:
        raise WireError(f"expected {expect_records} records, header says {n_records}")
    return body, n_records


def decode_update(data: bytes) -> Pytree:
    """Inverse of ``encode_update``: rebuild the pytree bit-exactly.

    Dict containers round-trip as dicts (string and int keys preserved);
    list/tuple containers come back as lists (index paths carry no
    tuple-vs-list distinction), and attr-style custom nodes (GetAttrKey
    paths) come back as plain dicts keyed by attribute name — leaves are
    always bit-exact, containers normalize to dict/list. A single-leaf
    tree with an empty path decodes to the bare leaf.
    """
    body, n_records = _check_header(data)
    r = _Reader(body)
    root: dict = {}
    bare_leaf = None
    for _ in range(n_records):
        path = r.take(r.u16()).decode("utf-8")
        kind = r.u8()
        if kind == _KIND_TERNARY:
            leaf = _decode_ternary_body(r)
        elif kind == _KIND_RAW:
            leaf = _decode_array(r)
        else:
            raise WireError(f"unknown record kind {kind}")
        if not path:
            if n_records != 1:
                raise WireError("empty path in multi-record update")
            bare_leaf = leaf
        else:
            _insert(root, path.split(_PATH_SEP), leaf)
    if r.pos != len(body):
        raise WireError(f"{len(body) - r.pos} trailing bytes after last record")
    if bare_leaf is not None:
        return bare_leaf
    return _containerize(root)


def update_nbytes(tree: Pytree) -> int:
    """Measured wire size of a pytree: ``len(encode_update(tree))``."""
    return len(encode_update(tree))
