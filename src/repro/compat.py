"""JAX cross-version shims (0.4.x ↔ ≥0.5 API drift).

The mesh/shard_map surface moved between JAX releases: ≥0.5 exposes
``jax.shard_map(..., axis_names=..., check_vma=...)`` and the
``jax.set_mesh`` context, while 0.4.x has
``jax.experimental.shard_map.shard_map(..., auto=..., check_rep=...)`` and
the legacy ``with mesh:`` resource context. Every call site in this repo
(and its tests) goes through these wrappers so the same code runs on both.

  - ``shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
    check_vma=False)`` — the ≥0.5 calling convention. On 0.4.x,
    ``axis_names`` translates to ``auto`` (the complement over the mesh
    axes), ``check_vma`` to ``check_rep``, and a ``None`` mesh falls back
    to the mesh installed by ``set_mesh``.
  - ``set_mesh(mesh)`` — context manager; delegates to ``jax.set_mesh``
    when present, else records the active mesh for ``shard_map`` and
    enters the legacy mesh resource context.
  - ``cost_analysis(compiled)`` — ``Compiled.cost_analysis()`` returned a
    one-element list on 0.4.x and a dict on ≥0.5; always returns the dict.
"""

from __future__ import annotations

import contextlib

import jax

_ACTIVE_MESH: list = []  # stack of meshes installed by the 0.4.x set_mesh


def _fallback_mesh(mesh):
    if mesh is not None:
        return mesh
    if _ACTIVE_MESH:
        return _ACTIVE_MESH[-1]
    raise ValueError(
        "shard_map needs a mesh: pass mesh=... or enter repro.compat.set_mesh"
    )


if hasattr(jax, "shard_map"):  # ≥ 0.5: the new API, passed through

    def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
                  check_vma=False):
        kw = dict(in_specs=in_specs, out_specs=out_specs, check_vma=check_vma)
        if mesh is not None:
            kw["mesh"] = mesh
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)

else:  # 0.4.x: experimental shard_map with auto/check_rep spelling
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh=None, in_specs, out_specs, axis_names=None,
                  check_vma=False):
        m = _fallback_mesh(mesh)
        kw = dict(mesh=m, in_specs=in_specs, out_specs=out_specs,
                  check_rep=bool(check_vma))
        if axis_names is not None:
            auto = frozenset(m.axis_names) - set(axis_names)
            if auto:
                kw["auto"] = auto
        return _legacy_shard_map(f, **kw)


@contextlib.contextmanager
def set_mesh(mesh):
    """``with set_mesh(mesh):`` — the ≥0.5 ``jax.set_mesh`` everywhere."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    _ACTIVE_MESH.append(mesh)
    try:
        with mesh:  # legacy resource-env context (harmless under jit)
            yield mesh
    finally:
        _ACTIVE_MESH.pop()


def axis_size(name) -> int:
    """``jax.lax.axis_size`` (≥0.5); on 0.4.x the constant-folded psum(1)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every JAX version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)
