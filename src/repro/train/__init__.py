"""Training substrate: distributed train-step factory, checkpointing,
fault tolerance / elasticity helpers."""

from repro.train.trainer import TrainState, TrainerConfig, make_train_step, init_train_state
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.train.fault import retrying, elastic_reshard

__all__ = [
    "TrainState", "TrainerConfig", "make_train_step", "init_train_state",
    "save_checkpoint", "restore_checkpoint", "latest_step",
    "retrying", "elastic_reshard",
]
