"""Distributed train-step factory.

The paper-faithful QAT path: the loss is evaluated on FTTQ-quantized params
(clients train the quantized network — Alg. 1); latent full-precision params
and the per-layer trained factors w_q update from STE gradients.

Distribution:
  - single-pod mesh ("data","model"): plain jit + GSPMD (FSDP/TP/EP per
    parallel.sharding).
  - multi-pod mesh ("pod","data","model") with pod_compression=True: the
    step is shard_map'ed MANUAL over "pod" (auto over "data"/"model");
    per-pod gradients are synchronized with the ternary-compressed
    all-gather collective (parallel.collectives) + error feedback — the
    T-FedAvg wire protocol at datacenter cadence. With
    pod_compression=False, params are replicated over "pod" and GSPMD emits
    a standard (exact) cross-pod all-reduce — the FedAvg-equivalent baseline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import fttq
from repro.models import transformer as tfm
from repro.optim import Optimizer, apply_updates, clip_by_global_norm
from repro.parallel.collectives import ternary_allreduce_tree
from repro.parallel.sharding import logical_batch_axes, param_specs

Pytree = Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    qat: bool = True                     # train the quantized network (FTTQ)
    fttq: fttq.FTTQConfig = dataclasses.field(default_factory=fttq.FTTQConfig)
    grad_clip: float = 1.0
    wq_lr: float = 0.05
    pod_compression: bool = True         # ternary cross-pod grad sync
    error_feedback: bool = True
    microbatches: int = 1                # gradient-accumulation chunks


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Pytree
    wq: Pytree
    opt_state: Pytree
    residuals: Pytree | None
    step: jax.Array


def init_train_state(
    model_cfg: tfm.ModelConfig,
    tcfg: TrainerConfig,
    optimizer: Optimizer,
    key: jax.Array,
    *,
    n_pods: int = 1,
) -> TrainState:
    params = tfm.init_params(model_cfg, key)
    wq = fttq.init_wq_tree(params, tcfg.fttq) if tcfg.qat else None
    opt_state = optimizer.init(params)
    residuals = None
    if tcfg.pod_compression and n_pods > 1 and tcfg.error_feedback:
        # per-pod error-feedback residuals, stacked on a leading pod axis.
        residuals = jax.tree_util.tree_map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params
        )
    return TrainState(
        params=params, wq=wq, opt_state=opt_state, residuals=residuals,
        step=jnp.zeros((), jnp.int32),
    )


def _loss(model_cfg, tcfg, params, wq, batch):
    if tcfg.qat:
        qparams = fttq.quantize_tree(params, wq, tcfg.fttq)
    else:
        qparams = params
    loss, metrics = tfm.loss_fn(model_cfg, qparams, batch)
    return loss, metrics


def _apply_grads(tcfg, optimizer, state: TrainState, grads, g_wq, loss, metrics,
                 residuals=None):
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    updates, opt_state = optimizer.update(grads, state.opt_state, state.params)
    params = apply_updates(state.params, updates)
    if tcfg.qat:
        def upd_wq(w, g, p):
            if w is None:
                return None
            # float(p.size): stacked expert weights exceed int32 (>2^31
            # elements) and an int literal would overflow jit arg parsing.
            return (w - tcfg.wq_lr * g / float(p.size)).astype(w.dtype)

        wq = jax.tree_util.tree_map(
            upd_wq, state.wq, g_wq, state.params, is_leaf=lambda x: x is None
        )
    else:
        wq = state.wq
    new_state = TrainState(
        params=params, wq=wq, opt_state=opt_state,
        residuals=residuals if residuals is not None else state.residuals,
        step=state.step + 1,
    )
    out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
    return new_state, out_metrics


def make_train_step(
    model_cfg: tfm.ModelConfig,
    tcfg: TrainerConfig,
    optimizer: Optimizer,
    mesh=None,
):
    """Returns step(state, batch) → (state, metrics). jit it with the
    shardings from launch.dryrun / launch.train."""

    multi_pod = mesh is not None and "pod" in mesh.axis_names
    compressed = multi_pod and tcfg.pod_compression
    # batch mesh axes visible to the microbatch reshape. In the compressed
    # path the step body runs inside a shard_map MANUAL over "pod", so only
    # "data" remains an auto axis there.
    if mesh is None:
        mb_axes: tuple = ()
    elif compressed:
        mb_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
    else:
        mb_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def _constrain_mb(x):
        if not mb_axes or x.ndim < 2:
            return x
        u = jax.sharding.PartitionSpec.UNCONSTRAINED
        spec = P(None, mb_axes, *([u] * (x.ndim - 2)))
        return jax.lax.with_sharding_constraint(x, spec)

    def grads_of(state: TrainState, batch):
        if tcfg.qat:
            (loss, metrics), (g_p, g_w) = jax.value_and_grad(
                lambda p, w: _loss(model_cfg, tcfg, p, w, batch),
                argnums=(0, 1), has_aux=True,
            )(state.params, state.wq)
        else:
            (loss, metrics), g_p = jax.value_and_grad(
                lambda p: _loss(model_cfg, tcfg, p, None, batch), has_aux=True
            )(state.params)
            g_w = None
        return loss, metrics, g_p, g_w

    def local_grads(state: TrainState, batch):
        """Microbatched gradient accumulation: batch (B, …) is processed as
        ``microbatches`` sequential chunks (lax.scan), grads averaged. Keeps
        live activations at 1/microbatches — the standard way the 4k-train
        cells fit HBM with remat (DESIGN.md §4)."""
        n_micro = tcfg.microbatches
        if n_micro <= 1:
            return grads_of(state, batch)

        def split(x):
            b = x.shape[0]
            return _constrain_mb(x.reshape(n_micro, b // n_micro, *x.shape[1:]))

        mb = jax.tree_util.tree_map(split, batch)

        def body(acc, mbatch):
            loss, metrics, g_p, g_w = grads_of(state, mbatch)
            a_l, a_m, a_p, a_w = acc
            add = lambda a, g: a + g.astype(a.dtype) / n_micro
            acc = (
                a_l + loss / n_micro,
                jax.tree_util.tree_map(lambda a, g: a + g / n_micro, a_m, metrics),
                jax.tree_util.tree_map(add, a_p, g_p),
                jax.tree_util.tree_map(
                    lambda a, g: None if a is None else a + g / n_micro,
                    a_w, g_w, is_leaf=lambda x: x is None,
                ) if g_w is not None else None,
            )
            return acc, None

        l0 = jnp.zeros((), jnp.float32)
        m0 = {"ce": jnp.zeros((), jnp.float32), "aux": jnp.zeros((), jnp.float32)}
        p0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        w0 = (
            jax.tree_util.tree_map(
                lambda w: None if w is None else jnp.zeros(w.shape, jnp.float32),
                state.wq, is_leaf=lambda x: x is None,
            )
            if tcfg.qat else None
        )
        (loss, metrics, g_p, g_w), _ = jax.lax.scan(body, (l0, m0, p0, w0), mb)
        return loss, metrics, g_p, g_w

    if not compressed:
        def step(state: TrainState, batch):
            loss, metrics, g_p, g_w = local_grads(state, batch)
            # cross-pod sync (if any) is GSPMD's exact all-reduce (baseline).
            return _apply_grads(tcfg, optimizer, state, g_p, g_w, loss, metrics)

        return step

    # ---- compressed multi-pod path --------------------------------------
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
    batch_axes = P("pod")

    def per_pod_step(state: TrainState, residuals, batch):
        # residuals arrive with a leading length-1 pod-block dim.
        residuals = jax.tree_util.tree_map(lambda r: r[0], residuals)
        loss, metrics, g_p, g_w = local_grads(state, batch)
        g_p, new_res = ternary_allreduce_tree(
            g_p, "pod", cfg=tcfg.fttq, residuals=residuals,
            error_feedback=tcfg.error_feedback,
        )
        if g_w is not None:
            g_w = jax.tree_util.tree_map(
                lambda g: None if g is None else jax.lax.pmean(g, "pod"),
                g_w, is_leaf=lambda x: x is None,
            )
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree_util.tree_map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        new_state, out_metrics = _apply_grads(
            tcfg, optimizer, state, g_p, g_w, loss, metrics
        )
        new_res = jax.tree_util.tree_map(lambda r: r[None], new_res)
        return new_state, new_res, out_metrics

    def step(state: TrainState, batch):
        residuals = state.residuals
        state = dataclasses.replace(state, residuals=None)
        batch_specs = jax.tree_util.tree_map(
            lambda _: P("pod"), batch
        )
        state_specs = jax.tree_util.tree_map(lambda _: P(), state)
        res_specs = jax.tree_util.tree_map(lambda _: P("pod"), residuals)
        new_state, new_res, metrics = shard_map(
            per_pod_step,
            mesh=mesh,
            in_specs=(state_specs, res_specs, batch_specs),
            out_specs=(
                jax.tree_util.tree_map(lambda _: P(), state),
                res_specs,
                jax.tree_util.tree_map(lambda _: P(), {"loss": 0.0, "grad_norm": 0.0,
                                                       "ce": 0.0, "aux": 0.0}),
            ),
            axis_names={"pod"},
            check_vma=False,
        )(state, residuals, batch)
        new_state = dataclasses.replace(new_state, residuals=new_res)
        return new_state, metrics

    return step
