"""Checkpointing: atomic msgpack pytree snapshots with an optional ternary
codec (the checkpoint mirrors the T-FedAvg wire format — 2-bit weights +
per-layer scale ⇒ ~16× smaller; used for cross-site replication where the
paper's downstream-compression argument applies verbatim).

Layout:  <dir>/step_<N>/state.msgpack  (+ .meta.json), written via tmp+rename
so a crash mid-write never corrupts the latest checkpoint (restart safety).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.compression import (
    CodecSpec,
    DowncastTensor,
    TopKTensor,
    compress_pytree,
    decompress_pytree,
)
from repro.core.ternary import TernaryTensor

Pytree = Any

_SENTINEL_ARRAY = "__nd__"
_SENTINEL_TERNARY = "__tern__"
_SENTINEL_DOWNCAST = "__down__"
_SENTINEL_TOPK = "__topk__"
_SENTINEL_NONE = "__none__"


def _arr_obj(leaf) -> dict:
    arr = np.asarray(leaf)
    return {"data": arr.tobytes(), "dtype": arr.dtype.name,
            "shape": list(arr.shape)}


def _arr_from(obj) -> jnp.ndarray:
    dt = np.dtype(jnp.dtype(obj["dtype"]))
    return jnp.asarray(
        np.frombuffer(obj["data"], dt).reshape(obj["shape"])
    )


def _pack_leaf(leaf):
    if leaf is None:
        return {_SENTINEL_NONE: True}
    if isinstance(leaf, DowncastTensor):
        return {_SENTINEL_DOWNCAST: True, "payload": _arr_obj(leaf.data),
                "orig_dtype": leaf.orig_dtype}
    if isinstance(leaf, TopKTensor):
        return {_SENTINEL_TOPK: True, "indices": _arr_obj(leaf.indices),
                "values": _arr_obj(leaf.values), "shape": list(leaf.shape),
                "dtype": leaf.dtype}
    if isinstance(leaf, TernaryTensor):
        return {
            _SENTINEL_TERNARY: True,
            "packed": np.asarray(leaf.packed).tobytes(),
            "packed_len": int(leaf.packed.size),
            "w_q": np.asarray(leaf.w_q, np.float32).tobytes(),
            "w_q_shape": list(np.asarray(leaf.w_q).shape),
            "shape": list(leaf.shape),
            "dtype": leaf.dtype,
        }
    arr = np.asarray(leaf)
    return {
        _SENTINEL_ARRAY: True,
        "data": arr.tobytes(),
        "dtype": arr.dtype.str,
        "shape": list(arr.shape),
    }


def _unpack_leaf(obj):
    if _SENTINEL_NONE in obj:
        return None
    if _SENTINEL_DOWNCAST in obj:
        return DowncastTensor(data=_arr_from(obj["payload"]),
                              orig_dtype=obj["orig_dtype"])
    if _SENTINEL_TOPK in obj:
        return TopKTensor(indices=_arr_from(obj["indices"]),
                          values=_arr_from(obj["values"]),
                          shape=tuple(obj["shape"]), dtype=obj["dtype"])
    if _SENTINEL_TERNARY in obj:
        wq = np.frombuffer(obj["w_q"], np.float32).reshape(obj["w_q_shape"])
        return TernaryTensor(
            packed=jnp.asarray(
                np.frombuffer(obj["packed"], np.uint8)[: obj["packed_len"]]
            ),
            w_q=jnp.asarray(wq),
            shape=tuple(obj["shape"]),
            dtype=obj["dtype"],
        )
    arr = np.frombuffer(obj["data"], np.dtype(obj["dtype"])).reshape(obj["shape"])
    return jnp.asarray(arr)


def _is_leaf(x):
    return x is None or isinstance(x, (TernaryTensor, DowncastTensor, TopKTensor))


def save_checkpoint(
    directory: str,
    step: int,
    state: Pytree,
    *,
    compression: CodecSpec | None = None,
    keep: int = 3,
    metadata: dict | None = None,
) -> str:
    """Atomically persist ``state`` at ``<directory>/step_<step>``.

    compression: ternary-compress quantizable leaves (params) on disk.
    keep: retain only the newest ``keep`` checkpoints (0 = keep all).
    """
    os.makedirs(directory, exist_ok=True)
    if compression is not None and not compression.is_identity:
        wire, _ = compress_pytree(state, compression)
    else:
        wire = state

    leaves, treedef = jax.tree_util.tree_flatten(wire, is_leaf=_is_leaf)
    payload = {
        "leaves": [_pack_leaf(l) for l in leaves],
        "treedef": str(treedef),
    }
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "state.msgpack"), "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    meta = dict(metadata or {})
    meta.update({"step": step, "compressed": compression is not None
                 and not compression.is_identity})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    if keep:
        steps = sorted(latest_steps(directory))
        for s in steps[:-keep]:
            shutil.rmtree(os.path.join(directory, f"step_{s:012d}"), ignore_errors=True)
    return final


def latest_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = latest_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    step: int | None = None,
    *,
    example_state: Pytree | None = None,
    compression: CodecSpec | None = None,
    sharding: Any | None = None,
) -> tuple[Pytree, dict]:
    """Load a checkpoint. If ``example_state`` is given its treedef is used
    (robust across refactors of container types). ``sharding`` (a pytree of
    NamedSharding or a single sharding) re-places leaves for the current mesh
    — this is the elastic-rescale entry point."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(path, "state.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    leaves = [_unpack_leaf(o) for o in payload["leaves"]]
    if example_state is not None:
        treedef = jax.tree_util.tree_structure(example_state, is_leaf=_is_leaf)
    else:
        raise ValueError("restore_checkpoint requires example_state for treedef")
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if (compression is not None and not compression.is_identity) or meta.get(
            "compressed"):
        state = decompress_pytree(state)
    if sharding is not None:
        if jax.tree_util.tree_structure(sharding) == jax.tree_util.tree_structure(state):
            state = jax.tree_util.tree_map(jax.device_put, state, sharding)
        else:
            state = jax.tree_util.tree_map(lambda l: jax.device_put(l, sharding), state)
    return state, meta
