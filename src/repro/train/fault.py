"""Fault tolerance & elasticity helpers.

Failure model at 1000+-node scale:
  - CLIENT/POD loss mid-round (fed path): handled inside fed.simulation —
    aggregation reweights over survivors; no round is lost.
  - HOST crash (datacenter path): training resumes from the newest atomic
    checkpoint (train.checkpoint); the data cursor + RNG + step live in the
    checkpoint so the resumed run is bit-identical modulo the lost steps.
  - STRAGGLERS: fed rounds enforce a deadline (drop & reweight); datacenter
    path notes: ternary compression itself shrinks the sync critical path
    16×, which is the paper's own straggler story for slow links.
  - ELASTIC RESCALE: ``elastic_reshard`` re-places a checkpointed state onto
    a smaller/larger mesh (e.g. 2 pods → 1 pod after a pod outage) using the
    same sharding rules — GSPMD resharding is just device_put with the new
    NamedShardings.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable

import jax

log = logging.getLogger("repro.fault")

Pytree = Any


def retrying(fn: Callable, *, max_attempts: int = 3, backoff_s: float = 0.1,
             retryable=(RuntimeError, OSError)):
    """Wrap a step/IO function with bounded retry (transient failures:
    preempted hosts, flaky interconnect, fs hiccups)."""

    def wrapped(*args, **kwargs):
        last = None
        for attempt in range(max_attempts):
            try:
                return fn(*args, **kwargs)
            except retryable as e:  # pragma: no cover - exercised in tests
                last = e
                log.warning("attempt %d/%d failed: %s", attempt + 1, max_attempts, e)
                time.sleep(backoff_s * (2**attempt))
        raise last

    return wrapped


def elastic_reshard(state: Pytree, shardings: Pytree) -> Pytree:
    """Re-place every leaf of ``state`` onto new shardings (new mesh).

    shardings: pytree of NamedSharding matching state, or a prefix thereof
    (a single sharding broadcasts to all leaves)."""
    if jax.tree_util.tree_structure(shardings) == jax.tree_util.tree_structure(state):
        return jax.tree_util.tree_map(jax.device_put, state, shardings)
    return jax.tree_util.tree_map(lambda l: jax.device_put(l, shardings), state)


class StragglerDeadline:
    """Wall-clock budget for a unit of work; callers drop work that overruns
    (used by fed.simulation's round loop and the serving batcher)."""

    def __init__(self, budget_s: float):
        self.budget_s = budget_s
        self._start = time.monotonic()

    def reset(self):
        self._start = time.monotonic()

    def exceeded(self) -> bool:
        return (time.monotonic() - self._start) > self.budget_s

    def remaining(self) -> float:
        return max(0.0, self.budget_s - (time.monotonic() - self._start))
