"""Compression API shared by checkpoints, collectives, and the fed protocol.

``CompressionSpec`` selects the codec; ``compress_pytree`` /
``decompress_pytree`` apply it leaf-wise. Two codecs:

  - "none":    identity (fp32/bf16 wire) — the FedAvg baseline.
  - "ternary": FTTQ wire format (TernaryTensor: 2-bit codes + scale) — the
    paper's codec. Optional error feedback keeps the quantization residual
    locally so repeated compression of a drifting signal is unbiased in the
    long run (beyond-paper; used by the gradient-compression path).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fttq
from repro.core.ternary import TernaryTensor, encode_ternary

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    kind: str = "ternary"  # "none" | "ternary"
    fttq: fttq.FTTQConfig = dataclasses.field(default_factory=fttq.FTTQConfig)
    error_feedback: bool = False

    def __post_init__(self):
        if self.kind not in ("none", "ternary"):
            raise ValueError(f"unknown compression kind {self.kind!r}")


def compress_pytree(
    tree: Pytree, spec: CompressionSpec, residual: Pytree | None = None
) -> tuple[Pytree, Pytree | None]:
    """Compress each quantizable leaf; returns (wire_tree, new_residual).

    With error feedback, the input is first corrected by the carried residual
    and the new residual is (corrected − dequant(wire)).
    """
    if spec.kind == "none":
        return tree, residual

    cfg = spec.fttq

    def one(path, leaf, res):
        if not fttq.is_quantizable(path, leaf, cfg):
            return leaf, jnp.zeros_like(leaf) if spec.error_feedback else None
        x = leaf + res if (spec.error_feedback and res is not None) else leaf
        ts = fttq.scale_layer(x)
        d = fttq.fttq_threshold(ts, cfg.t_k, cfg.threshold_rule)
        i_t = fttq.ternarize(ts, d)
        absw = jnp.abs(ts)
        sel = absw > d
        wq = jnp.sum(jnp.where(sel, absw, 0.0)) / (jnp.sum(sel) + 1e-8)
        wq = wq * (jnp.max(jnp.abs(x)) + 1e-8)  # undo layer scaling on the wire
        wire = encode_ternary(i_t, wq.astype(x.dtype), dtype=str(x.dtype))
        new_res = (x - wire.dequantize()) if spec.error_feedback else None
        return wire, new_res

    paths_leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    res_leaves = (
        jax.tree_util.tree_leaves(residual)
        if residual is not None
        else [None] * len(paths_leaves)
    )
    out_wire, out_res = [], []
    for (path, leaf), res in zip(paths_leaves, res_leaves):
        w, r = one(path, leaf, res)
        out_wire.append(w)
        out_res.append(r)
    wire_tree = jax.tree_util.tree_unflatten(treedef, out_wire)
    res_tree = (
        jax.tree_util.tree_unflatten(treedef, out_res)
        if spec.error_feedback
        else None
    )
    return wire_tree, res_tree


def decompress_pytree(wire_tree: Pytree, spec: CompressionSpec) -> Pytree:
    if spec.kind == "none":
        return wire_tree

    def one(leaf):
        if isinstance(leaf, TernaryTensor):
            return leaf.dequantize()
        return leaf

    return jax.tree_util.tree_map(
        one, wire_tree, is_leaf=lambda x: isinstance(x, TernaryTensor)
    )


def wire_nbytes(wire_tree: Pytree) -> int:
    """Actual bytes of a compressed pytree on the wire.

    Delegates to the ``repro.comm.wire`` codec: the tree is serialized and
    the buffer length measured, so header/framing overhead is included and
    this number is exactly what a transport would move.
    """
    from repro.comm.wire import update_nbytes  # lazy: comm imports core.ternary

    return update_nbytes(wire_tree)
