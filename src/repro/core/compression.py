"""Codec registry shared by checkpoints, collectives, and the fed protocol.

Compression is organized as a registry of ``Codec`` objects. A codec turns
one pytree leaf into a *wire leaf* (a compact, serializable representation)
and back; each codec owns a wire record kind byte so ``repro.comm.wire``
can frame it without a hard-coded type switch. Shipped codecs:

  - "none":    identity (fp32/bf16 wire) — the FedAvg baseline.
  - "ternary": FTTQ wire format (``TernaryTensor``: 2-bit codes + scale) —
    the paper's codec.
  - "fp16" / "bf16": half-precision downcast (``DowncastTensor``) — 2×
    on the non-quantizable leaves (biases, norms) that FTTQ ships raw.
  - "topk":    magnitude top-k sparsification (``TopKTensor``: sorted flat
    indices + values), per Sattler et al. (arXiv:1903.02891) — the other
    half of "downcast + sparsify the residual streams".

``CodecSpec`` selects codecs for ONE direction of traffic: ``kind`` applies
to quantizable (weight-like) leaves, ``residual`` to everything else.
``CompressionSpec`` pairs two of them — ``upstream`` (client→server) and
``downstream`` (server→client) — because the two directions compress
independently (paper §III.B broadcasts re-quantized weights while clients
upload FTTQ payloads; asymmetric codecs fall out of the same split).

Optional error feedback keeps the compression residual locally so repeated
compression of a drifting signal is unbiased in the long run (beyond-paper;
used by the gradient-compression path). It is generic over codecs: the
residual is ``x − decode(encode(x))`` whatever the codec.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fttq
from repro.core.ternary import TernaryTensor, encode_ternary

Pytree = Any

# Wire record kind bytes (the framing contract with ``repro.comm.wire``).
# RAW and TERNARY are wire-v1; DOWNCAST and TOPK need wire-v2 buffers;
# TOPK_DELTA (delta-varint indices, the kind encoders emit for TopKTensor
# since v3) needs v3. KIND_TOPK stays decodable for stored v2 captures.
KIND_RAW = 0
KIND_TERNARY = 1
KIND_DOWNCAST = 2
KIND_TOPK = 3
KIND_TOPK_DELTA = 4


# --------------------------------------------------------------------------
# Wire leaf containers (what a codec's encode_leaf produces).
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DowncastTensor:
    """A leaf downcast to a narrower float dtype for the wire.

    ``data`` carries the payload (fp16/bf16); ``orig_dtype`` is the logical
    dtype ``restore()`` upcasts to (static aux data).
    """

    data: jax.Array
    orig_dtype: str = "float32"

    def tree_flatten(self):
        return (self.data,), (self.orig_dtype,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(data=children[0], orig_dtype=aux[0])

    def restore(self) -> jax.Array:
        return self.data.astype(jnp.dtype(self.orig_dtype))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TopKTensor:
    """Magnitude top-k sparsified leaf: sorted flat indices + their values.

    Indices are uint32 over the flattened logical shape (ascending, so the
    wire stream is delta-encodable later); dropped positions decode to zero.
    """

    indices: jax.Array  # (k,) uint32, ascending flat indices
    values: jax.Array   # (k,) surviving values
    shape: tuple
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.indices, self.values), (tuple(self.shape), self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(indices=children[0], values=children[1],
                   shape=aux[0], dtype=aux[1])

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def densify(self) -> jax.Array:
        flat = jnp.zeros((self.n_elements,), jnp.dtype(self.dtype))
        flat = flat.at[self.indices.astype(jnp.int32)].set(
            self.values.astype(jnp.dtype(self.dtype))
        )
        return flat.reshape(self.shape)


# --------------------------------------------------------------------------
# The Codec protocol + registry.
# --------------------------------------------------------------------------


@runtime_checkable
class Codec(Protocol):
    """One leaf-level compression scheme.

    ``wire_kind`` is the record kind byte ``repro.comm.wire`` frames this
    codec's leaves under; ``leaf_type`` the wire-leaf class ``encode_leaf``
    produces (None for codecs whose output is a plain array / RAW record).

    Optional capability: a codec may additionally expose
    ``encode_leaves_batch(leaves, spec) -> list`` — ``compress_pytree``
    probes for it and routes ALL of a tree's kind-codec leaves through one
    call (the fused-kernel batching hook) instead of the per-leaf loop.
    """

    name: str
    wire_kind: int
    leaf_type: type | None

    def encode_leaf(self, leaf: jax.Array, spec: "CodecSpec") -> Any: ...

    def decode_leaf(self, wire_leaf: Any) -> jax.Array: ...


_CODECS: dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    """Add a codec to the registry (name and wire kind must be consistent:
    two codecs may share a wire kind only if they share a leaf type)."""
    if codec.name in _CODECS:
        raise ValueError(f"codec {codec.name!r} already registered")
    for other in _CODECS.values():
        if other.wire_kind == codec.wire_kind and other.leaf_type is not codec.leaf_type:
            raise ValueError(
                f"codec {codec.name!r} reuses wire kind {codec.wire_kind} of "
                f"{other.name!r} with a different leaf type"
            )
    _CODECS[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; registered: {available_codecs()}"
        ) from None


def available_codecs() -> list[str]:
    return sorted(_CODECS)


def wire_leaf_types() -> tuple[type, ...]:
    """All registered non-RAW wire leaf classes (for tree_map is_leaf)."""
    return tuple({c.leaf_type for c in _CODECS.values() if c.leaf_type is not None})


def is_wire_leaf(x: Any) -> bool:
    return isinstance(x, wire_leaf_types())


def decode_wire_leaf(leaf: Any) -> jax.Array:
    """Decode any registered wire leaf back to a dense array (type dispatch)."""
    for codec in _CODECS.values():
        if codec.leaf_type is not None and isinstance(leaf, codec.leaf_type):
            return codec.decode_leaf(leaf)
    return leaf


# --------------------------------------------------------------------------
# Specs.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """Codec selection for ONE direction of traffic.

    kind:     codec for quantizable (weight-like) leaves.
    residual: codec for the non-quantizable leaves (biases, norms, scalars)
              — the streams FTTQ ships raw; fp16/bf16/topk live here.
    """

    kind: str = "ternary"
    residual: str = "none"
    fttq: fttq.FTTQConfig = dataclasses.field(default_factory=fttq.FTTQConfig)
    error_feedback: bool = False
    topk_fraction: float = 0.1  # fraction of elements the "topk" codec keeps
    # True → ternary leaves encode through the fused one-pass quantize→pack
    # kernel (core.encode; byte-identical wire output, property-tested);
    # False → the pinned per-leaf jnp reference.
    fused_encode: bool = True

    def __post_init__(self):
        for field in ("kind", "residual"):
            name = getattr(self, field)
            if name not in _CODECS:
                raise ValueError(
                    f"unknown compression {field} {name!r}; "
                    f"registered: {available_codecs()}"
                )
        if not 0.0 < self.topk_fraction <= 1.0:
            raise ValueError(f"topk_fraction must be in (0, 1], got {self.topk_fraction}")

    @property
    def is_identity(self) -> bool:
        return self.kind == "none" and self.residual == "none"


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """Per-direction codec selection: upstream (client→server) and
    downstream (server→client) compress independently."""

    upstream: CodecSpec = dataclasses.field(default_factory=CodecSpec)
    downstream: CodecSpec = dataclasses.field(default_factory=CodecSpec)

    @classmethod
    def symmetric(cls, kind: str = "ternary", residual: str = "none",
                  **kw) -> "CompressionSpec":
        d = CodecSpec(kind=kind, residual=residual, **kw)
        return cls(upstream=d, downstream=d)


# --------------------------------------------------------------------------
# Shipped codecs.
# --------------------------------------------------------------------------


class NoneCodec:
    name = "none"
    wire_kind = KIND_RAW
    leaf_type = None

    def encode_leaf(self, leaf, spec):
        return leaf

    def decode_leaf(self, wire_leaf):
        return wire_leaf


class TernaryCodec:
    """The paper's FTTQ wire path (2-bit codes + one trained scale).

    The whole-leaf scale (no per-layer split — the codec sees opaque
    leaves) uses the CANONICAL tiled moment reduction defined in
    ``kernels.quantize_pack``: a float sum's value depends on reduction
    order, so the jnp reference and the fused kernel share one order and
    serialize byte-identically. ``spec.fused_encode`` picks the path.
    """

    name = "ternary"
    wire_kind = KIND_TERNARY
    leaf_type = TernaryTensor

    def encode_leaf(self, leaf, spec):
        if getattr(spec, "fused_encode", False):
            from repro.core.encode import encode_codec_leaves_fused  # lazy

            return encode_codec_leaves_fused([leaf], spec)[0]
        from repro.kernels.quantize_pack import (  # lazy: import cycle
            moments_ref, scale_from_moments,
        )

        cfg = spec.fttq
        ts = fttq.scale_layer(leaf)
        d = fttq.fttq_threshold(ts, cfg.t_k, cfg.threshold_rule)
        i_t = fttq.ternarize(ts, d)
        denom = jnp.max(jnp.abs(leaf)) + 1e-8  # undo layer scaling on the wire
        wq = scale_from_moments(moments_ref(leaf, denom, d), denom)
        return encode_ternary(i_t, wq.astype(leaf.dtype), dtype=str(leaf.dtype))

    def encode_leaves_batch(self, leaves, spec):
        """Batch capability for the ``compress_pytree`` pre-pass: the fused
        pipeline encodes all leaves in one launch per dtype; with
        ``fused_encode=False`` it degrades to the per-leaf reference."""
        if getattr(spec, "fused_encode", False):
            from repro.core.encode import encode_codec_leaves_fused  # lazy

            return encode_codec_leaves_fused(leaves, spec)
        return [self.encode_leaf(leaf, spec) for leaf in leaves]

    def decode_leaf(self, wire_leaf):
        return wire_leaf.dequantize()


class DowncastCodec:
    """Half-precision downcast of the whole leaf (Sattler et al.-style)."""

    wire_kind = KIND_DOWNCAST
    leaf_type = DowncastTensor

    def __init__(self, name: str, wire_dtype):
        self.name = name
        self.wire_dtype = jnp.dtype(wire_dtype)

    def encode_leaf(self, leaf, spec):
        return DowncastTensor(
            data=leaf.astype(self.wire_dtype), orig_dtype=str(leaf.dtype)
        )

    def decode_leaf(self, wire_leaf):
        return wire_leaf.restore()


class TopKCodec:
    """Keep the spec.topk_fraction largest-magnitude entries; rest decode 0.

    Leaves frame under TOPK_DELTA since wire v3 (sorted indices ship as
    varint gaps); v2 TOPK buffers still decode to the same leaf type.
    """

    name = "topk"
    wire_kind = KIND_TOPK_DELTA
    leaf_type = TopKTensor

    def encode_leaf(self, leaf, spec):
        flat = leaf.reshape(-1)
        n = flat.shape[0]
        k = max(1, math.ceil(spec.topk_fraction * n))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        idx = jnp.sort(idx)
        return TopKTensor(
            indices=idx.astype(jnp.uint32),
            values=flat[idx],
            shape=tuple(leaf.shape),
            dtype=str(leaf.dtype),
        )

    def decode_leaf(self, wire_leaf):
        return wire_leaf.densify()


class TopKDowncastCodec(TopKCodec):
    """Composed top-k → downcast: the sparsification of ``TopKCodec`` with
    the surviving VALUES narrowed to fp16 on the wire (indices stay uint32
    varint-gaps). Roughly halves the value bytes of plain top-k; with error
    feedback the extra rounding error joins the residual stream, so the
    composition stays unbiased in the long run. Same ``TopKTensor`` leaf /
    TOPK_DELTA record — decoders cannot tell the two codecs apart.
    """

    name = "topk16"

    def encode_leaf(self, leaf, spec):
        t = super().encode_leaf(leaf, spec)
        return TopKTensor(
            indices=t.indices,
            values=t.values.astype(jnp.float16),
            shape=t.shape,
            dtype=t.dtype,
        )


register_codec(NoneCodec())
register_codec(TernaryCodec())
register_codec(DowncastCodec("fp16", jnp.float16))
register_codec(DowncastCodec("bf16", jnp.bfloat16))
register_codec(TopKCodec())
register_codec(TopKDowncastCodec())


# --------------------------------------------------------------------------
# Pytree application.
# --------------------------------------------------------------------------


def compress_pytree(
    tree: Pytree, spec: CodecSpec, residual: Pytree | None = None
) -> tuple[Pytree, Pytree | None]:
    """Compress each leaf per the directional spec; returns (wire_tree,
    new_residual).

    Quantizable leaves (``fttq.is_quantizable``) go through ``spec.kind``,
    the rest through ``spec.residual``. Leaves that are ALREADY wire leaves
    (e.g. a QAT client payload whose weights are TernaryTensor) pass through
    untouched, so this also "finishes" a partially compressed tree. With
    error feedback, the input is first corrected by the carried residual and
    the new residual is (corrected − decode(wire)).

    Kind codecs exposing the optional ``encode_leaves_batch`` capability
    (the ternary codec, when ``spec.fused_encode``) get all raw quantizable
    leaves BATCHED through one call — the fused quantize→pack pipeline:
    lane-aligned staging, one kernel launch per dtype — instead of one
    Python-level per-leaf chain.
    """
    if spec.is_identity:
        return tree, residual

    paths_leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_wire_leaf
    )[0]
    treedef = jax.tree_util.tree_structure(tree, is_leaf=is_wire_leaf)
    res_leaves = (
        jax.tree_util.tree_leaves(residual)
        if residual is not None
        else [None] * len(paths_leaves)
    )

    # batched pre-pass: codecs exposing the optional encode_leaves_batch
    # capability (see the Codec protocol) encode every raw quantizable leaf
    # in one call — the fused ternary pipeline's O(few)-kernel-launch hook;
    # the per-leaf loop below picks the results up.
    pre: dict[int, Any] = {}
    batch = getattr(get_codec(spec.kind), "encode_leaves_batch", None)
    if batch is not None:
        idxs, to_encode = [], []
        for i, ((path, leaf), res) in enumerate(zip(paths_leaves, res_leaves)):
            if is_wire_leaf(leaf) or not fttq.is_quantizable(path, leaf, spec.fttq):
                continue
            x = leaf + res if (spec.error_feedback and res is not None) else leaf
            idxs.append(i)
            to_encode.append(x)
        if idxs:
            for i, x, wire in zip(idxs, to_encode, batch(to_encode, spec)):
                pre[i] = (x, wire)

    def one(i, path, leaf, res):
        if is_wire_leaf(leaf):
            # already compressed upstream of us; zero placeholder keeps the
            # residual tree structure-aligned for the next round.
            return leaf, (jnp.zeros(()) if spec.error_feedback else None)
        if i in pre:
            x, wire = pre[i]
            codec = get_codec(spec.kind)
            new_res = (x - codec.decode_leaf(wire)) if spec.error_feedback else None
            return wire, new_res
        if fttq.is_quantizable(path, leaf, spec.fttq):
            codec = get_codec(spec.kind)
        elif jnp.issubdtype(jnp.result_type(leaf), jnp.floating):
            codec = get_codec(spec.residual)
        else:
            # int step counters, uint32 RNG keys, bools: lossy float codecs
            # would corrupt them — they always ship raw.
            codec = get_codec("none")
        x = leaf + res if (spec.error_feedback and res is not None) else leaf
        wire = codec.encode_leaf(x, spec)
        new_res = (x - codec.decode_leaf(wire)) if spec.error_feedback else None
        return wire, new_res

    out_wire, out_res = [], []
    for i, ((path, leaf), res) in enumerate(zip(paths_leaves, res_leaves)):
        w, r = one(i, path, leaf, res)
        out_wire.append(w)
        out_res.append(r)
    wire_tree = jax.tree_util.tree_unflatten(treedef, out_wire)
    res_tree = (
        jax.tree_util.tree_unflatten(treedef, out_res)
        if spec.error_feedback
        else None
    )
    return wire_tree, res_tree


def decompress_pytree(wire_tree: Pytree, spec: CodecSpec | None = None) -> Pytree:
    """Decode every wire leaf back to dense arrays (type dispatch — the wire
    tree is self-describing, so ``spec`` is accepted only for symmetry)."""
    del spec
    return jax.tree_util.tree_map(
        decode_wire_leaf, wire_tree, is_leaf=is_wire_leaf
    )


def wire_nbytes(wire_tree: Pytree) -> int:
    """Actual bytes of a compressed pytree on the wire.

    Delegates to the ``repro.comm.wire`` codec: the tree is serialized and
    the buffer length measured, so header/framing overhead is included and
    this number is exactly what a transport would move.
    """
    from repro.comm.wire import update_nbytes  # lazy: comm imports core.ternary

    return update_nbytes(wire_tree)
