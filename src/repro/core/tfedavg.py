"""T-FedAvg — Ternary Federated Averaging protocol (paper §III.B, Algorithm 2).

Round structure:
  1. UPSTREAM  — each selected client k trains locally with FTTQ (QAT) and
     uploads the wire payload {I_t packed 2-bit, w_q per layer} — NOT the
     full-precision update. Non-quantized leaves (biases, norms) ship FP32.
  2. AGGREGATE — the server rebuilds each client model θ_k^t = w_q·I_t and
     forms the dataset-size-weighted average
         θ_{r+1} = Σ_k |D_k| / Σ|D_k| · θ_k^t .
  3. DOWNSTREAM — the server re-quantizes the aggregated model with a FIXED
     threshold Δ = server_delta (default 0.05 per the paper) on the layer-wise
     scaled weights and broadcasts ternary codes + the server scale factor.

Byte accounting mirrors the paper's Table IV: FedAvg ships 32-bit weights both
ways; T-FedAvg ships 2 bits/weight + one fp32 scale per layer both ways.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fttq
from repro.core.ternary import TernaryTensor, encode_ternary

Pytree = Any


@dataclasses.dataclass
class TernaryUpdate:
    """A client's upstream payload.

    payload: pytree matching the model params; quantized leaves are
      TernaryTensor, non-quantized leaves are raw arrays (fp32 wire).
    n_samples: |D_k| — the aggregation weight.
    client_id: bookkeeping.
    """

    payload: Pytree
    n_samples: int
    client_id: int = -1

    def nbytes_upstream(self) -> int:
        """Measured upstream size: length of the serialized wire buffer."""
        from repro.comm.wire import update_nbytes  # lazy: comm imports core.ternary

        return update_nbytes(self.payload)


def _reference_payload_leaf(leaf, wq, cfg: fttq.FTTQConfig):
    """Pinned jnp reference for ONE quantizable upstream leaf: scale →
    threshold → ternarize → pack, with the TRAINED w_q carried as-is.
    The fused path (``core.encode``) is property-tested byte-identical."""

    def tern(t):
        ts = fttq.scale_layer(t)
        d = fttq.fttq_threshold(ts, cfg.t_k, cfg.threshold_rule)
        return fttq.ternarize(ts, d)

    if leaf.ndim >= 3 and hasattr(wq, "ndim") and wq.ndim == leaf.ndim:
        # stacked scan layers: ternarize per layer, keep per-layer w_q.
        i_t = jax.vmap(tern)(leaf)
    else:
        i_t = tern(leaf)
    return encode_ternary(i_t, wq, dtype=str(leaf.dtype))


def client_update_payload(
    params: Pytree, wq_tree: Pytree, cfg: fttq.FTTQConfig, *,
    fused: bool = True,
) -> Pytree:
    """Build the upstream wire payload from trained latent params + w_q tree.

    Quantizable leaves → TernaryTensor(I_t, w_q); others pass through (fp32).
    ``fused=True`` (default) routes the whole tree through the one-pass
    quantize→pack kernel pipeline (``core.encode.client_payload_fused``,
    O(few) launches, byte-identical wire output); ``fused=False`` keeps the
    per-leaf jnp chain as the pinned reference.
    """
    if fused:
        from repro.core.encode import client_payload_fused  # lazy: imports kernels

        return client_payload_fused(params, wq_tree, cfg)

    def one(path, leaf, wq):
        if wq is None:
            return leaf
        return _reference_payload_leaf(leaf, wq, cfg)

    return jax.tree_util.tree_map_with_path(
        one, params, wq_tree, is_leaf=lambda x: x is None
    )


def _dequant_payload(payload: Pytree) -> Pytree:
    # type-dispatched through the codec registry: handles ternary, downcast
    # and top-k wire leaves alike (whatever the upstream spec shipped).
    from repro.core.compression import decompress_pytree  # lazy: import order

    return decompress_pytree(payload)


def server_aggregate(updates: list[TernaryUpdate]) -> Pytree:
    """θ_{r+1} = Σ_k |D_k|/Σ|D_k| · dequant(payload_k)  (Algorithm 2).

    This is the list-based REFERENCE: it dequantizes every client to a
    dense tree before folding — O(C·P) fp32 traffic. The servers stream
    wire blobs through ``fed.aggregator.Aggregator`` instead (fused packed
    fan-in kernel, O(chunk) memory); the property tests pin the two paths
    together within fp32 reordering tolerance.
    """
    if not updates:
        raise ValueError("server_aggregate: no client updates survived the round")
    total = float(sum(u.n_samples for u in updates))
    weights = [u.n_samples / total for u in updates]
    dequant = [_dequant_payload(u.payload) for u in updates]

    def wsum(*leaves):
        acc = leaves[0] * weights[0]
        for w, l in zip(weights[1:], leaves[1:]):
            acc = acc + w * l
        return acc

    return jax.tree_util.tree_map(wsum, *dequant)


def _reference_requantize_leaf(leaf, wq, cfg: fttq.FTTQConfig):
    """Pinned jnp reference for ONE downstream leaf: fixed Δ = server_delta
    on scaled weights; the downstream scale uses the CANONICAL tiled moment
    reduction (``kernels.quantize_pack.moments_ref``) — a float sum's value
    depends on its reduction order, so the reference and the fused kernel
    share one defined order and stay byte-identical on the wire."""
    from repro.kernels.quantize_pack import moments_ref, scale_from_moments

    def codes(t):
        ts = fttq.scale_layer(t)
        return fttq.ternarize(ts, jnp.asarray(cfg.server_delta, ts.dtype))

    def scale_of(t):
        denom = jnp.max(jnp.abs(t)) + 1e-8
        d = jnp.asarray(cfg.server_delta, t.dtype)
        return scale_from_moments(moments_ref(t, denom, d), denom)

    if leaf.ndim >= 3 and hasattr(wq, "ndim") and wq.ndim == leaf.ndim:
        i_t = jax.vmap(codes)(leaf)
        scale = jnp.stack(
            [scale_of(leaf[i]) for i in range(leaf.shape[0])]
        ).reshape(wq.shape)
    else:
        i_t = codes(leaf)
        scale = scale_of(leaf)
    return encode_ternary(i_t, scale.astype(leaf.dtype), dtype=str(leaf.dtype))


def server_requantize(
    global_params: Pytree, cfg: fttq.FTTQConfig, wq_tree: Pytree | None = None,
    *, fused: bool = True,
) -> Pytree:
    """Downstream compression: re-quantize the aggregated global model.

    Uses the FIXED server threshold Δ = cfg.server_delta on layer-wise scaled
    weights (Algorithm 2's server step), with the downstream scale set to the
    Prop-4.1 optimum mean(|θ_s| over I_p) so the broadcast model is the best
    L2 ternary approximation — the paper broadcasts sign codes with the
    clients re-initializing w_q; carrying the optimal scale is equivalent on
    the wire (one extra fp32/layer) and keeps the global model usable for
    immediate evaluation.

    ``fused=True`` (default) encodes through the one-pass quantize→pack
    kernel (``core.encode.requantize_fused``, byte-identical wire output);
    ``fused=False`` keeps the per-leaf jnp reference.
    """
    if fused:
        from repro.core.encode import requantize_fused  # lazy: imports kernels

        return requantize_fused(global_params, cfg, wq_tree)
    if wq_tree is None:
        wq_tree = fttq.init_wq_tree(global_params, cfg)

    def one(path, leaf, wq):
        if wq is None:
            return leaf
        return _reference_requantize_leaf(leaf, wq, cfg)

    return jax.tree_util.tree_map_with_path(
        one, global_params, wq_tree, is_leaf=lambda x: x is None
    )


# --------------------------------------------------------------------------
# Communication accounting (paper Table IV) — measured, not estimated: both
# helpers serialize the actual wire payload and take len(bytes).
# --------------------------------------------------------------------------


def fedavg_round_bytes(params: Pytree, n_participants: int) -> dict:
    """FP32 FedAvg per-round bytes (upload = download = n·|serialized θ|)."""
    from repro.comm.wire import update_nbytes

    per_client = update_nbytes(params)
    return {
        "upload": per_client * n_participants,
        "download": per_client * n_participants,
        "per_client": per_client,
    }


def tfedavg_round_bytes(
    params: Pytree, n_participants: int, cfg: fttq.FTTQConfig
) -> dict:
    """T-FedAvg per-round bytes: serialized ternary wire both directions."""
    from repro.comm.wire import update_nbytes

    per_client = update_nbytes(server_requantize(params, cfg))
    return {
        "upload": per_client * n_participants,
        "download": per_client * n_participants,
        "per_client": per_client,
    }
