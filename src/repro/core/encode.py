"""Fused client-egress encode: whole-tree ternary quantize→pack in O(few)
kernel launches.

This is the encode-side counterpart of ``fed.aggregator`` (PR 3's fused
fan-in): the paper's upstream step (§III.B Algorithm 2 — every client ships
2-bit I_t + w_q each round) and the server's downstream re-quantization both
used to run a per-leaf jnp chain with ~5 HBM passes of fp32 per tensor.
Here every quantizable leaf of an update is flattened into lane-aligned
staging (``kernels.quantize_pack.stage_encode``, one segment per leaf or per
stacked-scan layer) and the whole tree is encoded by

  - ONE ``quantize_pack_segments`` launch for all single-segment leaves of a
    dtype (per-block (denom, Δ) scalars ride in SMEM), plus
  - one vmapped ``quantize_pack_stacked`` launch per stacked (ndim ≥ 3)
    scan leaf with per-layer scales,

each fusing scale → threshold → ternarize → 2-bit-pack into one HBM read and
a ~1/16-size write, with the w_q numerator/denominator coming out of the
same pass as per-tile partial moments. The packed output IS the wire byte
stream: one host transfer per tree, sliced zero-copy into per-leaf
``TernaryTensor.packed`` views.

Bit-exactness: the fused payloads serialize BYTE-IDENTICAL to the pinned
jnp reference paths (``core.tfedavg.client_update_payload(fused=False)``,
``server_requantize(fused=False)``, ``TernaryCodec`` with
``fused_encode=False``) — codes are elementwise IEEE ops, per-leaf stats are
computed by the very same jnp expressions, and the w_q reduction follows the
canonical tile order defined in ``kernels.quantize_pack`` on both sides.
Property-tested in ``tests/test_encode.py``.

Ragged stacked leaves (per-layer size % 4 ≠ 0) pack bytes ACROSS layer
boundaries on the wire, which no per-layer staging can emit directly; the
kernel still does all the fp work and a cheap host pass re-aligns the 2-bit
codes across the boundaries (``_repack_ragged``) — so "one launch per
client update" holds unconditionally, with byte-identical wire output.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fttq
from repro.core.ternary import TernaryTensor, packed_nbytes
from repro.kernels.quantize_pack import (
    BLOCK_S,
    LANES,
    quantize_pack_segments,
    quantize_pack_stacked,
    scale_from_moments,
    stage_encode,
    staged_rows,
)

Pytree = Any

_EPS = 1e-8


def _interp(interpret: bool | None) -> bool:
    return (jax.default_backend() != "tpu") if interpret is None else interpret


@dataclasses.dataclass(frozen=True)
class _Meta:
    """Static (hashable) per-leaf descriptor for the jitted group encode.

    mode: "payload" (trained w_q given, Δ from the threshold rule),
          "codec"   (w_q from moments, Δ from the threshold rule),
          "server"  (w_q from moments, fixed Δ = server_delta).
    """

    shape: tuple
    dtype: str
    mode: str
    rule: str = "mean"
    t_k: float = 0.7
    server_delta: float = 0.05
    has_wq: bool = False


def _n_elements(shape: tuple) -> int:
    return int(np.prod(shape)) if shape else 1


@functools.partial(jax.jit, static_argnames=("meta", "block_s", "interpret"))
def _encode_flat_group(
    leaves: tuple, meta: tuple, block_s: int, interpret: bool
) -> tuple[jax.Array, tuple]:
    """All single-segment leaves of one dtype → one fused kernel launch.

    Per-leaf denominators come from ONE batched |·|-max over the whole
    group's staging (one reduction per dtype group, not one per leaf): max
    is order-invariant and the staging's zero padding cannot move an
    abs-max, so each per-leaf slice reproduces the reference's
    ``jnp.max(jnp.abs(leaf))`` bit-exactly. The threshold MEAN stays a
    per-leaf reduction on purpose — fp summation order is part of the wire
    bytes, and batching it would break the byte-identity invariant.

    Returns (packed (S_total//4, LANES) uint8 — the concatenated wire byte
    streams, segment-aligned — and a per-leaf tuple of w_q scales, None
    where the caller supplies the trained factor)."""
    staged_parts, rows = [], []
    for leaf in leaves:
        staged, _ = stage_encode(leaf, block_s)
        staged_parts.append(staged)
        rows.append(staged.shape[0])
    staged_all = (staged_parts[0] if len(staged_parts) == 1
                  else jnp.concatenate(staged_parts, axis=0))
    row_max = jnp.max(jnp.abs(staged_all), axis=1)
    scal_parts, denoms = [], []
    off = 0
    for leaf, m, r in zip(leaves, meta, rows):
        denom = jnp.max(row_max[off:off + r]).astype(leaf.dtype) + _EPS
        off += r
        if m.mode == "server":
            delta = jnp.asarray(m.server_delta, leaf.dtype)
        else:
            # the same jnp expressions as the reference path, with the
            # batched denom substituted for scale_layer's internal max.
            delta = fttq.fttq_threshold(
                fttq.scale_layer(leaf, denom=denom), m.t_k, m.rule
            )
        g = r // block_s
        scal_parts.append(jnp.broadcast_to(
            jnp.stack([denom, delta]).astype(jnp.float32)[None, :], (g, 2)
        ))
        denoms.append(denom)
    scal_all = (scal_parts[0] if len(scal_parts) == 1
                else jnp.concatenate(scal_parts, axis=0))
    packed, moments = quantize_pack_segments(
        staged_all, scal_all, block_s=block_s, interpret=interpret
    )
    scales, off = [], 0
    for m, denom in zip(meta, denoms):
        g = staged_rows(_n_elements(m.shape), block_s) // block_s
        scales.append(
            None if m.has_wq
            else scale_from_moments(moments[off:off + g], denom).astype(m.dtype)
        )
        off += g
    return packed, tuple(scales)


@functools.partial(jax.jit, static_argnames=("meta", "block_s", "interpret"))
def _encode_stacked_leaf(
    leaf: jax.Array, meta: _Meta, block_s: int, interpret: bool
) -> tuple[jax.Array, jax.Array | None]:
    """One stacked (L, ...) scan leaf through the vmapped kernel: per-layer
    (denom, Δ) scalars, per-layer packed streams, per-layer w_q where the
    mode computes it. Ragged layer sizes are repacked host-side."""
    n_layers = leaf.shape[0]
    # ONE batched reduction for all layers' denominators (max is
    # order-invariant → bit-identical to the per-layer reference max).
    denoms = jnp.max(jnp.abs(leaf.reshape(n_layers, -1)), axis=1) + _EPS
    if meta.mode == "server":
        deltas = jnp.broadcast_to(
            jnp.asarray(meta.server_delta, leaf.dtype), (n_layers,)
        )
    else:
        deltas = jax.vmap(
            lambda t, d: fttq.fttq_threshold(
                fttq.scale_layer(t, denom=d), meta.t_k, meta.rule
            )
        )(leaf, denoms)
    packed, moments, _ = quantize_pack_stacked(
        leaf, denoms, deltas, block_s=block_s, interpret=interpret
    )
    if meta.has_wq:
        return packed, None
    scales = jnp.stack([
        scale_from_moments(moments[i], denoms[i]) for i in range(n_layers)
    ]).astype(leaf.dtype)
    return packed, scales


# --------------------------------------------------------------------------
# Batched leaf encode (the shared engine).
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Item:
    leaf: jax.Array
    meta: _Meta
    wq: Any = None          # trained factor (payload mode) — passed through
    stacked: bool = False


def _repack_ragged(packed_np: np.ndarray, n_layers: int,
                   layer_n: int) -> np.ndarray:
    """Rebuild the flat wire stream of a RAGGED stacked leaf (layer size %
    4 ≠ 0) from the kernel's per-layer packed planes.

    The wire format packs the CONCATENATED per-layer codes 4-per-byte, so
    layer boundaries land mid-byte — no per-layer staging can emit those
    bytes directly. The kernel still does all the fp work (scale →
    threshold → ternarize → per-layer pack); this host pass just re-aligns
    the 2-bit codes across layer boundaries: unpack each layer's first
    ``layer_n`` codes, concatenate, pad the tail with code 1 (= value 0,
    ``pack2bit``'s padding), and repack. Byte-identical to packing the
    concatenated codes, i.e. to the reference wire stream."""
    per = packed_np.reshape(n_layers, -1)[:, : (layer_n + 3) // 4]
    codes = np.empty((n_layers, per.shape[1] * 4), dtype=np.uint8)
    for j in range(4):
        codes[:, j::4] = (per >> (2 * j)) & 3
    codes = codes[:, :layer_n].reshape(-1)
    pad = (-codes.size) % 4
    if pad:
        codes = np.concatenate([codes, np.ones(pad, dtype=np.uint8)])
    q = codes.reshape(-1, 4)
    return (q[:, 0] | (q[:, 1] << 2) | (q[:, 2] << 4)
            | (q[:, 3] << 6)).astype(np.uint8)


def _encode_items(
    items: Sequence[_Item], *, block_s: int | None = None,
    interpret: bool | None = None,
) -> list[TernaryTensor]:
    """Encode a batch of quantizable leaves; one flat-group launch per dtype
    plus one vmapped launch per stacked leaf, then ONE device→host transfer
    for every packed stream and kernel-computed w_q scale of the whole
    batch. Output order matches input."""
    bs = BLOCK_S if block_s is None else block_s
    interp = _interp(interpret)
    out: list[TernaryTensor | None] = [None] * len(items)

    # stacked leaves: vmapped per-layer path
    stacked_res = [
        (i, *_encode_stacked_leaf(it.leaf, it.meta, bs, interp))
        for i, it in enumerate(items) if it.stacked
    ]

    # flat leaves: one launch per dtype group
    flat_ids = [i for i, it in enumerate(items) if not it.stacked]
    by_dtype: dict[str, list[int]] = {}
    for i in flat_ids:
        by_dtype.setdefault(items[i].meta.dtype, []).append(i)
    flat_res = []
    for ids in by_dtype.values():
        leaves = tuple(items[i].leaf for i in ids)
        meta = tuple(items[i].meta for i in ids)
        flat_res.append((ids, *_encode_flat_group(leaves, meta, bs, interp)))

    # ONE batched host sync for the whole update (the per-leaf np.asarray
    # calls this replaces each blocked on its own transfer).
    sp, ss, fp, fs = jax.device_get((
        [p for _, p, _ in stacked_res],
        [s for _, _, s in stacked_res],
        [p for _, p, _ in flat_res],
        [list(s) for _, _, s in flat_res],
    ))

    for (i, _, _), packed_np, scales in zip(stacked_res, sp, ss):
        it = items[i]
        layer_n = _n_elements(it.meta.shape[1:])
        if layer_n % 4 == 0:
            stream = np.concatenate(
                [packed_np[layer].reshape(-1)[: layer_n // 4]
                 for layer in range(it.leaf.shape[0])]
            )
        else:
            stream = _repack_ragged(packed_np, it.leaf.shape[0], layer_n)
        if it.meta.has_wq:
            wq = it.wq
        else:
            wq = scales.reshape(
                (it.leaf.shape[0],) + (1,) * (it.leaf.ndim - 1)
            )
        out[i] = TernaryTensor(
            packed=stream, w_q=wq, shape=it.meta.shape, dtype=it.meta.dtype
        )

    for (ids, _, _), packed_np, scales in zip(flat_res, fp, fs):
        flat_bytes = packed_np.reshape(-1)
        off_rows = 0
        for i, scale in zip(ids, scales):
            it = items[i]
            n = _n_elements(it.meta.shape)
            byte_off = (off_rows // 4) * LANES
            stream = flat_bytes[byte_off:byte_off + packed_nbytes(n)]
            wq = it.wq if it.meta.has_wq else scale
            out[i] = TernaryTensor(
                packed=stream, w_q=wq, shape=it.meta.shape, dtype=it.meta.dtype
            )
            off_rows += staged_rows(n, bs)
    return out  # type: ignore[return-value]


def _is_stacked(leaf, wq) -> bool:
    """Per-layer treatment mirrors the reference dispatch: ndim ≥ 3 with a
    broadcast-shaped per-layer factor tree."""
    return leaf.ndim >= 3 and hasattr(wq, "ndim") and wq.ndim == leaf.ndim


# --------------------------------------------------------------------------
# Public entry points (one per rewired call site).
# --------------------------------------------------------------------------


def client_payload_fused(
    params: Pytree, wq_tree: Pytree, cfg: fttq.FTTQConfig, *,
    block_s: int | None = None, interpret: bool | None = None,
) -> Pytree:
    """Fused ``core.tfedavg.client_update_payload``: trained w_q per leaf,
    whole update encoded in O(few) launches, byte-identical wire output."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    wqs = jax.tree_util.tree_flatten(wq_tree, is_leaf=lambda x: x is None)[0]
    out = list(leaves)
    items, idxs = [], []
    for i, (leaf, wq) in enumerate(zip(leaves, wqs)):
        if wq is None:
            continue
        stacked = _is_stacked(leaf, wq)
        meta = _Meta(
            shape=tuple(int(s) for s in leaf.shape), dtype=str(leaf.dtype),
            mode="payload", rule=cfg.threshold_rule, t_k=cfg.t_k, has_wq=True,
        )
        items.append(_Item(leaf=leaf, meta=meta, wq=wq, stacked=stacked))
        idxs.append(i)
    for i, t in zip(idxs, _encode_items(items, block_s=block_s,
                                        interpret=interpret)):
        out[i] = t
    return jax.tree_util.tree_unflatten(treedef, out)


def requantize_fused(
    global_params: Pytree, cfg: fttq.FTTQConfig, wq_tree: Pytree | None = None,
    *, block_s: int | None = None, interpret: bool | None = None,
) -> Pytree:
    """Fused ``core.tfedavg.server_requantize``: fixed Δ = server_delta on
    scaled weights, downstream scale from the same-pass moments."""
    if wq_tree is None:
        wq_tree = fttq.init_wq_tree(global_params, cfg)
    leaves, treedef = jax.tree_util.tree_flatten(global_params)
    wqs = jax.tree_util.tree_flatten(wq_tree, is_leaf=lambda x: x is None)[0]
    out = list(leaves)
    items, idxs = [], []
    for i, (leaf, wq) in enumerate(zip(leaves, wqs)):
        if wq is None:
            continue
        stacked = _is_stacked(leaf, wq)
        meta = _Meta(
            shape=tuple(int(s) for s in leaf.shape), dtype=str(leaf.dtype),
            mode="server", server_delta=cfg.server_delta, has_wq=False,
        )
        items.append(_Item(leaf=leaf, meta=meta, stacked=stacked))
        idxs.append(i)
    for i, t in zip(idxs, _encode_items(items, block_s=block_s,
                                        interpret=interpret)):
        out[i] = t
    return jax.tree_util.tree_unflatten(treedef, out)


def encode_codec_leaves_fused(
    leaves: Sequence[jax.Array], spec, *,
    block_s: int | None = None, interpret: bool | None = None,
) -> list[TernaryTensor]:
    """Fused ``TernaryCodec.encode_leaf`` over a BATCH of raw leaves (the
    ``compress_pytree`` pre-pass): whole-leaf scale regardless of ndim —
    exactly the codec reference — so every leaf is one segment and the batch
    is one launch per dtype."""
    cfg = spec.fttq
    items = [
        _Item(
            leaf=leaf,
            meta=_Meta(
                shape=tuple(int(s) for s in leaf.shape), dtype=str(leaf.dtype),
                mode="codec", rule=cfg.threshold_rule, t_k=cfg.t_k,
                has_wq=False,
            ),
        )
        for leaf in leaves
    ]
    return _encode_items(items, block_s=block_s, interpret=interpret)
