"""Core contribution of the paper: FTTQ quantization + T-FedAvg protocol."""

from repro.core.fttq import (
    FTTQConfig,
    fttq_quantize,
    scale_layer,
    fttq_threshold,
    ternarize,
    init_wq,
    quantize_tree,
    is_quantizable,
)
from repro.core.ternary import (
    pack2bit,
    unpack2bit,
    packed_nbytes,
    encode_ternary,
    decode_ternary,
    TernaryTensor,
)
from repro.core.tfedavg import (
    TernaryUpdate,
    client_update_payload,
    server_aggregate,
    server_requantize,
    tfedavg_round_bytes,
    fedavg_round_bytes,
)
from repro.core.compression import (
    Codec,
    CodecSpec,
    CompressionSpec,
    DowncastTensor,
    TopKTensor,
    available_codecs,
    compress_pytree,
    decompress_pytree,
    get_codec,
    register_codec,
)

__all__ = [
    "FTTQConfig", "fttq_quantize", "scale_layer", "fttq_threshold", "ternarize",
    "init_wq", "quantize_tree", "is_quantizable",
    "pack2bit", "unpack2bit", "packed_nbytes", "encode_ternary", "decode_ternary",
    "TernaryTensor",
    "TernaryUpdate", "client_update_payload", "server_aggregate",
    "server_requantize", "tfedavg_round_bytes", "fedavg_round_bytes",
    "Codec", "CodecSpec", "CompressionSpec", "DowncastTensor", "TopKTensor",
    "available_codecs", "get_codec", "register_codec",
    "compress_pytree", "decompress_pytree",
]
