"""Ternary wire format: 2-bit packed codes (4 weights per byte).

TPUs have no 2-bit dtype, so the wire / HBM format is uint8 with 4 ternary
codes per byte and the compute format is int8 {-1, 0, +1}.

Code mapping: code = I_t + 1 ∈ {0, 1, 2}; value 3 is unused (reserved).
Packing layout (little-endian within the byte):

    byte = c0 | c1 << 2 | c2 << 4 | c3 << 6

Padding semantics (unified across the repo): when n % 4 ≠ 0, the trailing
slots of the last byte carry code 1 — ternary VALUE 0 — matching
``kernels.pack2bit.pad_to_packable`` and the fused encode kernel, so any
consumer that reads past ``n`` (e.g. the fan-in kernel before its tail
slice) sees zeros, never −1.

These jnp implementations are the REFERENCE path; ``repro.kernels`` carries
the Pallas TPU kernels for the same ops (validated against these).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

CODES_PER_BYTE = 4


def packed_nbytes(n_elements: int) -> int:
    """Bytes needed to store n ternary values at 2 bits each."""
    return (n_elements + CODES_PER_BYTE - 1) // CODES_PER_BYTE


def pack2bit(i_t: jax.Array) -> jax.Array:
    """Pack a flat ternary array {-1,0,+1} into uint8, 4 codes per byte.

    Input of any shape is flattened; output is 1-D uint8 of
    ``packed_nbytes(i_t.size)``.
    """
    flat = i_t.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % CODES_PER_BYTE
    if pad:
        # pad with VALUE 0 (wire code 1) — see padding semantics above.
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    codes = (flat.astype(jnp.int8) + 1).astype(jnp.uint8)
    codes = codes.reshape(-1, CODES_PER_BYTE)
    out = (
        codes[:, 0]
        | (codes[:, 1] << 2)
        | (codes[:, 2] << 4)
        | (codes[:, 3] << 6)
    )
    return out.astype(jnp.uint8)


def unpack2bit(packed: jax.Array, n_elements: int, dtype=jnp.int8) -> jax.Array:
    """Inverse of ``pack2bit``: uint8 bytes → flat ternary array of n values."""
    shifts = jnp.array([0, 2, 4, 6], jnp.uint8)
    codes = (packed[:, None] >> shifts) & 0x3
    vals = codes.astype(jnp.int8) - 1
    return vals.reshape(-1)[:n_elements].astype(dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TernaryTensor:
    """A ternary-quantized tensor in wire format.

    Fields:
      packed: uint8 1-D, 4 codes/byte.
      w_q:    the trained layer scale (scalar or per-layer broadcast shape).
      shape:  logical (unpacked) shape — static aux data.
      dtype:  logical dtype name for dequantization — static aux data.
    """

    packed: jax.Array
    w_q: jax.Array
    shape: tuple
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.packed, self.w_q), (tuple(self.shape), self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, w_q = children
        shape, dtype = aux
        return cls(packed=packed, w_q=w_q, shape=shape, dtype=dtype)

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    def nbytes_wire(self) -> int:
        """Bytes on the wire: packed codes + the scale payload, derived from
        the ``w_q`` dtype/shape METADATA only — no ``np.asarray`` device→host
        sync per leaf (this runs once per leaf per round in byte accounting),
        while bf16/fp16 or per-layer stacked scales still report correctly."""
        w = self.w_q
        if hasattr(w, "dtype") and hasattr(w, "shape"):
            scale_bytes = int(np.prod(w.shape)) * jnp.dtype(w.dtype).itemsize
        else:  # plain python scalar: matches np.asarray's float64 default
            scale_bytes = np.asarray(w).nbytes
        return int(self.packed.size) + scale_bytes

    def dequantize(self) -> jax.Array:
        it = unpack2bit(self.packed, self.n_elements, jnp.int8)
        out = it.astype(self.dtype).reshape(self.shape)
        return out * jnp.asarray(self.w_q, self.dtype)

    def ternary(self) -> jax.Array:
        """Unpacked codes {-1,0,+1} at logical shape (int8)."""
        return unpack2bit(self.packed, self.n_elements, jnp.int8).reshape(self.shape)

    def to_bytes(self) -> bytes:
        """Serialize to the framed ``repro.comm.wire`` single-tensor format."""
        from repro.comm.wire import encode_tensor  # lazy: comm imports this module

        return encode_tensor(self)

    @classmethod
    def from_bytes(cls, data: bytes) -> "TernaryTensor":
        """Inverse of ``to_bytes`` (CRC-checked)."""
        from repro.comm.wire import decode_tensor

        return decode_tensor(data)


def encode_ternary(i_t: jax.Array, w_q: jax.Array, dtype: str = "float32") -> TernaryTensor:
    """Wrap ternary codes + scale into wire format."""
    return TernaryTensor(
        packed=pack2bit(i_t), w_q=w_q, shape=tuple(i_t.shape), dtype=dtype
    )


def decode_ternary(t: TernaryTensor) -> jax.Array:
    return t.dequantize()
