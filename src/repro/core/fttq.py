"""Federated Trained Ternary Quantization (FTTQ) — paper §III.A, Algorithm 1.

The quantizer pipeline per layer (eqs. 6-12 of the paper):

    θ_s  = g(θ)                    layer-wise scale to [-1, 1]          (eq. 6)
    Δ    = T_k / m · Σ_i |θ_s_i|   sparsity-aware threshold             (eq. 8)
    mask = ε(|θ_s| − Δ)            step function                        (eq. 10)
    I_t  = sign(mask ⊙ θ_s)        ternary codes in {-1, 0, +1}         (eq. 11)
    θ_t  = w_q · I_t               single TRAINED scale factor          (eq. 12)

Backward pass (Algorithm 1 + the TTQ rules the paper adopts from Zhu et al.):

    ∂J/∂w_q = Σ_i ∂J/∂θ_t_i · I_t_i      (generalizes the paper's Σ_{i∈I_p}
                                          rule to the single-factor case: the
                                          factor multiplies BOTH signs)
    ∂J/∂θ_i = ∂J/∂θ_t_i · (w_q  if I_t_i ≠ 0 else 1)   straight-through,
              scaled by the factor on quantized positions (TTQ latent rule).

All functions are pure and jit/vmap/pjit-compatible. ``quantize_tree`` applies
the quantizer across a parameter pytree, quantizing only "weight-like" leaves
(ndim ≥ 2) and leaving biases / norms / scalars full precision — matching the
paper's practice (and TTQ/TWN practice of keeping sensitive layers FP).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

_EPS = 1e-8


@dataclasses.dataclass(frozen=True)
class FTTQConfig:
    """Hyper-parameters of the FTTQ quantizer.

    Attributes:
      t_k: threshold hyper-parameter T_k of eq. (8). The paper notes the
        mean-rule threshold "will turn into the optimal solution proposed in
        [TWN] if we set the value of T_k to 0.7" — so 0.7 is the default.
      threshold_rule: "mean" → eq. (8) (default, sparsity-aware);
        "max" → eq. (7) (TTQ heuristic Δ = t·max|θ_s|).
      server_delta: fixed re-quantization threshold used by the server on the
        aggregated global model (paper §III.B: default 0.05).
      quantize_embed: also ternarize embedding / unembedding tables. Off by
        default (TTQ keeps first/last layers FP).
      exclude_patterns: regexes over the pytree key-path; matching leaves stay
        full precision even if weight-like.
      min_ndim: leaves with fewer dims are never quantized (biases, norms).
    """

    t_k: float = 0.7
    threshold_rule: str = "mean"
    server_delta: float = 0.05
    quantize_embed: bool = False
    exclude_patterns: tuple[str, ...] = ()
    min_ndim: int = 2


def scale_layer(theta: jax.Array, denom: jax.Array | None = None) -> jax.Array:
    """g(θ): scale one layer's weights into [-1, 1] (eq. 6), layer-wise.

    Layer-wise (not global) scaling avoids the magnitude-imbalance problem the
    paper points out (§III.A): scaling the whole network pushes most weights
    of small-magnitude layers to zero.

    ``denom`` lets a caller that already holds max|θ| + ε (e.g. the fused
    encoder's ONE batched reduction per dtype group) reuse it; max is
    order-invariant, so a precomputed denom carries the same fp bits.
    """
    if denom is None:
        denom = jnp.max(jnp.abs(theta)) + _EPS
    return theta / denom


def fttq_threshold(theta_s: jax.Array, t_k: float, rule: str = "mean") -> jax.Array:
    """Δ for one layer. rule="mean" is eq. (8); rule="max" is eq. (7)."""
    if rule == "mean":
        return t_k * jnp.mean(jnp.abs(theta_s))
    if rule == "max":
        return t_k * jnp.max(jnp.abs(theta_s))
    raise ValueError(f"unknown threshold rule: {rule!r}")


def ternarize(theta_s: jax.Array, delta: jax.Array) -> jax.Array:
    """I_t = sign(ε(|θ_s| − Δ) ⊙ θ_s) ∈ {-1, 0, +1} (eqs. 10-11)."""
    mask = (jnp.abs(theta_s) > delta).astype(theta_s.dtype)
    return jnp.sign(theta_s) * mask


def init_wq(theta: jax.Array, cfg: FTTQConfig) -> jax.Array:
    """Initialize the trained factor w_q at its Prop-4.1 optimum.

    w* = mean(|θ_i| : i ∈ I_p ∪ I_n) — the converged value of both TTQ
    factors (eq. 20) expressed in ORIGINAL (unscaled) units, because the
    forward pass uses θ_t = w_q · I_t directly: training starts at the
    analytic L2-optimal reconstruction instead of an arbitrary constant.
    """
    theta_s = scale_layer(theta)
    delta = fttq_threshold(theta_s, cfg.t_k, cfg.threshold_rule)
    sel = jnp.abs(theta_s) > delta
    absw = jnp.abs(theta)
    num = jnp.sum(jnp.where(sel, absw, 0.0))
    den = jnp.sum(sel) + _EPS
    return (num / den).astype(theta.dtype)


# --------------------------------------------------------------------------
# The quantizer with straight-through-estimator backward (Algorithm 1).
# --------------------------------------------------------------------------


@jax.custom_vjp
def fttq_quantize(theta: jax.Array, w_q: jax.Array, t_k: float) -> jax.Array:
    """θ_t = w_q · ternarize(g(θ), Δ(g(θ))).  Differentiable via STE."""
    theta_s = scale_layer(theta)
    delta = fttq_threshold(theta_s, t_k)
    i_t = ternarize(theta_s, delta)
    return w_q * i_t


def _fttq_fwd(theta, w_q, t_k):
    theta_s = scale_layer(theta)
    delta = fttq_threshold(theta_s, t_k)
    i_t = ternarize(theta_s, delta)
    return w_q * i_t, (i_t, w_q)


def _fttq_bwd(res, g):
    i_t, w_q = res
    # ∂J/∂w_q = Σ g · I_t  (paper Alg. 1 generalized to one factor).
    g_wq = jnp.sum(g * i_t).astype(w_q.dtype)
    # Latent full-precision gradient: STE scaled by w_q on quantized positions
    # (TTQ rule [Zhu et al. 2016] that the paper adopts), identity elsewhere.
    scale = jnp.where(i_t != 0, w_q, jnp.ones_like(w_q))
    g_theta = g * scale
    return g_theta, g_wq, None


fttq_quantize.defvjp(_fttq_fwd, _fttq_bwd)


# --------------------------------------------------------------------------
# Pytree application.
# --------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def is_quantizable(path, leaf: jax.Array, cfg: FTTQConfig) -> bool:
    """Policy: quantize weight-like leaves only.

    - ndim ≥ cfg.min_ndim (matrices / conv kernels / stacked scan weights),
    - not an excluded path (norm/bias/embedding unless quantize_embed),
    - floating point.
    """
    if not hasattr(leaf, "ndim") or leaf.ndim < cfg.min_ndim:
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    name = _path_str(path).lower()
    builtin_excludes = ["norm", "bias", "scale", "ln_", "layernorm", "a_log", "dt_"]
    if not cfg.quantize_embed:
        builtin_excludes += ["embed", "lm_head", "unembed", "patch_proj", "frontend"]
    for pat in builtin_excludes:
        if pat in name:
            return False
    for pat in cfg.exclude_patterns:
        if re.search(pat, name):
            return False
    return True


def init_wq_tree(params: Pytree, cfg: FTTQConfig) -> Pytree:
    """One w_q scalar per quantizable leaf; None (pruned) elsewhere.

    For STACKED scan layers (leading dim = layer index, ndim ≥ 3) the factor is
    per-layer: shape (num_layers, 1, 1, ...) broadcastable — each scanned layer
    gets its own trained factor, exactly as the paper trains one per layer.
    """

    def make(path, leaf):
        if not is_quantizable(path, leaf, cfg):
            return None
        if leaf.ndim >= 3:
            # stacked layers: per-leading-index factor.
            per_layer = jax.vmap(lambda t: init_wq(t, cfg))(leaf)
            return per_layer.reshape(leaf.shape[0], *([1] * (leaf.ndim - 1)))
        return init_wq(leaf, cfg)

    return jax.tree_util.tree_map_with_path(make, params)


def quantize_tree(params: Pytree, wq_tree: Pytree, cfg: FTTQConfig) -> Pytree:
    """Apply FTTQ to every quantizable leaf (QAT forward); rest pass through.

    ``wq_tree`` must be structure-matched to ``params`` with None at
    non-quantized leaves (as produced by ``init_wq_tree``).
    """

    def one(path, leaf, wq):
        if wq is None:
            return leaf
        if leaf.ndim >= 3 and wq.ndim == leaf.ndim:
            # stacked scan weights: vmap the quantizer over the layer dim.
            return jax.vmap(lambda t, w: fttq_quantize(t, w, cfg.t_k))(
                leaf, wq.reshape(leaf.shape[0])
            )
        return fttq_quantize(leaf, wq, cfg.t_k)

    return jax.tree_util.tree_map_with_path(
        one, params, wq_tree, is_leaf=lambda x: x is None
    )


def ternary_stats(params: Pytree, cfg: FTTQConfig) -> dict:
    """Diagnostics: per-tree sparsity and quantized fraction of parameters.

    The per-leaf zero counts stay on device and are folded by ONE final
    sum — a single device→host sync for the whole tree instead of one
    ``int(jnp.sum(...))`` blocking round trip per leaf."""
    total = 0
    quantized = 0
    zero_counts = []

    def visit(path, leaf):
        nonlocal total, quantized
        n = leaf.size
        total += n
        if is_quantizable(path, leaf, cfg):
            quantized += n
            ts = scale_layer(leaf)
            d = fttq_threshold(ts, cfg.t_k, cfg.threshold_rule)
            zero_counts.append(jnp.sum(jnp.abs(ts) <= d))
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    # one transfer of the per-leaf count vector, summed in Python ints on
    # the host — a device-side int32 fold could wrap past 2³¹ zeros.
    zeros = (
        int(np.asarray(jnp.stack(zero_counts)).astype(np.int64).sum())
        if zero_counts else 0
    )
    return {
        "total_params": total,
        "quantized_params": quantized,
        "quantized_fraction": quantized / max(total, 1),
        "ternary_sparsity": zeros / max(quantized, 1),
    }
