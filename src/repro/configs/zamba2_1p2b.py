"""zamba2-1.2b — [hybrid] 38L d_model=2048 32H (MHA) d_ff=8192 vocab=32000
ssm_state=64 — Mamba2 backbone + SHARED attention block applied every 6
layers [arXiv:2411.15242; hf]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "zamba2-1.2b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_heads=64,               # d_inner 4096 / head 64
        attn_every=6,               # 7 shared-attn applications over 38 layers
        gated_mlp=True,
        activation="silu",
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        ssm_state=8,
        ssm_expand=2,
        ssm_heads=8,
        ssm_chunk=4,
        attn_every=2,
        gated_mlp=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
