"""olmo-1b — [dense] 16L d_model=2048 16H (MHA) d_ff=8192 vocab=50304 —
non-parametric LN [arXiv:2402.00838; hf]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "olmo-1b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=50304,
        norm="nonparam",            # OLMo's non-parametric LayerNorm
        gated_mlp=True,
        activation="silu",
        tie_embeddings=True,
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=128,
        norm="nonparam",
        gated_mlp=True,
        tie_embeddings=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
