"""granite-20b — [dense] 52L d_model=6144 48H (GQA kv=1 ⇒ MQA) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "granite-20b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        gated_mlp=False,
        activation="gelu",
        norm="layernorm",
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=256,
        vocab_size=128,
        gated_mlp=False,
        activation="gelu",
        norm="layernorm",
    )
    base.update(overrides)
    return ModelConfig(**base)
