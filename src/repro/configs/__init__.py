"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``
plus the input-shape suite (see shapes.py).

Ten assigned architectures + the paper's own two models (MLP-MNIST and
ResNet18*-CIFAR10, used by the federated benchmarks)."""

from __future__ import annotations

from repro.configs import (
    deepseek_moe_16b,
    gemma3_4b,
    granite_20b,
    hubert_xlarge,
    llama32_vision_11b,
    mamba2_370m,
    olmo_1b,
    qwen3_moe_30b,
    yi_9b,
    zamba2_1p2b,
)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, input_specs

_MODULES = {
    granite_20b.ARCH_ID: granite_20b,
    gemma3_4b.ARCH_ID: gemma3_4b,
    olmo_1b.ARCH_ID: olmo_1b,
    yi_9b.ARCH_ID: yi_9b,
    zamba2_1p2b.ARCH_ID: zamba2_1p2b,
    mamba2_370m.ARCH_ID: mamba2_370m,
    llama32_vision_11b.ARCH_ID: llama32_vision_11b,
    qwen3_moe_30b.ARCH_ID: qwen3_moe_30b,
    deepseek_moe_16b.ARCH_ID: deepseek_moe_16b,
    hubert_xlarge.ARCH_ID: hubert_xlarge,
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str, **overrides):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _MODULES[arch_id].config(**overrides)


def get_reduced(arch_id: str, **overrides):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return _MODULES[arch_id].reduced(**overrides)


__all__ = [
    "ARCH_IDS", "get_config", "get_reduced",
    "SHAPES", "ShapeSpec", "applicable", "input_specs",
]
