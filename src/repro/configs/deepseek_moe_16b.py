"""deepseek-moe-16b — [moe] 28L d_model=2048 16H (MHA) expert d_ff=1408
vocab=102400 — 2 shared + 64 routed experts, top-6, fine-grained
[arXiv:2401.06066; hf]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "deepseek-moe-16b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        moe_d_ff=1408,
        n_experts=64,
        top_k=6,
        n_shared_experts=2,
        shared_d_ff=2816,           # 2 shared experts fused: 2 × 1408
        vocab_size=102400,
        gated_mlp=True,
        activation="silu",
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        moe_d_ff=32,
        n_experts=8,
        top_k=2,
        n_shared_experts=2,
        shared_d_ff=64,
        vocab_size=128,
        gated_mlp=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
