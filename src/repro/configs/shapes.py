"""Assigned input-shape suite and (arch × shape) applicability rules.

LM transformer shapes are seq_len × global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``. Skips (recorded in DESIGN.md §Arch-applicability):
  - long_500k needs sub-quadratic attention → runs only for SSM / hybrid /
    sliding-window archs;
  - encoder-only archs (hubert) have no decode step → decode shapes skipped.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch × shape) cell."""
    spec = SHAPES[shape_name]
    if spec.kind == "decode" and not cfg.causal:
        return False, "encoder-only arch has no decode step"
    if spec.kind == "prefill" and not cfg.causal:
        return True, ""  # encoder forward
    if shape_name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or cfg.sliding_window > 0
        if not sub_quadratic:
            return False, "pure full-attention arch: 500k context needs sub-quadratic attention"
    return True, ""


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation — suitable for .lower()/.compile() dry-runs.
    Token dtype int32; embedding stand-ins use cfg.compute_dtype.
    """
    spec = SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    cdt = cfg.cdtype()
    i32 = jnp.int32

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    specs: dict = {}
    if spec.kind == "train":
        if cfg.family == "audio":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt)
        else:
            specs["tokens"] = tok((b, s))
        specs["labels"] = tok((b, s))
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), cdt
            )
    elif spec.kind == "prefill":
        if cfg.family == "audio":
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt)
        else:
            specs["tokens"] = tok((b, s))
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), cdt
            )
    else:  # decode
        specs["tokens"] = tok((b, 1))
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, b, s, cdt))
        specs["cache"] = cache_shapes
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), cdt
            )
    return specs
