"""hubert-xlarge — [audio] 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504 —
encoder-only (w2v2 arch); the conv waveform frontend is a STUB — inputs are
precomputed frame embeddings [arXiv:2106.07447; unverified]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "hubert-xlarge"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,               # encoder-only
        use_rope=False,
        norm="layernorm",
        gated_mlp=False,
        activation="gelu",
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=37,
        causal=False,
        use_rope=False,
        norm="layernorm",
        gated_mlp=False,
        activation="gelu",
    )
    base.update(overrides)
    return ModelConfig(**base)
