"""mamba2-370m — [ssm] 48L d_model=1024 (attn-free) vocab=50280
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "mamba2-370m"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=1024,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_heads=32,               # d_inner 2048 / head 64
        ssm_chunk=256,
        tie_embeddings=True,
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=3,
        d_model=64,
        vocab_size=128,
        ssm_state=8,
        ssm_expand=2,
        ssm_heads=4,
        ssm_chunk=4,
        tie_embeddings=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
