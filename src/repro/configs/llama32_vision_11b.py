"""llama-3.2-vision-11b — [vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer; vision frontend is a
STUB (input_specs provides patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "llama-3.2-vision-11b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        cross_every=5,              # 8 cross-attention layers
        n_patches=1600,
        gated_mlp=True,
        activation="silu",
        rope_theta=500_000.0,
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        cross_every=2,
        n_patches=8,
        gated_mlp=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
