"""gemma3-4b — [dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "gemma3-4b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        gated_mlp=True,
        activation="gelu",          # GeGLU
        sliding_window=1024,
        global_every=6,             # 5 local : 1 global
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        gated_mlp=True,
        activation="gelu",
        sliding_window=8,
        global_every=6,
        tie_embeddings=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
