"""yi-9b — [dense] 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA [arXiv:2403.04652; hf]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "yi-9b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        gated_mlp=True,
        activation="silu",
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=128,
        gated_mlp=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
