"""qwen3-moe-30b-a3b — [moe] 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936 — 128 experts, top-8 routing [hf:Qwen/Qwen3-30B-A3B; hf]."""

from repro.models.transformer import ModelConfig

ARCH_ID = "qwen3-moe-30b-a3b"


def config(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID,
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        moe_d_ff=768,
        n_experts=128,
        top_k=8,
        vocab_size=151936,
        gated_mlp=True,
        activation="silu",
        rope_theta=1_000_000.0,
    )
    base.update(overrides)
    return ModelConfig(**base)


def reduced(**overrides) -> ModelConfig:
    base = dict(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        moe_d_ff=32,
        n_experts=8,
        top_k=2,
        vocab_size=128,
        gated_mlp=True,
    )
    base.update(overrides)
    return ModelConfig(**base)
