"""Distribution layer: parameter/activation sharding rules (DP+FSDP+TP+EP+SP),
ternary-compressed collectives (the paper's protocol mapped onto the
cross-pod axis), and the client-sharded packed fan-in for server-side
aggregation at scale."""

from repro.parallel.sharding import (
    param_shardings,
    param_specs,
    batch_specs,
    cache_specs,
    logical_batch_axes,
)
from repro.parallel.collectives import (
    ternary_allreduce,
    ternary_allreduce_tree,
    compressed_bytes_per_element,
)
from repro.parallel.fanin import (
    fanin_weighted_sum,
    fanin_trace_count,
)

__all__ = [
    "param_shardings", "param_specs", "batch_specs", "cache_specs",
    "logical_batch_axes",
    "ternary_allreduce", "ternary_allreduce_tree", "compressed_bytes_per_element",
    "fanin_weighted_sum", "fanin_trace_count",
]
