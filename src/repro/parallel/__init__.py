"""Distribution layer: parameter/activation sharding rules (DP+FSDP+TP+EP+SP)
and ternary-compressed collectives (the paper's protocol mapped onto the
cross-pod axis)."""

from repro.parallel.sharding import (
    param_shardings,
    param_specs,
    batch_specs,
    cache_specs,
    logical_batch_axes,
)
from repro.parallel.collectives import (
    ternary_allreduce,
    ternary_allreduce_tree,
    compressed_bytes_per_element,
)

__all__ = [
    "param_shardings", "param_specs", "batch_specs", "cache_specs",
    "logical_batch_axes",
    "ternary_allreduce", "ternary_allreduce_tree", "compressed_bytes_per_element",
]
