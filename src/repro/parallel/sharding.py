"""Parameter / input sharding rules (DESIGN.md §4).

Layout (single pod): mesh ("data", "model").
  - TP  over "model": attention QKV out-columns, MLP hidden, vocab, experts.
  - FSDP over "data": the other matrix axis of every weight + optimizer
    state (states inherit param specs) — ZeRO-3 via GSPMD.
  - EP  over "model" for MoE expert stacks.
  - batch over "data" (and "pod" when present); long-context decode with
    batch=1 shards the KV-cache/sequence axis over "data" instead (SP).

Multi-pod: mesh ("pod", "data", "model") — parameters are REPLICATED over
"pod" (each pod = one paper "client"); the cross-pod gradient sync is the
ternary-compressed collective in collectives.py.

Rules are path-regex → per-dimension logical axes, resolved against actual
shapes with a divisibility guard (a dim is only sharded if divisible by the
mesh axis size — e.g. MQA/GQA KV projections with few heads fall back to
replication automatically).
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import ModelConfig, init_params
from repro.configs.shapes import SHAPES

Pytree = Any

# (path-regex, per-dim logical axes from the LAST dim backwards).
# "tp" → model axis; "fsdp" → data axis; None → replicated.
# Leading unlisted dims (e.g. the stacked layer dim) are replicated.
_RULES: list[tuple[str, tuple]] = [
    (r"embed/table$",               ("tp", None)),        # vocab-parallel rows
    (r"lm_head$",                   ("fsdp", "tp")),
    (r"attn/w[qkv]$",               ("fsdp", "tp")),
    (r"attn/wo$",                   ("tp", "fsdp")),
    (r"mlp/w_(in|gate)$",           ("fsdp", "tp")),
    (r"mlp/w_out$",                 ("tp", "fsdp")),
    (r"moe/router$",                ("fsdp", None)),
    (r"moe/w_(in|gate)$",           ("ep", "fsdp", None)),   # (E, D, F)
    (r"moe/w_out$",                 ("ep", None, "fsdp")),   # (E, F, D)
    (r"moe/shared/w_(in|gate)$",    ("fsdp", "tp")),
    (r"moe/shared/w_out$",          ("tp", "fsdp")),
    (r"mamba/in_proj$",             ("fsdp", "tp")),
    (r"mamba/out_proj$",            ("tp", "fsdp")),
    (r"mamba/conv_w$",              (None, "tp")),
    # everything else (norms, biases, scalars, a_log, …): replicated.
]

_AXIS_MAP = {"tp": "model", "fsdp": "data", "ep": "model", None: None}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _spec_for(path: str, shape: tuple, mesh_axis_sizes: dict) -> P:
    for pat, dims in _RULES:
        if re.search(pat, path):
            ndim = len(shape)
            entries: list = [None] * ndim
            # dims are specified from the last dimension backwards.
            for i, logical in enumerate(reversed(dims)):
                d = ndim - 1 - i
                if d < 0:
                    break
                ax = _AXIS_MAP[logical]
                if ax is None:
                    continue
                if shape[d] % mesh_axis_sizes.get(ax, 1) == 0 and shape[d] > 0:
                    entries[d] = ax
            return P(*entries)
    return P()  # replicated


def param_specs(cfg: ModelConfig, mesh: Mesh) -> Pytree:
    """PartitionSpec pytree matching init_params(cfg) structure."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))

    def one(path, leaf):
        return _spec_for(_path_str(path), leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(one, shapes)


def param_shardings(cfg: ModelConfig, mesh: Mesh) -> Pytree:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs(cfg, mesh)
    )


def logical_batch_axes(mesh: Mesh) -> tuple:
    """The mesh axes that jointly carry the batch dimension."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    return tuple(names)


def batch_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh) -> dict:
    """PartitionSpecs for the input batch of a given shape cell."""
    spec = SHAPES[shape_name]
    bax = logical_batch_axes(mesh)
    bsz = spec.global_batch
    total = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in bax])) if bax else 1
    batch_axis = bax if bsz % max(total, 1) == 0 and bsz >= total else None
    bspec = P(batch_axis) if batch_axis else P()

    out: dict = {}
    if spec.kind == "train":
        if cfg.family == "audio":
            out["embeds"] = P(batch_axis, None, None) if batch_axis else P()
        else:
            out["tokens"] = P(batch_axis, None) if batch_axis else P()
        out["labels"] = P(batch_axis, None) if batch_axis else P()
        if cfg.family == "vlm":
            out["vision_embeds"] = P(batch_axis, None, None) if batch_axis else P()
    elif spec.kind == "prefill":
        if cfg.family == "audio":
            out["embeds"] = P(batch_axis, None, None) if batch_axis else P()
        else:
            out["tokens"] = P(batch_axis, None) if batch_axis else P()
        if cfg.family == "vlm":
            out["vision_embeds"] = P(batch_axis, None, None) if batch_axis else P()
    else:  # decode
        out["tokens"] = P(batch_axis, None) if batch_axis else P()
        out["cache"] = cache_specs(cfg, mesh, batch_sharded=batch_axis is not None)
        out["pos"] = P()
        if cfg.family == "vlm":
            out["vision_embeds"] = P(batch_axis, None, None) if batch_axis else P()
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, *, batch_sharded: bool) -> Pytree:
    """KV/state cache PartitionSpecs.

    batch_sharded=True: batch over ("pod","data"), kv-heads over "model" when
    divisible. batch_sharded=False (long-context, batch=1): SEQUENCE axis is
    sharded over "data" instead (sequence parallelism for flash-decode)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    bax = logical_batch_axes(mesh)
    tp = sizes.get("model", 1)
    specs: dict = {}
    kv_cols = cfg.n_kv_heads
    head_ax = "model" if kv_cols % tp == 0 and kv_cols >= tp else None
    # GQA/MQA archs with kv_heads < model-axis size can't head-shard the
    # cache — shard the SEQUENCE dim over "model" instead (flash-decode
    # combines partial softmax across model; a 32k cache is seq-divisible).
    seq_ax_model = "model" if head_ax is None else None

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if batch_sharded:
            kv = P(None, bax, seq_ax_model, head_ax, None)
        else:
            kv = P(None, None, ("data",) if seq_ax_model is None
                   else ("data", "model"), head_ax, None)  # SP over sequence
        specs["k"] = kv
        specs["v"] = kv
    if cfg.family in ("ssm", "hybrid"):
        ch_ax = "model"  # conv channels / heads over model when divisible
        d_in = cfg.ssm_expand * cfg.d_model
        conv_ch = d_in + 2 * cfg.ssm_state
        specs["conv"] = P(
            None, bax if batch_sharded else None, None,
            ch_ax if conv_ch % tp == 0 else None,
        )
        specs["ssd"] = P(
            None, bax if batch_sharded else None,
            "model" if cfg.ssm_heads % tp == 0 else None, None, None,
        )
    if cfg.family == "hybrid":
        if batch_sharded:
            kv = P(None, bax, seq_ax_model, head_ax, None)
        else:
            kv = P(None, None, ("data",) if seq_ax_model is None
                   else ("data", "model"), head_ax, None)
        specs["attn_k"] = kv
        specs["attn_v"] = kv
    return specs
