"""Ternary-compressed collectives — the paper's wire protocol applied to the
cross-pod gradient synchronization (DESIGN.md §2 mapping).

A standard bf16 ring all-reduce moves ≈ 2·2B/element across the slow
cross-pod links. ``ternary_allreduce`` instead:

  1. FTTQ-quantizes the local tensor (per-tensor trained/optimal scale w_q,
     eq. 8 threshold) — exactly the client upstream step,
  2. packs to 2 bits/element (4 codes per uint8 byte),
  3. all-gathers the packed payload over the pod axis (0.25B·(P-1)/elem),
  4. locally dequantizes + averages the P pod contributions — exactly the
     server aggregate step, executed redundantly per pod (the paper's
     "download the quantized global model" with zero extra wire cost).

For P=2 pods this is 2B → 0.25B per element = 8× less cross-pod traffic
(16× at P→∞ vs the 2·(P-1)/P·2B ring). Error feedback (beyond-paper,
Seide et al.-style) carries the quantization residual into the next step so
the compressed SGD remains convergent.

Must be called inside a shard_map region that is MANUAL over ``axis``
(see train.trainer: manual over "pod", auto over "data"/"model").
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fttq
from repro.core.ternary import CODES_PER_BYTE

Pytree = Any


def compressed_bytes_per_element(n_pods: int) -> float:
    """Wire bytes per gradient element of the ternary all-gather."""
    return 0.25 * (n_pods - 1)


def _quantize_lastdim(x: jax.Array, t_k: float):
    """FTTQ on an arbitrary-shape f32 tensor, packing 4 codes/byte along the
    LAST axis. SHAPE-PRESERVING on every other axis so existing data/model
    sharding survives (a flatten here would force each device to materialize
    the full tensor — measured 482 GB/device on granite-20b, §Perf C)."""
    absx = jnp.abs(x)
    mx = jnp.max(absx) + 1e-12
    delta = t_k * jnp.mean(absx) / mx          # threshold in scaled units
    xs = x / mx
    sel = jnp.abs(xs) > delta
    i_t = jnp.where(sel, jnp.sign(xs), 0.0)
    w_q = jnp.sum(jnp.where(sel, absx, 0.0)) / (jnp.sum(sel) + 1e-12)

    codes = (i_t.astype(jnp.int8) + 1).astype(jnp.uint8)
    c4 = codes.reshape(*x.shape[:-1], x.shape[-1] // CODES_PER_BYTE,
                       CODES_PER_BYTE)
    packed = (
        c4[..., 0] | (c4[..., 1] << 2) | (c4[..., 2] << 4) | (c4[..., 3] << 6)
    ).astype(jnp.uint8)
    recon = (w_q * i_t).astype(x.dtype)
    return packed, w_q.astype(jnp.float32), recon


def _unpack_lastdim(packed: jax.Array) -> jax.Array:
    shifts = jnp.array([0, 2, 4, 6], jnp.uint8)
    codes = (packed[..., None] >> shifts) & 0x3
    out = codes.astype(jnp.int8) - 1
    return out.reshape(*packed.shape[:-1],
                       packed.shape[-1] * CODES_PER_BYTE).astype(jnp.float32)


def ternary_allreduce(
    x: jax.Array,
    axis: str,
    *,
    t_k: float = 0.7,
    residual: jax.Array | None = None,
):
    """Mean over ``axis`` of FTTQ-compressed tensors.

    Returns (mean in x.dtype, new_residual or None). Requires
    x.shape[-1] % 4 == 0 (callers fall back to exact pmean otherwise).
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual

    packed, w_q, recon = _quantize_lastdim(xf, t_k)
    new_residual = (xf - recon) if residual is not None else None

    gathered = jax.lax.all_gather(packed, axis)       # (P, *shape[:-1], D/4)
    wqs = jax.lax.all_gather(w_q, axis)               # (P,)
    n_pods = gathered.shape[0]

    def add_one(carry, i):
        codes = _unpack_lastdim(gathered[i])
        return carry + wqs[i] * codes, None

    total, _ = jax.lax.scan(
        add_one, jnp.zeros(x.shape, jnp.float32), jnp.arange(n_pods)
    )
    mean = (total / n_pods).astype(x.dtype)
    return mean, new_residual


def ternary_allreduce_tree(
    grads: Pytree,
    axis: str,
    *,
    cfg: fttq.FTTQConfig | None = None,
    residuals: Pytree | None = None,
    error_feedback: bool = True,
) -> tuple[Pytree, Pytree]:
    """Apply ternary_allreduce leaf-wise to a gradient pytree.

    Quantizable leaves (ndim ≥ 2, per FTTQ policy) use the compressed path;
    small leaves (biases/norms/scalars) use an exact psum-mean — their bytes
    are negligible and exactness helps stability.
    Returns (synced_grads, new_residuals) — residuals zeros-like on the
    first call (pass state["residuals"] thereafter).
    """
    cfg = cfg or fttq.FTTQConfig()
    paths = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree_util.tree_structure(grads)
    res_leaves = (
        jax.tree_util.tree_leaves(residuals)
        if residuals is not None
        else [None] * len(paths)
    )

    out, new_res = [], []
    for (path, leaf), res in zip(paths, res_leaves):
        if fttq.is_quantizable(path, leaf, cfg) and leaf.shape[-1] % 4 == 0:
            r = res if (error_feedback and res is not None) else (
                jnp.zeros(leaf.shape, jnp.float32) if error_feedback else None
            )
            synced, nr = ternary_allreduce(leaf, axis, t_k=cfg.t_k, residual=r)
            out.append(synced)
            new_res.append(nr if nr is not None else jnp.zeros(leaf.shape, jnp.float32))
        else:
            out.append(jax.lax.pmean(leaf, axis))
            new_res.append(jnp.zeros(leaf.shape, jnp.float32))
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        jax.tree_util.tree_unflatten(treedef, new_res),
    )
