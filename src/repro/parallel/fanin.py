"""Multi-device fan-in: shard the CLIENT axis of the fused packed aggregator.

The streaming ``fed.aggregator`` batches up to ``chunk_c`` packed client
updates into one ``(C, R, LANES)`` uint8 tensor per kernel launch. At
million-client fan-in one device's HBM bandwidth becomes the ceiling, so
this module splits the C axis across a mesh with ``shard_map``: every
device runs ``kernels.aggregate.packed_weighted_sum`` over its client
shard (coefficients travel with their rows) and a single fp32 ``psum``
over the dense partials merges the shards — wire bytes never cross
devices un-aggregated, only one dense tree per device does (the ROADMAP's
"shard aggregation across devices for million-client fan-in").

``fanin_weighted_sum`` is the single entry point: mesh-less (or a C that
does not divide the axis) degrades to one kernel launch on the default
device; every (shape, mesh) signature is compiled exactly once through an
``lru_cache`` of jitted closures, so the trace count is inspectable
(``fanin_trace_count``) and bounded by the aggregator's bucket set.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels.aggregate import BLOCK_ROWS, packed_weighted_sum
from repro.kernels.vote import packed_vote_counts

try:  # jax ≥ 0.5 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def _fanin_axis(mesh: Mesh) -> str:
    """The mesh axis the client dimension shards over ("data" when present
    — clients are the data-parallel resource — else the first axis)."""
    return "data" if "data" in mesh.axis_names else mesh.axis_names[0]


@functools.lru_cache(maxsize=None)
def _build(c: int, rows: int, block_rows: int, interpret: bool,
           mesh: Mesh | None, axis: str | None):
    if mesh is not None:
        n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if mesh is None or n_shards == 1 or c % n_shards:
        @jax.jit
        def run(stacked, coeffs):
            return packed_weighted_sum(
                stacked, coeffs, block_rows=block_rows, interpret=interpret
            )
        return run

    def shard(stacked, coeffs):
        part = packed_weighted_sum(
            stacked, coeffs, block_rows=block_rows, interpret=interpret
        )
        return jax.lax.psum(part, axis)

    # check_rep=False: pallas_call has no replication rule; the psum above
    # establishes the replicated output explicitly.
    return jax.jit(_shard_map(
        shard, mesh=mesh, in_specs=(P(axis), P(axis)), out_specs=P(),
        check_rep=False,
    ))


@functools.lru_cache(maxsize=None)
def _build_vote(c: int, rows: int, block_rows: int, interpret: bool,
                mesh: Mesh | None, axis: str | None):
    if mesh is not None:
        n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if mesh is None or n_shards == 1 or c % n_shards:
        @jax.jit
        def run(stacked, coeffs):
            return packed_vote_counts(
                stacked, coeffs, block_rows=block_rows, interpret=interpret
            )
        return run

    def shard(stacked, coeffs):
        part = packed_vote_counts(
            stacked, coeffs, block_rows=block_rows, interpret=interpret
        )
        # vote masses are plain weighted sums over the client axis, so the
        # same psum merge as the mean path applies.
        return jax.lax.psum(part, axis)

    return jax.jit(_shard_map(
        shard, mesh=mesh, in_specs=(P(axis), P(axis)), out_specs=P(),
        check_rep=False,
    ))


def fanin_vote_counts(
    stacked,
    coeffs,
    *,
    mesh: Mesh | None = None,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Weighted −1/+1 vote masses per coordinate, C-sharded over ``mesh``.

    Same staging contract as ``fanin_weighted_sum``; returns
    (2, 4·R·LANES) fp32 [minus_mass, plus_mass], replicated.
    """
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    c, rows, _ = stacked.shape
    axis = _fanin_axis(mesh) if mesh is not None else None
    fn = _build_vote(c, rows, block_rows, interp, mesh, axis)
    return fn(jnp.asarray(stacked), jnp.asarray(coeffs, jnp.float32))


def fanin_weighted_sum(
    stacked,
    coeffs,
    *,
    mesh: Mesh | None = None,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Σ_c coeffs[c]·unpack(stacked[c]), C-sharded over ``mesh`` when given.

    stacked: (C, R, LANES) uint8 flat-packed 2-bit codes; coeffs: (C,) f32.
    Returns the flat fp32 weighted sum (length 4·R·LANES), replicated.
    """
    interp = (jax.default_backend() != "tpu") if interpret is None else interpret
    c, rows, _ = stacked.shape
    axis = _fanin_axis(mesh) if mesh is not None else None
    fn = _build(c, rows, block_rows, interp, mesh, axis)
    return fn(jnp.asarray(stacked), jnp.asarray(coeffs, jnp.float32))


def fanin_trace_count() -> int:
    """Number of distinct compiled fan-in signatures this process has built
    — the aggregator's bucketing keeps this bounded by the bucket set."""
    return _build.cache_info().currsize
