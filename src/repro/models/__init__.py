"""Architecture zoo: dense / MoE / SSM / hybrid / VLM / audio transformers,
all expressed as scan-over-layers pure functions for O(1)-in-depth compile.
"""

from repro.models.transformer import (
    init_params,
    forward,
    init_cache,
    decode_step,
    loss_fn,
    param_count,
)

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "decode_step",
    "loss_fn",
    "param_count",
]
