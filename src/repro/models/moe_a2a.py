"""Expert-parallel MoE via shard_map + all_to_all — the optimized dispatch.

Why: the GSPMD scatter-based dispatch (moe.py) is correct and simple, but
XLA lowers the cross-shard scatter-add into FULL-BUFFER all-reduces of the
(E, C, D) dispatch buffer — measured 2.2 TB/device/step on
qwen3-moe × train_4k (EXPERIMENTS.md §Perf A). The inherent communication
of top-k dispatch is only the k routed copies of each token; this module
moves exactly that via all_to_all:

  per device (T_loc tokens, E_loc = E/n_ep experts):
    1. route locally; destination device = expert // E_loc,
    2. LOCAL scatter into a (n_ep, C_send, D) send buffer (+ an int32
       buffer carrying each slot's local-expert index; 0 = empty),
    3. tiled all_to_all over the EP axis (both buffers),
    4. LOCAL scatter by local-expert index → (E_loc, C_loc, D), grouped
       GEMMs (einsum over the local expert dim),
    5. all_to_all back, local gather + gate-weighted combine.

Capacity semantics: per-(src,dst) queue C_send = T_loc·k/n_ep·cf and
per-local-expert queue C_loc = recv/E_loc·cf; overflow drops (GShard
semantics, like moe.py but applied per queue).

Must run inside a shard_map that is MANUAL over (batch_axes ∪ {ep_axis});
``transformer.forward`` arranges that when cfg.moe_impl == "a2a".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import act_fn


# ---------------------------------------------------------------------------
# int8-quantized all_to_all (beyond-paper, paper-inspired): the dispatch
# payload is activations, so we quantize each slot to int8 with a per-slot
# fp32 scale before it crosses the wire — 2× less EP traffic than bf16 (the
# paper's "compress what crosses the slow link" applied to expert routing).
# Backward quantizes the returning cotangents the same way (the tiled (0,0)
# all_to_all is its own transpose).
# ---------------------------------------------------------------------------


def _q8(x):
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantized_all_to_all(x, axis: str):
    q, s = _q8(x)
    qq = jax.lax.all_to_all(q, axis, 0, 0, tiled=True)
    ss = jax.lax.all_to_all(s, axis, 0, 0, tiled=True)
    return (qq.astype(jnp.float32) * ss).astype(x.dtype)


def _qa2a_fwd(x, axis):
    return quantized_all_to_all(x, axis), None


def _qa2a_bwd(axis, _res, g):
    q, s = _q8(g)
    qq = jax.lax.all_to_all(q, axis, 0, 0, tiled=True)
    ss = jax.lax.all_to_all(s, axis, 0, 0, tiled=True)
    return ((qq.astype(jnp.float32) * ss).astype(g.dtype),)


quantized_all_to_all.defvjp(_qa2a_fwd, _qa2a_bwd)


def _fill_queue(values, dest, keep_mask, n_queues, capacity, extra=None):
    """Scatter values (N, D) into (n_queues, capacity, D) by dest (N,).

    Returns (buffer, pos, keep) where pos is each value's queue slot.
    extra: optional int payload (N,) scattered into (n_queues, capacity).
    """
    n = dest.shape[0]
    onehot = jax.nn.one_hot(dest, n_queues, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # (N,)
    keep = keep_mask & (pos < capacity)
    safe_pos = jnp.where(keep, pos, 0)
    safe_dest = jnp.where(keep, dest, 0)
    buf = jnp.zeros((n_queues, capacity) + values.shape[1:], values.dtype)
    buf = buf.at[safe_dest, safe_pos].add(
        jnp.where(keep.reshape((n,) + (1,) * (values.ndim - 1)), values, 0)
    )
    ebuf = None
    if extra is not None:
        ebuf = jnp.zeros((n_queues, capacity), jnp.int32)
        ebuf = ebuf.at[safe_dest, safe_pos].max(jnp.where(keep, extra, 0))
    return buf, ebuf, safe_pos, keep


def moe_a2a(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
    n_experts: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    ep_axis: str = "model",
    data_axes: tuple = ("data",),
    wire_dtype: str = "bf16",   # "bf16" | "int8" dispatch payload
) -> tuple[jax.Array, jax.Array]:
    """x: (B_loc, S, D) per-shard activations → (out, aux). Call inside the
    manual shard_map region (transformer.forward sets it up)."""
    act = act_fn(activation)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    from repro.compat import axis_size
    n_ep = axis_size(ep_axis)
    e_loc = n_experts // n_ep

    # ---- 1. local routing (router weights are replicated) ----------------
    logits = (xt @ params["router"]).astype(jnp.float32)  # (T_loc, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)               # (T_loc, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balance aux loss over the GLOBAL batch (pmean over data axes).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32), axis=0)
    aux = n_experts * jnp.sum(me * ce)
    for ax in data_axes:
        aux = jax.lax.pmean(aux, ax)

    # ---- 2. local scatter into per-destination send queues ---------------
    flat_e = idx.reshape(-1)                                # (T_loc·k,)
    tok_id = jnp.repeat(jnp.arange(t), top_k)
    dest = flat_e // e_loc                                  # device owning it
    e_local_idx = flat_e % e_loc
    c_send = max(int(t * top_k / n_ep * capacity_factor), top_k)
    c_send = -(-c_send // 8) * 8
    send, send_e, pos_send, keep = _fill_queue(
        xt[tok_id], dest, jnp.ones_like(dest, bool), n_ep, c_send,
        extra=e_local_idx + 1,                              # 0 = empty slot
    )

    # ---- 3. EP all_to_all (the ONLY cross-device traffic) ----------------
    if wire_dtype == "int8":
        recv = quantized_all_to_all(send, ep_axis)
    else:
        recv = jax.lax.all_to_all(send, ep_axis, 0, 0, tiled=True)
    recv_e = jax.lax.all_to_all(send_e, ep_axis, 0, 0, tiled=True)

    # ---- 4. regroup by local expert, grouped GEMMs ------------------------
    rflat = recv.reshape(n_ep * c_send, d)
    reflat = recv_e.reshape(n_ep * c_send)                  # 0=empty, 1..E_loc
    # local regroup at cf=1.0: the send-side capacity factor already absorbs
    # routing imbalance; padding again here just multiplies empty-slot GEMM
    # work (measured +56% expert FLOPs at cf=1.25², §Perf A iter-3).
    c_loc = max(int(n_ep * c_send / e_loc), 8)
    c_loc = min(-(-c_loc // 8) * 8, n_ep * c_send)
    buf, _, pos_loc, keep_loc = _fill_queue(
        rflat, jnp.maximum(reflat - 1, 0), reflat > 0, e_loc, c_loc
    )

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    out_e = jnp.einsum("ecf,efd->ecd", act(g) * h, params["w_out"])

    # ---- 5. return trip + combine -----------------------------------------
    back = jnp.zeros_like(rflat)
    safe_e = jnp.where(keep_loc, jnp.maximum(reflat - 1, 0), 0)
    gathered = out_e[safe_e, jnp.where(keep_loc, pos_loc, 0)]
    back = jnp.where(keep_loc[:, None], gathered, 0).reshape(n_ep, c_send, d)
    if wire_dtype == "int8":
        res = quantized_all_to_all(back, ep_axis)     # (n_ep, C_send, D)
    else:
        res = jax.lax.all_to_all(back, ep_axis, 0, 0, tiled=True)

    per_copy = res[jnp.where(keep, dest, 0), jnp.where(keep, pos_send, 0)]
    per_copy = jnp.where(keep[:, None], per_copy, 0)
    combined = jnp.zeros((t, d), x.dtype).at[tok_id].add(
        (per_copy * gates.reshape(-1)[:, None]).astype(x.dtype)
    )

    if "shared" in params:
        sp = params["shared"]
        hs = act(xt @ sp["w_gate"]) * (xt @ sp["w_in"])
        combined = combined + hs @ sp["w_out"]

    return combined.reshape(b, s, d), aux.astype(jnp.float32)
