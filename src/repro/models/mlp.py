"""Dense feed-forward blocks: gated (SwiGLU/GeGLU) and plain (GELU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, dense_init, matmul


def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp(params: dict, x: jax.Array, activation: str = "silu") -> jax.Array:
    act = act_fn(activation)
    h = matmul(x, params["w_in"])
    if "w_gate" in params:
        h = act(matmul(x, params["w_gate"])) * h
    else:
        h = act(h)
    return matmul(h, params["w_out"])
