"""Mamba2 block — SSD (state-space duality) chunked scan + O(1) decode.

Follows the minimal SSD formulation of Dao & Gu (arXiv:2405.21060):

    h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t        y_t = C_t h_t + D x_t

Training/prefill uses the chunked algorithm (quadratic within a chunk of Q
tokens, linear across chunks via an inter-chunk state recurrence); decode is
a single state update. n_groups = 1 (B/C shared across heads).

Block layout (d_inner = expand·d_model, P = d_inner/n_heads, N = d_state):
    in_proj : D → [z(d_inner), x(d_inner), B(N), C(N), dt(H)]
    conv1d  : causal depthwise width-W over concat(x, B, C)
    SSD core, gated RMSNorm(y · silu(z)), out_proj : d_inner → D
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm


def d_inner_of(d_model: int, expand: int) -> int:
    return expand * d_model


def init_mamba(key, d_model: int, n_heads: int, d_state: int, expand: int,
               conv_width: int, dtype):
    d_in = d_inner_of(d_model, expand)
    conv_ch = d_in + 2 * d_state
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_in + 2 * d_state + n_heads), dtype),
        "conv_w": dense_init(ks[1], (conv_width, conv_ch), dtype, in_axis=0),
        "a_log": jnp.zeros((n_heads,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": jnp.zeros((d_in,), dtype),
        "out_proj": dense_init(ks[2], (d_in, d_model), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None):
    """Depthwise causal conv. x: (B,L,C), w: (W,C). state: (B,W-1,C) or None.
    Returns (y (B,L,C), new_state (B,W-1,C))."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, L+W-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return y, new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., Q) → (..., Q, Q) lower-tri cumulative sums Σ_{i=s+1..q} a_i."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., q, s)
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int):
    """SSD scan. x:(B,L,H,P) dt:(B,L,H) a:(H,)<0 b,c:(B,L,N) → y:(B,L,H,P),
    final_state:(B,H,P,N)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // chunk

    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    adt = dtf * a[None, None, None, :]                     # (B,nc,Q,H) ≤ 0
    adt_h = adt.transpose(0, 3, 1, 2)                      # (B,H,nc,Q)
    acs = jnp.cumsum(adt_h, axis=-1)                       # within-chunk cumsum
    xdt = xf * dtf[..., None]                              # Δ_t B_t x_t uses Δx

    # 1) intra-chunk (masked quadratic) term.
    lmat = jnp.exp(_segsum(adt_h))                         # (B,H,nc,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", cf, bf)         # (B,nc,Q,Q)
    y_diag = jnp.einsum(
        "bcqs,bhcqs,bcshp->bcqhp", scores, lmat, xdt
    )

    # 2) chunk-final states.
    decay_to_end = jnp.exp(acs[..., -1:] - acs)            # (B,H,nc,Q)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", bf, decay_to_end, xdt)

    # 3) inter-chunk recurrence (scan over chunks).
    chunk_decay = jnp.exp(acs[..., -1])                    # (B,H,nc)

    def step(h_prev, xs):
        s_c, dec_c = xs                                    # (B,H,P,N), (B,H)
        h_new = h_prev * dec_c[..., None, None] + s_c
        return h_new, h_prev

    states_t = states.transpose(1, 0, 2, 3, 4)             # (nc,B,H,P,N)
    decay_t = chunk_decay.transpose(2, 0, 1)               # (nc,B,H)
    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(step, h0, (states_t, decay_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)

    # 4) contribution of the carried-in state to each chunk.
    state_decay = jnp.exp(acs)                             # (B,H,nc,Q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", cf, h_prevs, state_decay)

    y = (y_diag + y_off).reshape(bsz, lp, h, p)[:, :l]
    return y, h_final


def ssd_decode(x, dt, a, b, c, state):
    """One-token state update. x:(B,H,P) dt:(B,H) b,c:(B,N) state:(B,H,P,N)."""
    da = jnp.exp(dt.astype(jnp.float32) * a[None, :])      # (B,H)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    state = state * da[..., None, None] + jnp.einsum("bhp,bn->bhpn", xdt, b.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    return y, state


def mamba_block(params, x, *, n_heads: int, d_state: int, expand: int,
                conv_width: int, chunk: int, cache: dict | None = None):
    """x: (B, L, D). cache: {"conv": (B,W-1,C), "ssd": (B,H,P,N)} for decode.
    Returns (out (B,L,D), new_cache)."""
    bsz, l, d = x.shape
    d_in = d_inner_of(d, expand)
    p = d_in // n_heads
    n = d_state

    zxbcdt = x @ params["in_proj"]
    z, xin, b, c, dt_raw = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, b, c = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    a = -jnp.exp(params["a_log"])                          # (H,) < 0
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    xh = xin.reshape(bsz, l, n_heads, p)
    if cache is not None and l == 1:
        y, new_ssd = ssd_decode(
            xh[:, 0], dt[:, 0], a, b[:, 0], c[:, 0], cache["ssd"].astype(jnp.float32)
        )
        y = y[:, None]
    else:
        y, new_ssd = ssd_chunked(xh, dt, a, b, c, chunk)

    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, l, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["gate_norm"])
    out = y @ params["out_proj"]
    new_cache = {"conv": new_conv, "ssd": new_ssd.astype(jnp.float32)}
    return out, new_cache
