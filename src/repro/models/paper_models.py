"""The paper's own experimental models (§V.A):

  - MLP: 784-30-20-10 feed-forward for MNIST (24,330 params — matches the
    paper's Table I "Parameter amount 24330" exactly: 784·30 + 30·20 + 20·10
    weight matrices + a 10-unit output bias; hidden layers are bias-free).
  - ResNet18*: the reduced ResNet18 with all conv channels at 64 (paper:
    607,050 params; ours matches the architecture definition — 8 basic
    blocks at 64 channels + linear head).

Implemented pure-JAX (lax.conv); used by the federated benchmarks to
reproduce Tables II–IV on synthetic stand-ins for MNIST/CIFAR10 (container
is offline — see benchmarks/README note)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


# --------------------------------------------------------------------------
# MLP (MNIST).
# --------------------------------------------------------------------------


def init_mlp_mnist(key, in_dim: int = 784, hidden=(30, 20), n_classes: int = 10,
                   dtype=jnp.float32):
    dims = (in_dim,) + tuple(hidden) + (n_classes,)
    ks = jax.random.split(key, len(dims) - 1)
    params = {
        f"fc{i}": {"w": dense_init(ks[i], (dims[i], dims[i + 1]), dtype)}
        for i in range(len(dims) - 1)
    }
    params[f"fc{len(dims) - 2}"]["bias"] = jnp.zeros((n_classes,), dtype)
    return params


def mlp_mnist(params, x):
    """x: (B, 784) → logits (B, 10)."""
    n = len(params)
    for i in range(n):
        p = params[f"fc{i}"]
        x = x @ p["w"]
        if "bias" in p:
            x = x + p["bias"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


# --------------------------------------------------------------------------
# ResNet18* (CIFAR10) — all conv channels reduced to 64 (paper §V.A).
# --------------------------------------------------------------------------


def _conv_init(key, k, cin, cout, dtype):
    fan_in = k * k * cin
    std = (2.0 / fan_in) ** 0.5
    return (jax.random.normal(key, (k, k, cin, cout), jnp.float32) * std).astype(dtype)


def init_resnet_cifar(key, n_classes: int = 10, width: int = 64, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 64))
    params: dict = {
        "stem": {"w": _conv_init(next(ks), 3, 3, width, dtype)},
        "stem_norm": {"scale": jnp.ones((width,), dtype), "bias": jnp.zeros((width,), dtype)},
    }
    for b in range(8):  # 4 stages × 2 basic blocks, all at `width` channels
        params[f"block{b}"] = {
            "conv1": {"w": _conv_init(next(ks), 3, width, width, dtype)},
            "norm1": {"scale": jnp.ones((width,), dtype), "bias": jnp.zeros((width,), dtype)},
            "conv2": {"w": _conv_init(next(ks), 3, width, width, dtype)},
            "norm2": {"scale": jnp.ones((width,), dtype), "bias": jnp.zeros((width,), dtype)},
        }
    params["head"] = {
        "w": dense_init(next(ks), (width, n_classes), dtype),
        "bias": jnp.zeros((n_classes,), dtype),
    }
    return params


def _conv(x, w, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _group_norm(x, p, groups: int = 8):
    """GroupNorm stand-in for BatchNorm (batch-stat-free → federated-friendly;
    avoids running-stat aggregation questions the paper doesn't address)."""
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    x = xg.reshape(b, h, w, c)
    return x * p["scale"] + p["bias"]


def resnet_cifar(params, x):
    """x: (B, 32, 32, 3) → logits (B, 10)."""
    h = _conv(x, params["stem"]["w"])
    h = jax.nn.relu(_group_norm(h, params["stem_norm"]))
    for b in range(8):
        p = params[f"block{b}"]
        stride = 2 if b in (2, 4, 6) else 1  # downsample at stage starts
        y = _conv(h, p["conv1"]["w"], stride)
        y = jax.nn.relu(_group_norm(y, p["norm1"]))
        y = _conv(y, p["conv2"]["w"])
        y = _group_norm(y, p["norm2"])
        if stride != 1:
            h = jax.lax.reduce_window(
                h, -jnp.inf, jax.lax.max, (1, stride, stride, 1),
                (1, stride, stride, 1), "SAME",
            )
        h = jax.nn.relu(h + y)
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head"]["w"] + params["head"]["bias"]
