"""Unified scan-over-layers LM covering every assigned architecture family.

Families:
  dense  — granite-20b, gemma3-4b (5:1 local:global sliding window),
           olmo-1b (non-parametric LN), yi-9b
  moe    — qwen3-moe-30b-a3b (128e top-8), deepseek-moe-16b (2 shared + 64 top-6)
  ssm    — mamba2-370m (SSD)
  hybrid — zamba2-1.2b (Mamba2 backbone + ONE shared attention block applied
           every `attn_every` layers, weights shared, per-application KV cache)
  vlm    — llama-3.2-vision-11b (cross-attn every 5th layer over patch embeds)
  audio  — hubert-xlarge (encoder-only; frontend is a stub — inputs are
           precomputed frame embeddings per the assignment)

Everything is a pure function of (cfg, params, inputs); layers are stacked on
a leading axis and driven by lax.scan so compile time/HLO size is O(1) in
depth. Heterogeneous structure inside the scan (global-vs-local window,
cross-attn layers, shared attn blocks) is expressed with per-layer scalar
scan inputs + lax.cond, NOT python branching, so one traced body serves all
layers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mamba2 as mb
from repro.models.attention import attention, init_attn
from repro.models.common import apply_norm, dense_init, embed_init, matmul
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe

Pytree = Any
BIG_WINDOW = 1 << 30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    norm: str = "rmsnorm"            # rmsnorm|layernorm|nonparam
    activation: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True
    causal: bool = True              # False → encoder-only
    tie_embeddings: bool = False
    # sliding window (gemma3)
    sliding_window: int = 0          # 0 = all-global
    global_every: int = 0            # every Nth layer is global
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0
    conv_width: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0              # hybrid: shared attn before every Nth layer
    # vlm
    cross_every: int = 0
    n_patches: int = 0
    # training
    aux_loss_coef: float = 0.01
    remat: str = "none"              # none|full|dots
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    # distribution: mesh axes that carry the batch dim of activations.
    # Empty = no sharding constraints (single-device tests). Set by the
    # launchers; forward() pins activations to P(batch_axes, UNCONSTRAINED…)
    # so reshapes (microbatching, loss flattening) cannot silently
    # replicate the batch (GSPMD otherwise loses the sharding).
    mesh_batch_axes: tuple = ()
    # mesh axis carrying the expert dim of MoE dispatch buffers (EP).
    mesh_ep_axis: str = ""
    # MoE dispatch implementation: "gspmd" (scatter, simple, XLA lowers the
    # cross-shard scatter to full-buffer all-reduces) or "a2a" (shard_map +
    # all_to_all — moves only the routed token copies; see moe_a2a.py and
    # EXPERIMENTS.md §Perf A for the measured 20×+ collective reduction).
    moe_impl: str = "gspmd"
    # dispatch payload dtype on the wire: "bf16" | "int8" (per-slot scales).
    moe_wire: str = "bf16"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def n_cross(self) -> int:
        return self.n_layers // self.cross_every if self.cross_every else 0

    @property
    def n_attn_apps(self) -> int:
        if not self.attn_every:
            return 0
        return (self.n_layers + self.attn_every - 1) // self.attn_every

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


# --------------------------------------------------------------------------
# Per-layer static patterns.
# --------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention lookback window (BIG = global)."""
    w = np.full((cfg.n_layers,), BIG_WINDOW, np.int32)
    if cfg.sliding_window:
        w[:] = cfg.sliding_window
        if cfg.global_every:
            w[cfg.global_every - 1 :: cfg.global_every] = BIG_WINDOW
    return w


def cross_gates(cfg: ModelConfig) -> np.ndarray:
    g = np.zeros((cfg.n_layers,), np.int32)
    if cfg.cross_every:
        g[cfg.cross_every - 1 :: cfg.cross_every] = 1
    return g


def attn_flags(cfg: ModelConfig) -> np.ndarray:
    f = np.zeros((cfg.n_layers,), np.int32)
    if cfg.attn_every:
        f[0 :: cfg.attn_every] = 1
    return f


# --------------------------------------------------------------------------
# Init.
# --------------------------------------------------------------------------


def _init_dense_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "attn": init_attn(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
        )
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(
            k2, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
            cfg.n_shared_experts, cfg.shared_d_ff, dtype,
        )
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    if cfg.norm != "nonparam":
        p["attn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _init_mamba_block(key, cfg: ModelConfig, dtype):
    p = {
        "mamba": mb.init_mamba(
            key, cfg.d_model, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_expand,
            cfg.conv_width, dtype,
        )
    }
    if cfg.norm != "nonparam":
        p["norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _init_cross_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "attn": init_attn(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
        ),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
        "gate_attn": jnp.zeros((), dtype),
        "gate_mlp": jnp.zeros((), dtype),
    }
    if cfg.norm != "nonparam":
        p["attn_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Pytree:
    """Build the parameter pytree (stacked layers). eval_shape-safe."""
    dtype = cfg.pdtype()
    keys = jax.random.split(key, 8)
    params: dict = {}
    params["embed"] = {"table": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype)}

    layer_keys = jax.random.split(keys[1], cfg.n_layers)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        params["blocks"] = jax.vmap(
            lambda k: _init_dense_block(k, cfg, dtype)
        )(layer_keys)
    elif cfg.family in ("ssm", "hybrid"):
        params["blocks"] = jax.vmap(
            lambda k: _init_mamba_block(k, cfg, dtype)
        )(layer_keys)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    if cfg.family == "vlm":
        cross_keys = jax.random.split(keys[2], cfg.n_cross)
        params["cross"] = jax.vmap(
            lambda k: _init_cross_block(k, cfg, dtype)
        )(cross_keys)

    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(keys[3])
        shared = {
            "attn": init_attn(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim, dtype,
            ),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
        }
        if cfg.norm != "nonparam":
            shared["attn_norm"] = jnp.zeros((cfg.d_model,), dtype)
            shared["mlp_norm"] = jnp.zeros((cfg.d_model,), dtype)
        params["shared_attn"] = shared

    if cfg.norm != "nonparam":
        params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[4], (cfg.d_model, cfg.vocab_size), dtype)
    return params


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))


# --------------------------------------------------------------------------
# Cache.
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Pytree:
    """Decode cache pytree. Structure depends on the family."""
    dtype = dtype or cfg.cdtype()
    hd = cfg.resolved_head_dim
    l = cfg.n_layers
    cache: dict = {}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        cache["k"] = jnp.zeros((l, batch, max_seq, cfg.n_kv_heads, hd), dtype)
        cache["v"] = jnp.zeros((l, batch, max_seq, cfg.n_kv_heads, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        d_in = mb.d_inner_of(cfg.d_model, cfg.ssm_expand)
        conv_ch = d_in + 2 * cfg.ssm_state
        p = d_in // cfg.ssm_heads
        cache["conv"] = jnp.zeros((l, batch, cfg.conv_width - 1, conv_ch), dtype)
        cache["ssd"] = jnp.zeros(
            (l, batch, cfg.ssm_heads, p, cfg.ssm_state), jnp.float32
        )
    if cfg.family == "hybrid":
        a = cfg.n_attn_apps
        cache["attn_k"] = jnp.zeros((a, batch, max_seq, cfg.n_kv_heads, hd), dtype)
        cache["attn_v"] = jnp.zeros((a, batch, max_seq, cfg.n_kv_heads, hd), dtype)
    return cache


# --------------------------------------------------------------------------
# Layer bodies.
# --------------------------------------------------------------------------


def _attn_kwargs(cfg: ModelConfig):
    return dict(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope,
        causal=cfg.causal,
    )


def _dense_layer(cfg, bp, x, window, kv, pos):
    """One dense/moe/vlm/audio layer. kv = (k,v) slices or None."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(x, bp.get("attn_norm"), cfg.norm)
    attn_out, new_kv = attention(
        bp["attn"], h, window=window, cache=kv, pos=pos, **_attn_kwargs(cfg)
    )
    x = x + attn_out
    h = apply_norm(x, bp.get("mlp_norm"), cfg.norm)
    if cfg.family == "moe":
        if cfg.moe_impl == "a2a" and cfg.mesh_ep_axis:
            mo, aux = _moe_a2a_shardmapped(cfg, bp["moe"], h)
        else:
            mo, aux = moe(
                bp["moe"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, activation=cfg.activation,
                ep_axis=cfg.mesh_ep_axis, batch_axes=cfg.mesh_batch_axes,
            )
        x = x + mo
    else:
        x = x + mlp(bp["mlp"], h, cfg.activation)
    return x, new_kv, aux


def _moe_a2a_shardmapped(cfg, mp, x):
    """Run the all_to_all MoE inside a shard_map manual over
    (batch_axes ∪ {ep_axis}); expert weights enter EP-split, everything
    else replicated (FSDP shards re-gather here — normal per-layer FSDP)."""
    from repro.models.moe_a2a import moe_a2a

    P = jax.sharding.PartitionSpec
    bax = tuple(cfg.mesh_batch_axes)
    ep = cfg.mesh_ep_axis
    x_spec = P(bax if bax else None, None, None)
    pspecs = {
        "router": P(),
        "w_in": P(ep, None, None),
        "w_gate": P(ep, None, None),
        "w_out": P(ep, None, None),
    }
    if "shared" in mp:
        pspecs["shared"] = {k: P() for k in mp["shared"]}

    def fn(xx, pp):
        return moe_a2a(
            pp, xx, top_k=cfg.top_k, n_experts=cfg.n_experts,
            capacity_factor=cfg.capacity_factor, activation=cfg.activation,
            ep_axis=ep, data_axes=bax, wire_dtype=cfg.moe_wire,
        )

    from repro.compat import shard_map

    return shard_map(
        fn, in_specs=(x_spec, pspecs), out_specs=(x_spec, P()),
        axis_names=set(bax) | {ep}, check_vma=False,
    )(x, mp)


def _cross_layer(cfg, cp, x, vision):
    h = apply_norm(x, cp.get("attn_norm"), cfg.norm)
    co, _ = attention(cp["attn"], h, kv_source=vision, **_attn_kwargs(cfg))
    x = x + jnp.tanh(cp["gate_attn"]) * co
    h = apply_norm(x, cp.get("mlp_norm"), cfg.norm)
    x = x + jnp.tanh(cp["gate_mlp"]) * mlp(cp["mlp"], h, cfg.activation)
    return x


def _shared_attn_layer(cfg, sp, x, kv, pos):
    h = apply_norm(x, sp.get("attn_norm"), cfg.norm)
    ao, new_kv = attention(sp["attn"], h, cache=kv, pos=pos, **_attn_kwargs(cfg))
    x = x + ao
    h = apply_norm(x, sp.get("mlp_norm"), cfg.norm)
    x = x + mlp(sp["mlp"], h, cfg.activation)
    return x, new_kv


def _mamba_layer(cfg, bp, x, states):
    h = apply_norm(x, bp.get("norm"), cfg.norm)
    mo, new_states = mb.mamba_block(
        bp["mamba"], h,
        n_heads=cfg.ssm_heads, d_state=cfg.ssm_state, expand=cfg.ssm_expand,
        conv_width=cfg.conv_width, chunk=cfg.ssm_chunk, cache=states,
    )
    return x + mo, new_states


def constrain_batch(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Pin dim-0 of an activation to the batch mesh axes (no-op when
    cfg.mesh_batch_axes is empty)."""
    if not cfg.mesh_batch_axes or x.ndim < 2:
        return x
    u = jax.sharding.PartitionSpec.UNCONSTRAINED
    spec = jax.sharding.PartitionSpec(
        tuple(cfg.mesh_batch_axes), *([u] * (x.ndim - 1))
    )
    return jax.lax.with_sharding_constraint(x, spec)


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# Forward.
# --------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array | None = None,
    *,
    embeds: jax.Array | None = None,
    vision_embeds: jax.Array | None = None,
    cache: Pytree | None = None,
    pos: jax.Array | int = 0,
):
    """Returns (logits f32 (B,S,V), new_cache (or None), aux_loss scalar)."""
    cdt = cfg.cdtype()
    if embeds is not None:
        x = embeds.astype(cdt)
    else:
        x = params["embed"]["table"][tokens].astype(cdt)
    x = constrain_batch(cfg, x)
    use_cache = cache is not None

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        windows = jnp.asarray(layer_windows(cfg))
        gates = jnp.asarray(cross_gates(cfg))
        cross_stack = params.get("cross")
        vis = vision_embeds.astype(cdt) if vision_embeds is not None else None

        def body(carry, xs):
            if use_cache:
                bp, w, g, kc, vc = xs
            else:
                bp, w, g = xs
                kc = vc = None
            x, cross_idx = carry
            x = constrain_batch(cfg, x)
            kv = (kc, vc) if use_cache else None
            x, new_kv, aux = _dense_layer(cfg, bp, x, w, kv, pos)
            if cross_stack is not None:
                def do_cross(x):
                    cp = jax.tree_util.tree_map(
                        lambda t: jax.lax.dynamic_index_in_dim(
                            t, cross_idx, 0, keepdims=False
                        ),
                        cross_stack,
                    )
                    return _cross_layer(cfg, cp, x, vis)
                x = jax.lax.cond(g > 0, do_cross, lambda x: x, x)
                cross_idx = cross_idx + g
            ys = (new_kv[0], new_kv[1], aux) if use_cache else aux
            return (x, cross_idx), ys

        body = _maybe_remat(cfg, body)
        xs = (params["blocks"], windows, gates)
        if use_cache:
            xs = xs + (cache["k"], cache["v"])
        (x, _), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)), xs)
        if use_cache:
            new_k, new_v, aux = ys
            new_cache = {"k": new_k, "v": new_v}
        else:
            aux = ys
            new_cache = None
        aux = jnp.sum(aux)

    elif cfg.family == "ssm":
        def body(carry, xs):
            (x,) = carry
            x = constrain_batch(cfg, x)
            if use_cache:
                bp, conv_c, ssd_c = xs
                states = {"conv": conv_c, "ssd": ssd_c}
            else:
                (bp,) = xs
                states = None
            x, new_states = _mamba_layer(cfg, bp, x, states)
            # only emit state ys when serving: stacking 48 layers of SSD
            # states during training wastes GBs of scan-output memory.
            ys = (new_states["conv"], new_states["ssd"]) if use_cache else None
            return (x,), ys

        body = _maybe_remat(cfg, body)
        xs = (params["blocks"], cache["conv"], cache["ssd"]) if use_cache else (params["blocks"],)
        (x,), ys = jax.lax.scan(body, (x,), xs)
        new_cache = {"conv": ys[0], "ssd": ys[1]} if use_cache else None
        aux = jnp.zeros((), jnp.float32)

    elif cfg.family == "hybrid":
        flags = jnp.asarray(attn_flags(cfg))
        shared = params["shared_attn"]

        def body(carry, xs):
            if use_cache:
                bp, flag, conv_c, ssd_c = xs
                states = {"conv": conv_c, "ssd": ssd_c}
                x, app_idx, ak, av = carry
            else:
                bp, flag = xs
                states = None
                x, app_idx = carry[0], carry[1]
                ak = av = None

            def do_attn(operands):
                x, ak, av = operands
                if use_cache:
                    kc = jax.lax.dynamic_index_in_dim(ak, app_idx, 0, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(av, app_idx, 0, keepdims=False)
                    x, new_kv = _shared_attn_layer(cfg, shared, x, (kc, vc), pos)
                    ak = jax.lax.dynamic_update_index_in_dim(ak, new_kv[0], app_idx, 0)
                    av = jax.lax.dynamic_update_index_in_dim(av, new_kv[1], app_idx, 0)
                else:
                    x, _ = _shared_attn_layer(cfg, shared, x, None, pos)
                return x, ak, av

            def no_attn(operands):
                return operands

            x = constrain_batch(cfg, x)
            if use_cache:
                x, ak, av = jax.lax.cond(flag > 0, do_attn, no_attn, (x, ak, av))
            else:
                x, _, _ = jax.lax.cond(flag > 0, do_attn, no_attn, (x, None, None))
            app_idx = app_idx + flag
            x, new_states = _mamba_layer(cfg, bp, x, states)
            carry = (x, app_idx, ak, av) if use_cache else (x, app_idx)
            ys = (new_states["conv"], new_states["ssd"]) if use_cache else None
            return carry, ys

        body = _maybe_remat(cfg, body)
        if use_cache:
            xs = (params["blocks"], flags, cache["conv"], cache["ssd"])
            carry0 = (x, jnp.zeros((), jnp.int32), cache["attn_k"], cache["attn_v"])
            (x, _, ak, av), ys = jax.lax.scan(body, carry0, xs)
            new_cache = {"conv": ys[0], "ssd": ys[1], "attn_k": ak, "attn_v": av}
        else:
            xs = (params["blocks"], flags)
            (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.int32)), xs)
            new_cache = None
        aux = jnp.zeros((), jnp.float32)

    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    x = constrain_batch(cfg, x)
    x = apply_norm(x, params.get("final_norm"), cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = matmul(x, params["lm_head"])
    # logits stay in compute dtype: upcasting here would make every backward
    # cotangent f32 (2× activation-grad bandwidth + 2× TP all-reduce bytes);
    # the loss upcasts inside log_softmax instead.
    return constrain_batch(cfg, logits), new_cache, aux


def decode_step(cfg, params, tokens, cache, pos, *, vision_embeds=None):
    """One-token incremental decode. tokens: (B, 1). pos: int32 fill length."""
    logits, new_cache, _ = forward(
        cfg, params, tokens, vision_embeds=vision_embeds, cache=cache, pos=pos
    )
    return logits, new_cache


def loss_fn(cfg: ModelConfig, params: Pytree, batch: dict):
    """Mean next-token (or per-frame) cross entropy + MoE aux loss."""
    logits, _, aux = forward(
        cfg,
        params,
        batch.get("tokens"),
        embeds=batch.get("embeds"),
        vision_embeds=batch.get("vision_embeds"),
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = -jnp.mean(ll)
    return ce + cfg.aux_loss_coef * aux, {"ce": ce, "aux": aux}
