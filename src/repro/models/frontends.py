"""Modality frontend STUBS (per the assignment: [audio]/[vlm] entries specify
the transformer BACKBONE only; input_specs() provides precomputed frame/patch
embeddings).

These helpers only define the SHAPES the backbone consumes and a synthetic
generator for smoke tests/examples; no real conv feature extractor / ViT is
run."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frame_embeds_spec(batch: int, frames: int, d_model: int, dtype):
    """HuBERT-style: 20 ms frames already projected to d_model."""
    return jax.ShapeDtypeStruct((batch, frames, d_model), dtype)


def vision_patch_embeds_spec(batch: int, n_patches: int, d_model: int, dtype):
    """Llama-3.2-Vision-style: patch embeddings from the (stubbed) ViT."""
    return jax.ShapeDtypeStruct((batch, n_patches, d_model), dtype)


def synth_audio_frames(key, batch: int, frames: int, d_model: int, dtype=jnp.float32):
    return jax.random.normal(key, (batch, frames, d_model), dtype) * 0.02


def synth_vision_patches(key, batch: int, n_patches: int, d_model: int, dtype=jnp.float32):
    return jax.random.normal(key, (batch, n_patches, d_model), dtype) * 0.02
