"""Attention: GQA/MQA, sliding-window, cross-attention, KV-cache decode.

Two softmax paths:
  - ``_attend_naive`` — materializes scores; used for short sequences.
  - ``_attend_flash`` — jnp online-softmax scanned over KV blocks; O(block)
    memory, used when S_kv > flash_threshold. This is the memory-bounded
    path that lets 32k-prefill cells fit HBM (the scores tensor for yi-9b at
    32k would otherwise be ~68 GB per batch row).

All functions are shape-polymorphic over batch and work for prefill
(S_q == S_kv), decode (S_q == 1 vs cached S_kv) and cross-attention
(no causal mask, separate KV source).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, matmul

NEG_INF = -1e30
FLASH_THRESHOLD = 2048
FLASH_BLOCK = 1024


class AttnParams(NamedTuple):
    wq: jax.Array  # (D, H*hd)
    wk: jax.Array  # (D, Hkv*hd)
    wv: jax.Array  # (D, Hkv*hd)
    wo: jax.Array  # (H*hd, D)


def init_attn(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(k2, (d_model, n_kv_heads * head_dim), dtype),
        "wv": dense_init(k3, (d_model, n_kv_heads * head_dim), dtype),
        "wo": dense_init(k4, (n_heads * head_dim, d_model), dtype),
    }


def _mask_bias(q_pos, k_pos, *, causal: bool, window) -> jax.Array:
    """(Sq, Sk) additive bias. window is a traced scalar (tokens of lookback);
    window >= S disables the sliding constraint (global layer)."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        ok = ok & (dk <= dq)
    ok = ok & (dq - dk < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_naive(q, k, v, q_pos, k_pos, *, causal, window, k_len=None):
    """q: (B,Sq,Hkv,G,hd)  k,v: (B,Sk,Hkv,hd) → (B,Sq,Hkv,G,hd).

    bf16 operands feed the dot directly with f32 ACCUMULATION
    (preferred_element_type) instead of pre-casting: an explicit astype(f32)
    materializes a full copy of the KV cache (measured 9.2 GB/step on
    gemma3 decode_32k — §Perf B iter-2); the MXU upcasts for free."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
    if k_len is not None:  # decode: mask unwritten cache slots
        bias = bias + jnp.where(k_pos[None, :] < k_len, 0.0, NEG_INF)
    logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


def _attend_flash(q, k, v, q_pos, k_pos, *, causal, window, k_len=None,
                  block: int = FLASH_BLOCK):
    """Online-softmax over KV blocks (lax.scan); O(Sq·block) live memory."""
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    n_blocks = (sk + block - 1) // block
    pad = n_blocks * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10**9))
    kb = k.reshape(b, n_blocks, block, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_blocks, block, hkv, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(n_blocks, block)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = q.astype(jnp.float32)

    # checkpoint the block body: scan-AD would otherwise SAVE every block's
    # (Sq × block) f32 logits for the backward pass — recomputing them is
    # the whole point of flash attention (≈0.5 GB/layer saved at 4k train).
    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kc, vc, pc = xs
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kc.astype(jnp.float32)) * scale
        bias = _mask_bias(q_pos, pc, causal=causal, window=window)
        if k_len is not None:
            bias = bias + jnp.where(pc[None, :] < k_len, 0.0, NEG_INF)
        logits = logits + bias
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,Sq,Hkv,G,hd)


def attention(
    params: dict,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    use_rope: bool = True,
    causal: bool = True,
    window=None,
    kv_source: jax.Array | None = None,
    cache: tuple | None = None,
    pos: jax.Array | int = 0,
):
    """Full attention block (no norm/residual — the caller owns those).

    cache: (k_cache, v_cache) each (B, S_max, Hkv, hd); pos = current fill.
           When given, behaves as a decode/incremental step: new KV are
           written at [pos : pos+Sq] and attention runs over the cache.
    kv_source: if given, cross-attention (keys/values from this tensor, no
           causal mask, no cache write).
    Returns (out, new_cache).
    """
    b, sq, d = x.shape
    g = n_heads // n_kv_heads
    q = matmul(x, params["wq"]).reshape(b, sq, n_kv_heads, g, head_dim)
    src = kv_source if kv_source is not None else x
    k = matmul(src, params["wk"]).reshape(b, src.shape[1], n_kv_heads, head_dim)
    v = matmul(src, params["wv"]).reshape(b, src.shape[1], n_kv_heads, head_dim)

    q_pos = pos + jnp.arange(sq)
    if kv_source is not None:
        k_pos = jnp.arange(src.shape[1])
        causal = False
        use_rope = False
    else:
        k_pos = pos + jnp.arange(src.shape[1])

    if use_rope:
        qr = q.reshape(b, sq, n_heads, head_dim)
        qr = apply_rope(qr, jnp.broadcast_to(q_pos, (b, sq)), rope_theta)
        q = qr.reshape(b, sq, n_kv_heads, g, head_dim)
        k = apply_rope(k, jnp.broadcast_to(k_pos, (b, k.shape[1])), rope_theta)

    k_len = None
    new_cache = None
    if cache is not None:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
        k, v = k_cache, v_cache
        k_pos = jnp.arange(k.shape[1])
        k_len = pos + sq
        new_cache = (k_cache, v_cache)

    if window is None:
        window = jnp.asarray(1 << 30, jnp.int32)

    # flash (block-scanned online softmax) only pays when the QUERY side is
    # long: it bounds the (Sq × Sk) score memory. For decode (Sq == 1) the
    # scores are tiny AND the block reshape breaks GSPMD's tracking of a
    # sequence-sharded cache — XLA then all-gathers the whole cache per
    # layer (measured 77.6 GB/step on gemma3 decode_32k, §Perf B iter-1).
    if k.shape[1] > FLASH_THRESHOLD and sq > 1:
        out = _attend_flash(q, k, v, q_pos, k_pos, causal=causal, window=window,
                            k_len=k_len)
    else:
        out = _attend_naive(q, k, v, q_pos, k_pos, causal=causal, window=window,
                            k_len=k_len)
    out = out.reshape(b, sq, n_heads * head_dim)
    return matmul(out, params["wo"]), new_cache
