"""Mixture-of-Experts layer: top-k token-choice routing, optional shared
experts (DeepSeekMoE), scatter-based capacity dispatch.

Dispatch strategy (GSPMD/EP-friendly — DESIGN.md §4):
  1. route: (T, E) logits → top-k gates/indices per token,
  2. scatter each selected (token, expert) copy into a dense (E, C, D) buffer
     at position = rank-within-expert (computed by a cumsum over the one-hot
     routing matrix). Tokens beyond capacity C are dropped (standard GShard
     semantics; C = T·k/E · capacity_factor).
  3. batched expert GEMMs via einsum('ecd,edf->ecf') — the E dim carries the
     expert-parallel sharding ('model' axis) so GSPMD turns the scatter /
     gather into the EP all-to-all,
  4. gather results back per (token, k) and combine with gate weights.

This materializes (E, C, D) ≈ k·capacity_factor× the token activations —
the inherent top-k dispatch cost — and nothing quadratic in E.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, dense_init


def init_moe(
    key,
    d_model: int,
    moe_d_ff: int,
    n_experts: int,
    n_shared_experts: int,
    shared_d_ff: int,
    dtype,
):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), dtype),
        "w_gate": dense_init(ks[1], (n_experts, d_model, moe_d_ff), dtype, in_axis=-2),
        "w_in": dense_init(ks[2], (n_experts, d_model, moe_d_ff), dtype, in_axis=-2),
        "w_out": dense_init(ks[3], (n_experts, moe_d_ff, d_model), dtype, in_axis=-2),
    }
    if n_shared_experts > 0:
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], (d_model, shared_d_ff), dtype),
            "w_in": dense_init(kk[1], (d_model, shared_d_ff), dtype),
            "w_out": dense_init(kk[2], (shared_d_ff, d_model), dtype),
        }
    return p


def moe(
    params: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    ep_axis: str = "",
    batch_axes: tuple = (),
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out (B,S,D), aux_loss scalar).

    ep_axis: mesh axis carrying the expert dim of the dispatch buffer /
    expert GEMMs (expert parallelism); batch_axes shard the capacity dim.
    Both empty → no constraints (single-device tests)."""
    act = act_fn(activation)

    def _constrain_ecd(t):
        # E over the EP axis; capacity/feature replicated. Sharding C over
        # the data axes forces GSPMD to redistribute the token scatter
        # (measured 7× peak-memory blowup at 1M tokens) — E-only is the
        # stable layout: the scatter becomes the EP all-to-all.
        if not ep_axis:
            return t
        spec = jax.sharding.PartitionSpec(ep_axis, None, None)
        return jax.lax.with_sharding_constraint(t, spec)

    def _constrain_tok(t):
        # token-major tensors: the (B,S)→(T,) flatten can drop the batch
        # sharding; pin dim 0 back onto the batch axes (32k-prefill
        # dispatch intermediates are tens of GB when replicated).
        if not batch_axes:
            return t
        u = jax.sharding.PartitionSpec.UNCONSTRAINED
        spec = jax.sharding.PartitionSpec(
            tuple(batch_axes), *([u] * (t.ndim - 1)))
        return jax.lax.with_sharding_constraint(t, spec)
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    xt = _constrain_tok(x.reshape(t, d))

    logits = (xt @ params["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = e * jnp.sum(me * ce)

    capacity = max(int(t * top_k / e * capacity_factor), top_k)
    if capacity >= 256:  # keep the capacity dim shardable over batch axes
        capacity = -(-capacity // 256) * 256

    # rank of each (token, k) copy within its expert queue.
    flat_e = idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # rank per expert
    pos = jnp.sum(pos * onehot, axis=-1)  # (T*k,)
    keep = pos < capacity

    tok_id = jnp.repeat(jnp.arange(t), top_k)
    # scatter token activations into (E, C, D)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    safe_pos = jnp.where(keep, pos, 0)
    updates = _constrain_tok(
        jnp.where(keep[:, None], xt[tok_id], 0).astype(x.dtype)
    )
    buf = buf.at[flat_e, safe_pos].add(updates)
    buf = _constrain_ecd(buf)

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    h = act(g) * h
    out_e = _constrain_ecd(
        jnp.einsum("ecf,efd->ecd", h, params["w_out"])  # (E, C, D)
    )

    # gather each copy's result and combine with gates.
    res = _constrain_tok(out_e[flat_e, safe_pos])  # (T*k, D)
    res = jnp.where(keep[:, None], res, 0)
    combined = _constrain_tok(
        jnp.zeros((t, d), x.dtype).at[tok_id].add(
            (res * gates.reshape(-1)[:, None]).astype(x.dtype)
        )
    )

    if "shared" in params:
        sp = params["shared"]
        hs = act(xt @ sp["w_gate"]) * (xt @ sp["w_in"])
        combined = combined + hs @ sp["w_out"]

    return combined.reshape(b, s, d), aux.astype(jnp.float32)
