"""Shared model building blocks: norms, RoPE, activations, initializers,
and the weight-matmul dispatch that lets serving run on packed 2-bit
weights without touching the layer code."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, w) -> jax.Array:
    """``x @ w`` with weight-type dispatch.

    Dense arrays take the ordinary contraction. ``PackedTernary`` weights
    (the zero-copy serve path) route through the packed Pallas kernel —
    the 2-bit codes are unpacked in VMEM, never as a dense array in HBM.
    The dispatch is static: the weight's type is part of the pytree
    structure, so under jit/scan exactly one branch is traced.
    """
    from repro.kernels.repack import PackedTernary, packed_matmul

    if isinstance(w, PackedTernary):
        return packed_matmul(x, w)
    return x @ w


def rms_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    """RMSNorm; scale=None gives the non-parametric variant (OLMo §paper)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    """LayerNorm (mean-centred); scale=None → non-parametric (OLMo-style)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def apply_norm(x: jax.Array, scale: jax.Array | None, kind: str) -> jax.Array:
    if kind == "rmsnorm":
        return rms_norm(x, scale)
    if kind == "layernorm":
        return layer_norm(x, scale)
    if kind == "nonparam":
        return layer_norm(x, None)
    raise ValueError(f"unknown norm kind {kind!r}")


# --------------------------------------------------------------------------
# Rotary position embeddings.
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotate (..., S, H, D) by per-token positions (..., S)."""
    d = x.shape[-1]
    inv_freq = rope_frequencies(d, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, D/2)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Activations & init.
# --------------------------------------------------------------------------


def act_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "relu": jax.nn.relu,
    }[name]


def dense_init(key, shape, dtype, in_axis: int = -2) -> jax.Array:
    """Lecun-normal style init with fan_in from the given axis."""
    fan_in = shape[in_axis]
    std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
