"""Federated partitioners reproducing the paper's §V data distributions:

  - IID (N_c = #classes): every client gets an IID subset,
  - non-IID by label (N_c classes per client, paper §V.C / Fig. 9),
  - unbalanced sizes parameterized by β = median(S_N)/max(S_N) (§V.E, eq. 29).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClientDataset:
    x: np.ndarray
    y: np.ndarray
    client_id: int

    def __len__(self):
        return len(self.y)

    def batches(self, batch_size: int, rng: np.random.Generator, epochs: int = 1):
        for _ in range(epochs):
            order = rng.permutation(len(self.y))
            for i in range(0, len(order) - batch_size + 1, batch_size):
                sel = order[i : i + batch_size]
                yield self.x[sel], self.y[sel]


def partition_iid(x, y, n_clients: int, seed: int = 0) -> list[ClientDataset]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(y))
    shards = np.array_split(order, n_clients)
    return [ClientDataset(x[s], y[s], k) for k, s in enumerate(shards)]


def partition_noniid(x, y, n_clients: int, n_classes_per_client: int,
                     seed: int = 0) -> list[ClientDataset]:
    """Label-partitioned: each client holds samples from N_c classes; the
    union of clients covers the dataset (paper Fig. 9 construction)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    # assign classes to clients round-robin with wraparound so every client
    # has exactly N_c classes and all samples are used.
    client_classes = [
        [classes[(k * n_classes_per_client + j) % len(classes)]
         for j in range(n_classes_per_client)]
        for k in range(n_clients)
    ]
    # shard each class's samples among clients that own it.
    owners: dict[int, list[int]] = {int(c): [] for c in classes}
    for k, cc in enumerate(client_classes):
        for c in cc:
            owners[int(c)].append(k)
    parts: dict[int, list[np.ndarray]] = {k: [] for k in range(n_clients)}
    for c, ks in owners.items():
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        for holder, shard in zip(ks, np.array_split(idx, len(ks))):
            parts[holder].append(shard)
    out = []
    for k in range(n_clients):
        sel = np.concatenate(parts[k]) if parts[k] else np.empty((0,), np.int64)
        rng.shuffle(sel)
        out.append(ClientDataset(x[sel], y[sel], k))
    return out


def partition_unbalanced(x, y, n_clients: int, beta: float,
                         seed: int = 0) -> list[ClientDataset]:
    """Unbalanced sizes with median/max ratio ≈ β (paper eq. 29): one client
    holds the bulk; the rest share the remainder roughly equally."""
    assert 0 < beta <= 1
    rng = np.random.default_rng(seed)
    n = len(y)
    # sizes: one "max" client of size M, others at median m = β·M.
    # M + (K-1)·β·M = n  →  M = n / (1 + (K-1)β)
    m_max = n / (1 + (n_clients - 1) * beta)
    sizes = [int(m_max)] + [int(m_max * beta)] * (n_clients - 1)
    sizes[-1] += n - sum(sizes)  # absorb rounding
    order = rng.permutation(n)
    out, ofs = [], 0
    for k, s in enumerate(sizes):
        sel = order[ofs : ofs + s]
        ofs += s
        out.append(ClientDataset(x[sel], y[sel], k))
    return out


def emd_to_global(clients: list[ClientDataset], n_classes: int) -> float:
    """Mean earth-mover's distance between client label distributions and the
    global distribution (the divergence driver of Lemma 4.1/4.2)."""
    all_y = np.concatenate([c.y for c in clients])
    global_p = np.bincount(all_y, minlength=n_classes) / len(all_y)
    ds = []
    for c in clients:
        if len(c) == 0:
            continue
        p = np.bincount(c.y, minlength=n_classes) / len(c)
        ds.append(0.5 * np.abs(p - global_p).sum())  # total-variation EMD on labels
    return float(np.mean(ds))
