"""Synthetic datasets (the container is offline — MNIST/CIFAR10 are replaced
by learnable synthetic stand-ins with the same shapes/class counts; relative
comparisons between FedAvg and T-FedAvg carry over, absolute accuracies are
dataset-specific and noted as such in EXPERIMENTS.md)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_classification(
    key,
    n_samples: int,
    n_classes: int = 10,
    dim: int = 784,
    image_hw: tuple | None = None,
    noise: float = 2.0,
    n_test: int = 0,
):
    """Mixture-of-Gaussians classification set (learnable but not trivial).

    Returns (x, y) — or (x, y, x_test, y_test) when n_test > 0, with BOTH
    splits drawn from the same class centers. x is (N, dim) or (N, H, W, C)
    if image_hw is given.
    """
    kc, kx, ky = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_classes, dim)) * 1.0
    total = n_samples + n_test
    y = jax.random.randint(ky, (total,), 0, n_classes)
    x = centers[y] + noise * jax.random.normal(kx, (total, dim))
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.int32)
    if image_hw is not None:
        h, w, c = image_hw
        assert h * w * c == dim
        x = x.reshape(total, h, w, c)
    if n_test:
        return x[:n_samples], y[:n_samples], x[n_samples:], y[n_samples:]
    return x, y


def synthetic_tokens(key, n_tokens: int, vocab: int, order: int = 2):
    """Markov-ish token stream: next token depends on a hash of the previous
    ``order`` tokens — gives a learnable LM signal (loss ↓ from uniform)."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    trans = rng.integers(0, vocab, size=(vocab, 16), dtype=np.int32)
    toks = np.empty((n_tokens,), np.int32)
    toks[0] = rng.integers(vocab)
    state = int(toks[0])
    for i in range(1, n_tokens):
        if rng.random() < 0.15:  # noise branch keeps entropy > 0
            toks[i] = rng.integers(vocab)
        else:
            toks[i] = trans[state % vocab, state % 16]
        state = state * 31 + int(toks[i])
    return toks


def token_batches(tokens: np.ndarray, batch: int, seq: int, *, start: int = 0):
    """Iterate (tokens, labels) next-token batches; deterministic cursor for
    checkpoint/resume (the cursor is part of the train checkpoint)."""
    span = batch * (seq + 1)
    i = start
    while True:
        if (i + 1) * span > len(tokens):
            i = 0
        chunk = tokens[i * span : (i + 1) * span].reshape(batch, seq + 1)
        yield {"tokens": jnp.asarray(chunk[:, :-1]), "labels": jnp.asarray(chunk[:, 1:])}, i + 1
        i += 1
