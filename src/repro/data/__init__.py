"""Data substrate: synthetic generators + federated partitioners."""

from repro.data.synthetic import (
    synthetic_classification,
    synthetic_tokens,
    token_batches,
)
from repro.data.federated import (
    partition_iid,
    partition_noniid,
    partition_unbalanced,
    ClientDataset,
    emd_to_global,
)

__all__ = [
    "synthetic_classification", "synthetic_tokens", "token_batches",
    "partition_iid", "partition_noniid", "partition_unbalanced",
    "ClientDataset", "emd_to_global",
]
