"""Batched serving front end over the packed-ternary artifact, under load.

``launch.serve`` answers exactly one probe; this module is the long-lived
front end the edge actually runs:

  - **Request batching**: a closed loop coalesces every request that has
    arrived by the time the previous forward finished — up to
    ``max_batch`` — into ONE forward pass, so every weight matmul in the
    batch shares a single pass through the 2-bit ``ternary_matmul``
    kernel. Per-launch overhead (and, on real hardware, the packed-weight
    HBM read) amortizes across the batch; ``benchmarks/bench_serve.py``
    measures the resulting p50/p99-vs-QPS surface.

  - **LRU dequant-cache**: the artifact keeps its NON-matmul wire leaves
    (fp16-downcast embeddings/norms/biases, non-matmul ternary) in wire
    form and materializes them dense on demand through ``LRUDequantCache``
    — a byte-bounded cache, so serving memory is
    packed-weights + cache-capacity instead of the full dense model. Hot
    leaves (touched every forward) stay resident; a tight budget degrades
    to decode-per-forward instead of OOM. Hit/miss/eviction counts are
    exported to the bench record.

The matmul weights themselves are ``PackedTernary`` (2-bit kernel layout,
never dequantized) exactly as in ``launch.serve --packed``.

Demo::

    PYTHONPATH=src python -m repro.launch.serve_loop \
        --requests 64 --qps 200 --max-batch 8
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.wire import decode_update, encode_update
from repro.core import FTTQConfig
from repro.core.compression import (
    CodecSpec,
    compress_pytree,
    decode_wire_leaf,
    is_wire_leaf,
)
from repro.core.ternary import TernaryTensor
from repro.kernels.repack import PackedTernary, repack_to_kernel_layout

Pytree = Any


# --------------------------------------------------------------------------
# LRU dequant-cache.
# --------------------------------------------------------------------------


class LRUDequantCache:
    """Byte-bounded LRU over dense materializations of wire leaves.

    ``get(key, wire_leaf)`` returns the dense array, decoding on miss and
    evicting least-recently-used entries until the live bytes fit
    ``capacity_bytes``. A leaf larger than the whole capacity is decoded,
    returned, and immediately dropped (counted as an eviction) — the cache
    degrades to decode-per-use, it never refuses to serve.
    ``capacity_bytes=0`` disables retention entirely (every get is a miss).
    """

    def __init__(self, capacity_bytes: int):
        if capacity_bytes < 0:
            raise ValueError(f"capacity_bytes must be ≥ 0, got {capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self._entries: OrderedDict[str, tuple[Any, int]] = OrderedDict()
        self.live_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: str, wire_leaf) -> Any:
        hit = self._entries.get(key)
        if hit is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            return hit[0]
        self.misses += 1
        dense = decode_wire_leaf(wire_leaf)
        nbytes = int(np.asarray(dense).nbytes)
        self._entries[key] = (dense, nbytes)
        self.live_bytes += nbytes
        while self.live_bytes > self.capacity_bytes and self._entries:
            _k, (_v, nb) = self._entries.popitem(last=False)
            self.live_bytes -= nb
            self.evictions += 1
        return dense

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "capacity_bytes": self.capacity_bytes,
            "live_bytes": self.live_bytes,
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": (self.hits / total) if total else 0.0,
        }


# --------------------------------------------------------------------------
# The serving engine.
# --------------------------------------------------------------------------


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


class ServeEngine:
    """Long-lived packed-ternary inference engine with lazy wire leaves.

    The deploy artifact round-trips the real wire codec (compress →
    serialize → decode, CRC verified); 2-D/3-D ternary records repack into
    the 2-bit kernel layout, every OTHER wire leaf stays in wire form and
    is materialized through the LRU dequant-cache at forward time.
    """

    def __init__(self, model_cfg, params: Pytree, *,
                 fttq: FTTQConfig | None = None, residual: str = "fp16",
                 max_batch: int = 8, cache_capacity_bytes: int = 1 << 24):
        if max_batch < 1:
            raise ValueError(f"max_batch must be ≥ 1, got {max_batch}")
        self.model_cfg = model_cfg
        self.max_batch = int(max_batch)
        self.cache = LRUDequantCache(cache_capacity_bytes)
        fttq = fttq if fttq is not None else FTTQConfig()

        wire_tree, _ = compress_pytree(
            params, CodecSpec(kind="ternary", residual=residual, fttq=fttq)
        )
        blob = encode_update(wire_tree)
        self.wire_bytes = len(blob)
        decoded = decode_update(blob)

        # split: matmul ternary → PackedTernary (2-bit, resident); every
        # other wire leaf stays lazy behind the dequant-cache.
        self.packed_weight_bytes = 0
        self.lazy_wire_bytes_dense = 0   # dense size the cache may hold
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(
            decoded, is_leaf=is_wire_leaf
        )
        self._template: list = []        # PackedTernary | _Lazy | dense array
        self._lazy_keys: list[str] = []
        for path, leaf in flat:
            if isinstance(leaf, TernaryTensor) and len(leaf.shape) in (2, 3):
                p = repack_to_kernel_layout(leaf)
                self.packed_weight_bytes += (
                    int(p.packed.size) + int(np.asarray(p.w_q).nbytes)
                )
                self._template.append(p)
            elif is_wire_leaf(leaf):
                key = _path_str(path)
                self._lazy_keys.append(key)
                self.lazy_wire_bytes_dense += int(
                    np.asarray(decode_wire_leaf(leaf)).nbytes
                )
                self._template.append(_Lazy(key, leaf))
            else:
                self._template.append(leaf)
        self.forwards = 0
        self.requests_served = 0

    # -- params resolution -------------------------------------------------

    def resolve_params(self) -> Pytree:
        """The servable tree for ONE forward: lazy wire leaves go through
        the LRU cache (hot layers stay resident), the rest pass through."""
        leaves = [
            self.cache.get(x.key, x.wire) if isinstance(x, _Lazy) else x
            for x in self._template
        ]
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- inference ---------------------------------------------------------

    def forward(self, tokens: jax.Array) -> jax.Array:
        """One batched forward through the packed kernels; returns logits."""
        from repro.models.transformer import forward as model_forward

        b = int(tokens.shape[0])
        if b > self.max_batch:
            raise ValueError(f"batch {b} exceeds max_batch {self.max_batch}")
        params = self.resolve_params()
        logits, _cache, _aux = model_forward(self.model_cfg, params, tokens)
        jax.block_until_ready(logits)
        self.forwards += 1
        self.requests_served += b
        return logits

    def stats(self) -> dict:
        return {
            "wire_bytes": self.wire_bytes,
            "packed_weight_bytes": self.packed_weight_bytes,
            "lazy_wire_bytes_dense": self.lazy_wire_bytes_dense,
            "max_batch": self.max_batch,
            "forwards": self.forwards,
            "requests_served": self.requests_served,
            "cache": self.cache.stats(),
        }


@dataclasses.dataclass
class _Lazy:
    """A wire leaf the engine materializes through the dequant-cache."""

    key: str
    wire: Any


# --------------------------------------------------------------------------
# Closed-loop load generation.
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LoadReport:
    """One (offered QPS, max_batch) point of the latency surface."""

    offered_qps: float
    achieved_qps: float
    n_requests: int
    max_batch: int
    p50_ms: float
    p99_ms: float
    mean_ms: float
    mean_batch: float
    wall_s: float               # busy wall-clock of the serving loop
    cache: dict

    def row(self) -> dict:
        return dataclasses.asdict(self)


def run_closed_loop(engine: ServeEngine, *, n_requests: int,
                    offered_qps: float, prompt_len: int = 8,
                    seed: int = 0) -> LoadReport:
    """Drive the engine with a Poisson open-arrival schedule, coalescing
    everything that arrived while the previous forward ran (up to
    ``max_batch``) into the next one.

    The arrival clock is VIRTUAL (deterministic schedule from ``seed``);
    service times are REAL measured forward wall times, so latency =
    completion − arrival mixes a reproducible load pattern with honest
    compute costs. Under-offered load → batches of 1 and latency ≈ forward
    time; past saturation → batches grow toward ``max_batch`` and the
    p99 reflects queueing.
    """
    if n_requests < 1 or offered_qps <= 0:
        raise ValueError("need n_requests ≥ 1 and offered_qps > 0")
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / offered_qps, size=n_requests)
    arrivals = np.cumsum(inter)
    vocab = int(engine.model_cfg.vocab_size)
    prompts = rng.integers(0, vocab, size=(n_requests, prompt_len))

    # one warmup forward per batch size is NOT taken: the loop itself pays
    # first-touch costs exactly like a cold server would; run a single
    # warmup at batch 1 so jit/interpret setup doesn't distort every point.
    engine.forward(jnp.asarray(prompts[:1]))

    now = 0.0
    busy_s = 0.0
    done = 0
    latencies = np.empty(n_requests)
    batch_sizes = []
    while done < n_requests:
        if arrivals[done] > now:
            now = float(arrivals[done])      # idle until the next arrival
        take = done + 1
        while (take < n_requests and take - done < engine.max_batch
               and arrivals[take] <= now):
            take += 1
        batch = jnp.asarray(prompts[done:take])
        t0 = time.perf_counter()
        engine.forward(batch)
        dt = time.perf_counter() - t0
        busy_s += dt
        now += dt
        latencies[done:take] = now - arrivals[done:take]
        batch_sizes.append(take - done)
        done = take

    lat_ms = latencies * 1e3
    return LoadReport(
        offered_qps=float(offered_qps),
        achieved_qps=float(n_requests / now),
        n_requests=int(n_requests),
        max_batch=engine.max_batch,
        p50_ms=float(np.percentile(lat_ms, 50)),
        p99_ms=float(np.percentile(lat_ms, 99)),
        mean_ms=float(lat_ms.mean()),
        mean_batch=float(np.mean(batch_sizes)),
        wall_s=float(busy_s),
        cache=engine.cache.stats(),
    )


def demo_model(d_model: int = 32, n_layers: int = 2, vocab: int = 64):
    """The tiny dense LM the CLI demo and the bench serve."""
    from repro.models.transformer import ModelConfig, init_params

    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=n_layers,
                      d_model=d_model, vocab_size=vocab, n_heads=4,
                      n_kv_heads=2, d_ff=2 * d_model)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Closed-loop load against the packed-ternary serve engine"
    )
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--cache-bytes", type=int, default=1 << 24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, params = demo_model(args.d_model, args.layers)
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         cache_capacity_bytes=args.cache_bytes)
    report = run_closed_loop(engine, n_requests=args.requests,
                             offered_qps=args.qps,
                             prompt_len=args.prompt_len, seed=args.seed)
    print(json.dumps({"engine": engine.stats(), "load": report.row()},
                     indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
