"""Batched serving driver: prefill + decode with optional TERNARY weights —
the paper's deployed-inference path (§III: "at inference stage, only the
quantized model is needed for prediction").

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --ternary
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.core import FTTQConfig
from repro.core import fttq as F
from repro.models.transformer import (
    decode_step, forward, init_cache, init_params, param_count,
)


def ternary_deploy(params, cfg: FTTQConfig):
    """Quantize → dequantize the model for deployment (what a 2-bit edge
    checkpoint loads to; on TPU the packed path uses kernels.ternary_matmul)."""
    wq = F.init_wq_tree(params, cfg)
    return F.quantize_tree(params, wq, cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ternary", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(cfg) / 1e6:.1f}M params, "
          f"ternary={args.ternary}")
    if args.ternary:
        params = ternary_deploy(params, FTTQConfig())

    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    vision = (jax.random.normal(jax.random.PRNGKey(2),
                                (b, cfg.n_patches, cfg.d_model)) * 0.02
              if cfg.family == "vlm" else None)
    max_seq = s + args.gen

    # prefill
    cache = init_cache(cfg, b, max_seq)
    t0 = time.time()
    logits, cache, _ = forward(cfg, params, prompts, vision_embeds=vision,
                               cache=cache, pos=0)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {b}×{s} tokens in {t_prefill * 1e3:.0f} ms")

    # decode
    @jax.jit
    def step(params, tok, cache, pos):
        return decode_step(cfg, params, tok, cache, pos, vision_embeds=vision)

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, tok, cache, s + i)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode: {args.gen - 1} steps × batch {b} in {dt * 1e3:.0f} ms "
          f"({b * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    gen = jnp.concatenate(out, axis=1)
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
