"""Batched serving driver: prefill + decode with optional TERNARY weights —
the paper's deployed-inference path (§III: "at inference stage, only the
quantized model is needed for prediction").

With ``--ternary`` the deployment artifact is built through the
``repro.comm.wire`` codec: the model is compressed to the ternary wire
format, SERIALIZED, and decoded back before serving — so the reported
download size is the measured edge-checkpoint byte count and the served
weights provably round-tripped the wire.

``--packed`` additionally serves ZERO-COPY: the decoded ternary records are
repacked byte-wise into the ``(K//4, N)`` layout ``kernels.ternary_matmul``
consumes, and every weight matmul runs through the Pallas kernel. No
unpacked int8 codes and no dense fp32 weight copy are ever materialized on
the deploy path — weight HBM traffic is 16× below fp32, which is the whole
game for memory-bound decode. ``--residual-codec fp16`` downcasts the
non-quantizable leaves (biases, norms) on the wire as well.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --ternary --packed
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.comm import ChannelConfig, ClientLink, decode_update, encode_update
from repro.core import CodecSpec, FTTQConfig, decompress_pytree
from repro.core import compression as comp
from repro.kernels.repack import packed_params_from_wire
from repro.models.transformer import (
    decode_step, forward, init_cache, init_params, param_count,
)


def ternary_deploy(
    params,
    cfg: FTTQConfig,
    *,
    packed: bool = False,
    residual: str = "none",
    link: ClientLink | None = None,
    loss_rate: float = 0.0,
):
    """Compress → serialize → decode the deployment artifact.

    Returns (served_params, wire_bytes, est_download_s, link). With
    ``packed=False`` the artifact dequantizes to dense arrays (reference
    path); with ``packed=True`` ternary records repack straight into the
    ``(K//4, N)`` kernel layout and stay 2-bit in HBM. ``loss_rate`` runs
    the download estimate through the lossy channel model (chunk loss +
    retransmission), the same scenario knob the federated servers use.
    """
    spec = CodecSpec(kind="ternary", residual=residual, fttq=cfg)
    wire_tree, _ = comp.compress_pytree(params, spec)
    blob = encode_update(wire_tree)
    decoded = decode_update(blob)
    if packed:
        served = packed_params_from_wire(decoded)
    else:
        served = decompress_pytree(decoded)
    if link is None:
        c = ChannelConfig()
        link = ClientLink(0, c.mean_bandwidth_bytes_s, c.base_latency_s, 1.0)
    if loss_rate > 0.0:
        from repro.comm import Channel

        chan = Channel(
            ChannelConfig(latency_jitter_s=0.0, loss_rate=loss_rate,
                          chunk_bytes=4096),
            1, seed=0,
        )
        chan.links[0] = link   # meter over THIS link, not a fresh draw
        return served, len(blob), chan.transfer(0, len(blob), "down"), link
    return served, len(blob), link.transfer_time(len(blob)), link


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ternary", action="store_true")
    ap.add_argument("--packed", action="store_true",
                    help="serve through kernels.ternary_matmul on the packed "
                         "2-bit layout (requires --ternary)")
    ap.add_argument("--residual-codec", default="none",
                    choices=["none", "fp16", "bf16", "topk"],
                    help="codec for the non-quantizable wire leaves")
    ap.add_argument("--loss-rate", type=float, default=0.0,
                    help="edge-link packet loss for the download estimate "
                         "(chunk retransmission through comm.channel)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    if args.packed and not args.ternary:
        raise SystemExit("--packed requires --ternary")

    from repro.configs import get_config, get_reduced

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    if args.packed and cfg.family not in ("dense", "vlm", "audio"):
        raise SystemExit(
            f"--packed serves attention+mlp weights; family {cfg.family!r} "
            "routes its hot matmuls elsewhere (moe/ssm) — use --ternary alone"
        )
    params = init_params(cfg, jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(cfg) / 1e6:.1f}M params, "
          f"ternary={args.ternary} packed={args.packed}")
    if args.ternary:
        fp_bytes = len(encode_update(params))
        served, wire_bytes, dl_s, link = ternary_deploy(
            params, FTTQConfig(), packed=args.packed,
            residual=args.residual_codec, loss_rate=args.loss_rate,
        )
        print(f"edge checkpoint: {wire_bytes / 1e6:.2f} MB on the wire "
              f"(fp32 {fp_bytes / 1e6:.2f} MB, {fp_bytes / wire_bytes:.1f}× "
              f"smaller), est. download {dl_s:.1f}s "
              f"@ {link.bandwidth_bytes_s / 1e6:.1f} MB/s")
        if args.packed:
            # correctness receipt: packed-kernel logits vs the dequantized
            # reference path (the reference copy exists only for this check;
            # compression is deterministic, so both deploys see one blob).
            ref_params, _, _, _ = ternary_deploy(
                params, FTTQConfig(), packed=False,
                residual=args.residual_codec,
            )
            probe = jax.random.randint(
                jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab_size)
            lp, _, _ = forward(cfg, served, probe)
            lr, _, _ = forward(cfg, ref_params, probe)
            diff = float(jnp.max(jnp.abs(lp - lr)))
            print(f"packed-vs-dequant logits: max |Δ| = {diff:.2e}")
        params = served

    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    vision = (jax.random.normal(jax.random.PRNGKey(2),
                                (b, cfg.n_patches, cfg.d_model)) * 0.02
              if cfg.family == "vlm" else None)
    max_seq = s + args.gen

    # prefill
    cache = init_cache(cfg, b, max_seq)
    t0 = time.time()
    logits, cache, _ = forward(cfg, params, prompts, vision_embeds=vision,
                               cache=cache, pos=0)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {b}×{s} tokens in {t_prefill * 1e3:.0f} ms")

    # decode
    @jax.jit
    def step(params, tok, cache, pos):
        return decode_step(cfg, params, tok, cache, pos, vision_embeds=vision)

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, tok, cache, s + i)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode: {args.gen - 1} steps × batch {b} in {dt * 1e3:.0f} ms "
          f"({b * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    gen = jnp.concatenate(out, axis=1)
    print("sample tokens:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
