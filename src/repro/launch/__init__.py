"""Launch layer: production mesh builders, step factories, multi-pod dry-run,
end-to-end train/serve drivers."""
