"""Production mesh builders.

A FUNCTION (not module-level constant) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS for 512 host devices before any
jax initialization; tests and benches see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16×16 = 256 chips per pod; 2 pods = 512.

    Axes: ("data", "model") single-pod; ("pod", "data", "model") multi-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (tests / elastic re-mesh after a pod loss)."""
    return jax.make_mesh(shape, axes)


def describe(mesh) -> dict:
    return {
        "axes": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
    }
