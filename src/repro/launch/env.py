"""Pinned runtime configuration for benchmarks.

Benchmark numbers are only comparable when the process environment is:
allocator churn, XLA log spam, and a surprise host-device count all move
the measured microseconds. ``pin_runtime()`` applies the standard fast
config ONCE, before jax initializes (the exemplar settings production
launchers use):

  - ``LD_PRELOAD`` tcmalloc when the library exists on the host (faster
    malloc for the allocation-heavy staging/packing paths) — applied by
    re-exec'ing the interpreter, since a preload cannot take effect after
    process start. Gated: hosts without tcmalloc simply skip it.
  - ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` raised so numpy's large
    staging buffers don't spam allocation warnings.
  - ``TF_CPP_MIN_LOG_LEVEL=4`` — no XLA/TSL chatter inside timed regions.
  - optional ``--xla_force_host_platform_device_count=N`` merged into
    ``XLA_FLAGS`` (only BEFORE jax is imported — forcing it later would
    silently not apply, so that is an error).

Import-order contract: call ``pin_runtime()`` before anything imports
jax. ``benchmarks/run.py`` does this on its first line; tests do NOT use
this module (they must see the real single-device CPU host, see
``tests/conftest.py``).
"""

from __future__ import annotations

import os
import sys

# re-exec guard: the env var survives the exec, the module global does not.
_REEXEC_MARKER = "REPRO_ENV_PINNED"

_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def find_tcmalloc() -> str | None:
    """First tcmalloc shared object present on this host, if any."""
    for path in _TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def _merge_xla_flag(flag: str) -> None:
    current = os.environ.get("XLA_FLAGS", "")
    key = flag.split("=", 1)[0]
    if key in current:
        return
    os.environ["XLA_FLAGS"] = f"{current} {flag}".strip()


def pin_runtime(
    *, host_devices: int = 0, tcmalloc: bool = True, reexec: bool = True,
) -> dict:
    """Apply the pinned bench runtime; returns what was applied.

    host_devices > 0 forces the XLA host-platform device count (requires
    jax to not be imported yet). ``tcmalloc=True`` preloads tcmalloc via
    one re-exec when the library exists and we aren't already running
    under it; ``reexec=False`` only reports what would happen.
    """
    applied: dict = {}
    os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                          "60000000000")
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    applied["tf_log_level"] = os.environ["TF_CPP_MIN_LOG_LEVEL"]

    if host_devices > 0:
        if "jax" in sys.modules:
            raise RuntimeError(
                "pin_runtime(host_devices=...) called after jax was "
                "imported — the device count would silently not apply"
            )
        _merge_xla_flag(
            f"--xla_force_host_platform_device_count={host_devices}"
        )
        applied["host_devices"] = host_devices

    lib = find_tcmalloc() if tcmalloc else None
    applied["tcmalloc"] = lib
    if lib and lib not in os.environ.get("LD_PRELOAD", ""):
        if reexec and not os.environ.get(_REEXEC_MARKER):
            os.environ[_REEXEC_MARKER] = "1"
            preload = os.environ.get("LD_PRELOAD", "")
            os.environ["LD_PRELOAD"] = f"{lib} {preload}".strip()
            sys.stdout.flush()
            sys.stderr.flush()
            os.execv(sys.executable, [sys.executable] + sys.argv)
        applied["tcmalloc"] = None     # present but not preloaded this run
    return applied
