"""Step factories for the dry-run and the serve driver.

  train  → TrainState step (QAT + optimizer; see train.trainer)
  prefill→ forward with a fresh KV cache (serving admission)
  decode → one-token incremental step against a filled cache
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm


def make_prefill_step(cfg: tfm.ModelConfig, max_seq: int, chunks: int = 1):
    """f(params, batch) → (next_token_logits, cache).

    chunks > 1 = chunked prefill (vLLM/SARATHI-style): the prompt is run
    through the cache in sequence chunks, dividing peak activation /
    MoE-dispatch memory by ``chunks`` at the cost of one extra cache pass
    per chunk. Top-8 MoE at 1M prompt tokens needs this to fit HBM."""

    def prefill(params, batch):
        first = batch.get("tokens", batch.get("embeds"))
        bsz, seq = first.shape[0], first.shape[1]
        if not cfg.causal:
            logits, _, _ = tfm.forward(
                cfg, params, batch.get("tokens"),
                embeds=batch.get("embeds"),
                vision_embeds=batch.get("vision_embeds"),
            )
            return logits, None

        cache = tfm.init_cache(cfg, bsz, max_seq, cfg.cdtype())
        n = max(1, min(chunks, seq))
        clen = seq // n
        logits = None
        for i in range(n):
            sl = slice(i * clen, (i + 1) * clen if i < n - 1 else seq)
            logits, cache, _ = tfm.forward(
                cfg,
                params,
                batch["tokens"][:, sl] if "tokens" in batch else None,
                embeds=batch["embeds"][:, sl] if "embeds" in batch else None,
                vision_embeds=batch.get("vision_embeds"),
                cache=cache,
                pos=i * clen,
            )
        return logits[:, -1:], cache

    return prefill


def make_decode_step(cfg: tfm.ModelConfig):
    """f(params, batch{tokens, cache, pos[, vision_embeds]}) → (logits, cache)."""

    def decode(params, batch):
        logits, cache = tfm.decode_step(
            cfg,
            params,
            batch["tokens"],
            batch["cache"],
            batch["pos"],
            vision_embeds=batch.get("vision_embeds"),
        )
        return logits, cache

    return decode
