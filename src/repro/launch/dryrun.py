import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) cell against the production mesh with
512 placeholder host devices, prove it fits (memory_analysis), and extract
the roofline raw terms (trip-count-aware HLO analysis + cost_analysis).

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh multi
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --variant pod_compressed

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>[__<variant>].json —
consumed by benchmarks/roofline.py and EXPERIMENTS.md §Dry-run/§Roofline.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, input_specs
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import init_params, param_count
from repro.optim import adam
from repro.parallel.sharding import batch_specs, param_specs
from repro.train import TrainerConfig, init_train_state, make_train_step

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # B/s
LINK_BW = 50e9           # B/s per ICI link

# gradient-accumulation chunks per arch for the train_4k cell (activation
# memory control; batch 256 must stay divisible by microbatches × DP shards).
MICROBATCHES = {
    "granite-20b": 16, "yi-9b": 8, "llama-3.2-vision-11b": 8,
    "qwen3-moe-30b-a3b": 8, "deepseek-moe-16b": 8, "gemma3-4b": 4,
    "hubert-xlarge": 4, "olmo-1b": 2, "zamba2-1.2b": 4, "mamba2-370m": 8,
}

# chunked prefill (steps.make_prefill_step): top-k MoE dispatch at 1M prompt
# tokens needs sequence-chunking to fit HBM.
PREFILL_CHUNKS = {"qwen3-moe-30b-a3b": 4, "deepseek-moe-16b": 2}

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _sharded_specs(tree, spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, spec_tree,
    )


def _pad_spec(spec: P, ndim: int, prefix=()) -> P:
    entries = tuple(prefix) + tuple(spec) + (None,) * (ndim - len(prefix) - len(spec))
    return P(*entries[:ndim])


def _train_state_specs(cfg, tcfg, optimizer, mesh, n_pods):
    state = jax.eval_shape(
        lambda k: init_train_state(cfg, tcfg, optimizer, k, n_pods=n_pods),
        jax.random.PRNGKey(0),
    )
    pspecs = param_specs(cfg, mesh)
    wq_specs = jax.tree_util.tree_map(lambda w: P(), state.wq) if state.wq is not None else None
    opt_specs = {"step": P(), "m": pspecs, "v": pspecs}
    res_specs = None
    if state.residuals is not None:
        res_specs = jax.tree_util.tree_map(
            lambda r, s: _pad_spec(s, r.ndim, prefix=("pod",)),
            state.residuals, pspecs,
        )
    spec_state = dataclasses.replace(
        state,
        params=pspecs, wq=wq_specs, opt_state=opt_specs,
        residuals=res_specs, step=P(),
    )
    sharded = jax.tree_util.tree_map(
        lambda l, s: None if l is None else jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
        state, spec_state,
        is_leaf=lambda x: x is None,
    )
    return sharded


def active_param_count(cfg) -> int:
    """N_active: MoE counts only top-k routed experts (6·N_active·D)."""
    n = param_count(cfg)
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
        n -= inactive
    return n


def model_flops(cfg, shape_name: str) -> float:
    spec = SHAPES[shape_name]
    n_act = active_param_count(cfg)
    d_tokens = spec.global_batch * spec.seq_len
    if spec.kind == "train":
        return 6.0 * n_act * d_tokens
    if spec.kind == "prefill":
        return 2.0 * n_act * d_tokens
    return 2.0 * n_act * spec.global_batch  # decode: one token per request


def build_cell(arch: str, shape_name: str, mesh, variant: str):
    """Returns (jitted_fn, example_args_specs, meta)."""
    spec = SHAPES[shape_name]
    is_train = spec.kind == "train"
    flags = set(variant.split("+")) if variant else {"baseline"}
    axes_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_pods = axes_sizes.get("pod", 1)
    # batch-carrying mesh axes for activation constraints; inside the
    # compressed (manual-over-pod) step only "data" remains auto.
    if "pod_compressed" in flags:
        bax = ("data",)
    else:
        bax = tuple(a for a in ("pod", "data") if a in axes_sizes)
    n_batch_shards = int(np.prod([axes_sizes[a] for a in bax])) if bax else 1
    if spec.global_batch % max(n_batch_shards, 1) or spec.global_batch < n_batch_shards:
        bax = ()  # e.g. long_500k batch=1: sequence-parallel cache instead
    cfg = get_config(
        arch,
        param_dtype="bfloat16",
        compute_dtype="bfloat16",
        remat="full" if is_train else "none",
        mesh_batch_axes=bax,
        mesh_ep_axis="model",
        # optimized defaults (§Perf A): shard_map all_to_all dispatch with
        # int8 wire; "moe_gspmd" / "moe_bf16" flags select the older paths.
        moe_impl="gspmd" if "moe_gspmd" in flags else "a2a",
        moe_wire="bf16" if "moe_bf16" in flags else "int8",
    )
    ispecs = input_specs(cfg, shape_name)
    bspecs = batch_specs(cfg, shape_name, mesh)
    batch_sharded = _sharded_specs(ispecs, bspecs, mesh)

    if is_train:
        # clamp: each microbatch must still cover every batch shard
        # (multi-pod halves the per-shard batch vs single-pod).
        micro = MICROBATCHES.get(arch, 1)
        if bax:
            micro = min(micro, spec.global_batch // n_batch_shards)
        tcfg = TrainerConfig(
            qat=True,
            pod_compression=("pod_compressed" in flags),
            error_feedback=("pod_compressed" in flags),
            microbatches=max(micro, 1),
        )
        optimizer = adam(1e-4)
        step = make_train_step(cfg, tcfg, optimizer, mesh)
        state_specs = _train_state_specs(cfg, tcfg, optimizer, mesh, n_pods)
        fn = jax.jit(step)
        args = (state_specs, batch_sharded)
    else:
        pspecs = param_specs(cfg, mesh)
        params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        params_sharded = _sharded_specs(params, pspecs, mesh)
        if spec.kind == "prefill":
            step = make_prefill_step(cfg, max_seq=spec.seq_len,
                                     chunks=PREFILL_CHUNKS.get(arch, 1))
            fn = jax.jit(step)
        else:
            step = make_decode_step(cfg)
            fn = jax.jit(step, donate_argnums=(1,))
        args = (params_sharded, batch_sharded)
    return fn, args, cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str, variant: str = "baseline",
             out_dir: str = ARTIFACT_DIR) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(mesh.devices.size)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "mesh_axes": describe(mesh)["axes"],
        "n_devices": n_dev,
    }
    try:
        fn, args, cfg = build_cell(arch, shape_name, mesh, variant)
        from repro.compat import set_mesh
        with set_mesh(mesh):
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze_hlo(compiled.as_text())

        flops_dev = hlo["flops_per_device"]
        bytes_dev = hlo["bytes_per_device"]
        coll_dev = hlo["collective_bytes_per_device"]
        compute_s = flops_dev / PEAK_FLOPS
        memory_s = bytes_dev / HBM_BW
        collective_s = coll_dev / LINK_BW
        terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
        bottleneck = max(terms, key=terms.get)
        mflops = model_flops(cfg, shape_name)
        record.update({
            "status": "ok",
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "param_count": param_count(cfg),
            "active_param_count": active_param_count(cfg),
            "memory": {
                "argument_bytes_per_device": ma.argument_size_in_bytes,
                "output_bytes_per_device": ma.output_size_in_bytes,
                "temp_bytes_per_device": ma.temp_size_in_bytes,
                "alias_bytes_per_device": ma.alias_size_in_bytes,
                "peak_estimate_gb": round(
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 1e9, 3),
            },
            "hlo": {
                "flops_per_device": flops_dev,
                "bytes_per_device": bytes_dev,
                "collective_bytes_per_device": coll_dev,
                "collective_breakdown": hlo["collective_breakdown"],
                "n_collective_ops_executed": hlo["n_collective_ops_executed"],
                "while_trip_counts": hlo["while_trip_counts"],
                "xla_cost_analysis_flops": ca.get("flops"),
            },
            "roofline": {
                "compute_term_s": compute_s,
                "memory_term_s": memory_s,
                "collective_term_s": collective_s,
                "bottleneck": bottleneck,
                "step_time_lower_bound_s": max(terms.values()),
                "model_flops": mflops,
                "useful_flops_ratio": (
                    mflops / (flops_dev * n_dev) if flops_dev else None
                ),
                "mfu_upper_bound": (
                    mflops / (max(terms.values()) * n_dev * PEAK_FLOPS)
                    if max(terms.values()) > 0 else None
                ),
            },
        })
    except Exception as e:  # record failures — they are bugs to fix
        record.update({
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
    os.makedirs(out_dir, exist_ok=True)
    tag = "" if variant == "baseline" else f"__{variant}"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{tag}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=float)
    return record


def cells(mesh_kinds=("single", "multi")):
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            ok, reason = applicable(cfg, shape_name)
            if not ok:
                continue
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    if args.list:
        for c in cells():
            print(*c)
        return

    if args.all:
        todo = list(cells())
        if args.only_missing:
            def missing(c):
                p = os.path.join(args.out, f"{c[0]}__{c[1]}__{c[2]}.json")
                if not os.path.exists(p):
                    return True
                with open(p) as f:
                    return json.load(f).get("status") != "ok"
            todo = [c for c in todo if missing(c)]
        for arch, shape_name, mk in todo:
            r = run_cell(arch, shape_name, mk, out_dir=args.out)
            rf = r.get("roofline", {})
            print(f"[{r['status']:5s}] {arch} × {shape_name} × {mk} "
                  f"compile={r.get('compile_s', '-')}s "
                  f"bottleneck={rf.get('bottleneck', '-')} "
                  f"peak_gb={r.get('memory', {}).get('peak_estimate_gb', '-')}",
                  flush=True)
            if r["status"] != "ok":
                print(r.get("error"), flush=True)
        return

    r = run_cell(args.arch, args.shape, args.mesh, args.variant, out_dir=args.out)
    print(json.dumps(r, indent=1, default=float))


if __name__ == "__main__":
    main()
