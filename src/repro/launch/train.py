"""End-to-end training driver (deliverable b): QAT (FTTQ) LM pretraining
with checkpoint/restart, synthetic token data, and optional mesh execution.

CPU-scale example (~100M params, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300

Production pods would launch the same driver per host with a real mesh
(--mesh single|multi uses forced host devices only for demonstration;
on TPU the same code paths pick up the real topology).

XLA latency-hiding knobs used on real TPU (documented here; harmless on CPU):
    --xla_tpu_enable_latency_hiding_scheduler=true
    --xla_tpu_overlap_compute_collective_tc=true
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.synthetic import synthetic_tokens, token_batches
from repro.models.transformer import ModelConfig, param_count
from repro.optim import adam, warmup_cosine_schedule
from repro.train import (
    TrainerConfig, init_train_state, make_train_step,
    latest_step, restore_checkpoint, save_checkpoint,
)

PRESETS = {
    # ~100M-param dense LM for the end-to-end example.
    "100m": dict(name="lm-100m", family="dense", n_layers=12, d_model=768,
                 vocab_size=32768, n_heads=12, n_kv_heads=12, d_ff=3072),
    "10m": dict(name="lm-10m", family="dense", n_layers=6, d_model=256,
                vocab_size=8192, n_heads=8, n_kv_heads=4, d_ff=1024),
    "1m": dict(name="lm-1m", family="dense", n_layers=4, d_model=128,
               vocab_size=1024, n_heads=4, n_kv_heads=2, d_ff=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="1m", choices=list(PRESETS))
    ap.add_argument("--arch", default=None, help="use a reduced arch config instead")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--no-qat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.arch:
        cfg = get_reduced(args.arch)
    else:
        cfg = ModelConfig(**PRESETS[args.preset])
    print(f"model={cfg.name} params={param_count(cfg) / 1e6:.1f}M "
          f"qat={not args.no_qat}")

    tcfg = TrainerConfig(qat=not args.no_qat, pod_compression=False,
                         microbatches=args.microbatches)
    optimizer = adam(warmup_cosine_schedule(args.lr, 20, args.steps))
    state = init_train_state(cfg, tcfg, optimizer, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, tcfg, optimizer))

    toks = synthetic_tokens(jax.random.PRNGKey(1),
                            max(args.batch * (args.seq + 1) * 64, 200_000),
                            vocab=cfg.vocab_size)
    cursor = 0
    start = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, meta = restore_checkpoint(args.ckpt_dir, example_state=state)
        cursor = meta.get("data_cursor", 0)
        start = meta["step"]
        print(f"resumed from step {start} (cursor={cursor})")
    batches = token_batches(toks, args.batch, args.seq, start=cursor)

    t0 = time.time()
    for i in range(start, args.steps):
        batch, cursor = next(batches)
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / args.log_every
            tok_s = args.batch * args.seq / dt
            print(f"step {i + 1:5d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"{dt * 1e3:.0f} ms/step  {tok_s:.0f} tok/s", flush=True)
            t0 = time.time()
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, i + 1, state,
                            metadata={"data_cursor": cursor})
    print("done. final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
