"""Post-optimization HLO analyzer: trip-count-aware FLOPs / bytes /
collective-bytes, the three roofline terms.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a while
body ONCE — a scan-over-52-layers model reports 1/52nd of its real FLOPs
(verified empirically). This analyzer parses the SPMD-partitioned
post-optimization HLO text and:

  1. builds the computation call graph (fusion/call/while/conditional),
  2. recovers EXACT while trip counts from the loop-condition computation's
     comparison constant (lax.scan lowers to `compare(ind, constant(N)),
     direction=LT`) — no heuristics,
  3. multiplies per-computation costs by their execution multiplicity
     (nested scans multiply; both conditional branches are counted — a small
     documented overcount for gated layers),
  4. FLOPs: 2·numel(result)·K for every dot (K from contracting dims);
     convolutions 2·numel(result)·prod(kernel_spatial)·Cin/groups,
  5. bytes: fusion-boundary traffic model — Σ (result + operand bytes) over
     executed-context instructions (ENTRY / while bodies / branches), which
     approximates HBM traffic at fusion granularity,
  6. collective bytes: Σ operand bytes of all-reduce / all-gather /
     reduce-scatter / all-to-all / collective-permute × multiplicity
     (shapes in partitioned HLO are per-device ⇒ per-chip link bytes).

Shapes are per-device after GSPMD partitioning, so every number is per-chip.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes_and_elems(tok: str) -> tuple[int, int]:
    """Sum bytes/elems over every dtype[dims] occurrence in a type token
    (handles tuples)."""
    total_b = 0
    total_e = 0
    for m in _SHAPE_RE.finditer(tok):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: list
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    is_entry: bool = False


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],\{\}\s]+?)\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def parse_hlo(text: str) -> dict:
    """→ {name: Computation}; entry flagged."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_HEADER.match(stripped)
            if m:
                cur = Computation(
                    name=m.group(2), instrs=[], is_entry=bool(m.group(1))
                )
                comps[cur.name] = cur
                continue
        if stripped.startswith("}"):
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, rtype, opcode = im.group(1), im.group(2), im.group(3)
            # operand segment = text inside the top-level parens after opcode
            after = line[im.end():]
            depth = 1
            for i, ch in enumerate(after):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        after = after[:i]
                        break
            operands = _OPERAND_RE.findall(after)
            cur.instrs.append(Instr(name, opcode, rtype, operands, line))
    return comps


def _symbol_types(comp: Computation, header_line_types: dict | None = None) -> dict:
    return {i.name: i.result_type for i in comp.instrs}


def _while_trip_count(cond: Computation) -> int:
    """lax.scan condition: `compare(ind, bound), direction=LT` with the bound
    a constant in the same computation (possibly behind a fusion)."""
    consts = []
    for i in cond.instrs:
        if i.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", i.raw)
            if m:
                consts.append(int(m.group(1)))
    cands = [c for c in consts if c > 1]
    return max(cands) if cands else 1


def _dot_flops(instr: Instr, symtab: dict) -> float:
    rb, relems = _shape_bytes_and_elems(instr.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    k = 1
    if m and instr.operands:
        lhs_type = symtab.get(instr.operands[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * relems * k


def _conv_flops(instr: Instr, symtab: dict) -> float:
    _, relems = _shape_bytes_and_elems(instr.result_type)
    m = re.search(r"window=\{size=([\dx]+)", instr.raw)
    ksp = 1
    if m:
        for d in m.group(1).split("x"):
            ksp *= int(d)
    cin = 1
    if instr.operands:
        lhs_type = symtab.get(instr.operands[0], "")
        dm = re.search(r"dim_labels=(\w+)_", instr.raw)
        sm = _SHAPE_RE.search(lhs_type)
        if sm and dm:
            dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
            feat = dm.group(1).find("f")
            if 0 <= feat < len(dims):
                cin = dims[feat]
    gm = re.search(r"feature_group_count=(\d+)", instr.raw)
    groups = int(gm.group(1)) if gm else 1
    return 2.0 * relems * ksp * cin / max(groups, 1)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # control ops: their data traffic is accounted inside their called
    # computations; the operand tuple is aliased, not copied.
    "while", "conditional", "call", "optimization-barrier",
}


# Structural HBM-traffic model (TPU-adapted). The dry-run compiles with the
# CPU backend, whose fusion is far more conservative than the TPU backend's —
# counting every CPU fusion boundary overstates TPU HBM traffic by ~2 orders
# of magnitude. Instead we count only ops that MUST touch HBM on TPU:
#   dot/convolution   — operands + result cross HBM↔VMEM (upper bound: big
#                       operands can't persist in 16 MiB VMEM across steps),
#   copy/concatenate/reverse/transpose — explicit data movement,
#   dynamic-(update-)slice / gather / scatter — cache+stacking traffic,
#   reduce/sort       — operand + result,
#   collectives       — counted separately for the collective term but their
#                       local read/write also contributes here.
# Elementwise chains are assumed fused into their producers/consumers (the
# TPU compiler does this aggressively), so generic fusions are NOT counted.
_BYTES_ALLOWLIST = {
    "dot", "convolution", "copy", "concatenate", "reverse", "transpose",
    "reduce", "sort", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute",
}


def _instr_bytes(instr: "Instr", symtab: dict) -> float:
    """Structural HBM traffic of one executed instruction (see above).

    In-place/partial-access ops are modelled by the bytes actually touched:
      dynamic-update-slice → 2·|update|   (read + write the slice, in place)
      dynamic-slice/gather → 2·|result|   (read the slice, write the result)
      scatter              → 2·|updates|
    """
    rb, _ = _shape_bytes_and_elems(instr.result_type)
    if instr.opcode == "dynamic-update-slice":
        if len(instr.operands) >= 2:
            ub, _ = _shape_bytes_and_elems(symtab.get(instr.operands[1], ""))
            return 2.0 * ub
        return rb
    if instr.opcode in ("dynamic-slice", "gather"):
        return 2.0 * rb
    if instr.opcode == "scatter":
        if len(instr.operands) >= 3:
            ub, _ = _shape_bytes_and_elems(symtab.get(instr.operands[2], ""))
            return 2.0 * ub + rb
        return 2.0 * rb
    base = instr.opcode.split(".")[0]
    if base not in _BYTES_ALLOWLIST:
        return 0.0
    ob = sum(
        _shape_bytes_and_elems(symtab.get(o, ""))[0] for o in instr.operands
    )
    return rb + ob


def analyze_hlo(text: str) -> dict:
    """Roofline raw terms from post-optimization (per-device) HLO text."""
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # call graph: comp -> [(callee, multiplier, executed_context?)]
    # fusion bodies are NOT executed contexts for the bytes model (their
    # interior traffic stays in registers/VMEM); while bodies and branches are.
    calls: dict[str, list] = defaultdict(list)
    trip_counts: dict[str, int] = {}
    for comp in comps.values():
        for instr in comp.instrs:
            if instr.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", instr.raw)
                cm = re.search(r"condition=%?([\w\.\-]+)", instr.raw)
                if bm and cm and cm.group(1) in comps:
                    trips = _while_trip_count(comps[cm.group(1)])
                    trip_counts[instr.name] = trips
                    calls[comp.name].append((bm.group(1), trips, True))
                    calls[comp.name].append((cm.group(1), trips, True))
            elif instr.opcode in ("fusion", "call", "map", "reduce",
                                  "reduce-window", "scatter", "sort",
                                  "select-and-scatter", "custom-call"):
                for cm in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", instr.raw):
                    if cm.group(1) in comps:
                        calls[comp.name].append((cm.group(1), 1, False))
            elif instr.opcode == "conditional":
                for cm in re.finditer(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w\.\-]+))", instr.raw
                ):
                    blob = cm.group(1) or cm.group(2) or ""
                    for name in _OPERAND_RE.findall(blob) or re.findall(r"([\w\.\-]+)", blob):
                        if name in comps:
                            calls[comp.name].append((name, 1, True))

    # execution multiplicity per computation. FLOPs multiplicity follows ALL
    # call edges (fusion interiors included); bytes multiplicity only follows
    # executed-context edges (while bodies / branches) — fusion interiors
    # stay in registers/VMEM and are not fusion-boundary traffic.
    mult_flops: dict[str, float] = defaultdict(float)
    mult_bytes: dict[str, float] = defaultdict(float)

    def walk(name: str, m: float, bytes_ctx: bool, seen: tuple):
        if name in seen:  # recursion guard
            return
        mult_flops[name] += m
        if bytes_ctx:
            mult_bytes[name] += m
        for callee, k, exec_ctx in calls.get(name, []):
            walk(callee, m * k, bytes_ctx and exec_ctx, seen + (name,))

    walk(entry.name, 1.0, True, ())

    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes = 0.0
    coll_breakdown: dict[str, float] = defaultdict(float)
    n_collectives = 0

    for comp in comps.values():
        mf = mult_flops.get(comp.name, 0.0)
        mb = mult_bytes.get(comp.name, 0.0)
        if mf == 0.0 and mb == 0.0:
            continue
        symtab = _symbol_types(comp)
        for instr in comp.instrs:
            if instr.opcode == "dot" and mf:
                flops += mf * _dot_flops(instr, symtab)
            elif instr.opcode == "convolution" and mf:
                flops += mf * _conv_flops(instr, symtab)
            if mb and instr.opcode not in _SKIP_BYTES_OPS:
                bytes_accessed += mb * _instr_bytes(instr, symtab)
            base = instr.opcode.split(".")[0]
            if mb and any(base.startswith(c) for c in _COLLECTIVES):
                ob = sum(
                    _shape_bytes_and_elems(symtab.get(o, ""))[0]
                    for o in instr.operands
                )
                if ob == 0:  # operands may be params without local type
                    ob, _ = _shape_bytes_and_elems(instr.result_type)
                coll_bytes += mb * ob
                coll_breakdown[base] += mb * ob
                n_collectives += int(mb)

    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collective_breakdown": dict(coll_breakdown),
        "n_collective_ops_executed": n_collectives,
        "while_trip_counts": trip_counts,
    }
