"""Pure-JAX optimizers with an optax-like (init, update) interface.

No external deps (optax is not available offline). All states are pytrees
matching the param tree so they inherit the param sharding rules (ZeRO-style
sharding falls out of GSPMD — DESIGN.md §4)."""

from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    adamw,
    apply_updates,
    global_norm,
    clip_by_global_norm,
    cosine_schedule,
    warmup_cosine_schedule,
)

__all__ = [
    "Optimizer", "sgd", "momentum", "adam", "adamw", "apply_updates",
    "global_norm", "clip_by_global_norm", "cosine_schedule",
    "warmup_cosine_schedule",
]
