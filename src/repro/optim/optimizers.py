"""SGD / momentum / Adam / AdamW + schedules + gradient clipping."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """(init, update) pair. update returns (updates, new_state); apply with
    ``apply_updates`` (updates are ADDED to params)."""

    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], tuple[Pytree, Pytree]]


def _zeros_like_f32(params: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _resolve_lr(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        lr_t = _resolve_lr(lr, state["step"])
        updates = jax.tree_util.tree_map(
            lambda g: (-lr_t * g.astype(jnp.float32)), grads
        )
        return updates, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32), "m": _zeros_like_f32(params)}

    def update(grads, state, params=None):
        lr_t = _resolve_lr(lr, state["step"])
        m = jax.tree_util.tree_map(
            lambda m_, g: beta * m_ + g.astype(jnp.float32), state["m"], grads
        )
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda m_, g: -lr_t * (beta * m_ + g.astype(jnp.float32)), m, grads
            )
        else:
            upd = jax.tree_util.tree_map(lambda m_: -lr_t * m_, m)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    """Adam; with weight_decay > 0 it is decoupled (AdamW)."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": _zeros_like_f32(params),
            "v": _zeros_like_f32(params),
        }

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _resolve_lr(lr, state["step"])
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )

        def upd(m_, v_, p):
            u = -(lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            if weight_decay > 0.0 and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay > 0.0:
            updates = jax.tree_util.tree_map(upd, m, v, params)
        else:
            updates = jax.tree_util.tree_map(lambda m_, v_: upd(m_, v_, None), m, v)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)

    return lr


def warmup_cosine_schedule(base_lr: float, warmup: int, total_steps: int,
                           final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)

    def lr(step):
        warm = base_lr * (step.astype(jnp.float32) + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return lr
