"""Pallas TPU kernel: fused FTTQ elementwise apply (scale → threshold → ternarize → rescale).

The layer statistics (1/max|θ|, Δ, w_q) are scalars computed by a cheap jnp
reduction (one pass over the layer, fused by XLA); this kernel then performs
the bandwidth-bound elementwise pass tile-by-tile in VMEM, emitting BOTH the
int8 ternary codes (wire/compute format) and the dequantized θ_t used by the
QAT forward — one HBM read, two writes, zero intermediate round-trips.

TPU mapping: elementwise VPU work, (8·s, 128)-aligned tiles; scalars live in
SMEM. Target block (256, 512): 512 KiB fp32 in + 128 KiB int8 + 512 KiB out
≈ 1.2 MiB of VMEM — comfortable against the ~16 MiB/core budget and large
enough to amortize grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(s_ref, x_ref, it_ref, qt_ref):
    inv_scale = s_ref[0, 0]
    delta = s_ref[0, 1]
    w_q = s_ref[0, 2]
    x = x_ref[...]
    xs = x * inv_scale.astype(x.dtype)
    mask = jnp.abs(xs) > delta.astype(x.dtype)
    i_t = jnp.where(mask, jnp.sign(xs), jnp.zeros_like(xs))
    it_ref[...] = i_t.astype(jnp.int8)
    qt_ref[...] = (w_q.astype(x.dtype) * i_t).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ternary_quantize(
    theta: jax.Array,
    inv_scale: jax.Array,
    delta: jax.Array,
    w_q: jax.Array,
    *,
    block: tuple[int, int] = (256, 512),
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused FTTQ apply for a 2-D weight. Returns (I_t int8, θ_t theta.dtype).

    theta is padded virtually via grid ceil-div; Pallas masks the remainder
    tiles. Scalars are packed into one (1, 3) SMEM operand.
    """
    m, n = theta.shape
    bm, bn = block
    bm, bn = min(bm, m), min(bn, n)
    scalars = jnp.stack(
        [
            inv_scale.astype(jnp.float32),
            delta.astype(jnp.float32),
            w_q.astype(jnp.float32),
        ]
    ).reshape(1, 3)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, j: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, n), theta.dtype),
        ],
        interpret=interpret,
    )(scalars, theta)
