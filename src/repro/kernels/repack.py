"""Wire → kernel-layout repack: serve ternary weights without dequantizing.

The wire format (``core.ternary.pack2bit``) packs 2-bit codes along the
FLATTENED row-major element order — 4 consecutive flat elements per byte —
because the wire does not care about matmul tiling. The Pallas serving
kernel (``kernels.ternary_matmul``) wants the ``(K//4, N)`` layout instead:
each byte holds 4 K-consecutive codes of one N-column, so the in-VMEM
unpack is a sublane-only reshape (see pack2bit.py).

``repack_to_kernel_layout`` converts between the two BY BYTE MANIPULATION:
for aligned shapes (K and N multiples of 4 — every transformer matmul in
the repo) it extracts four 2-bit planes from the wire bytes and re-packs
them along K, touching only uint8 buffers of the packed size (~2× packed
peak). The deploy path therefore never materializes the unpacked int8
codes (4× larger) or a dense fp32 copy (16× larger) of any weight.
Unaligned shapes fall back to an unpack/repack via int8 — documented,
and never hit by the transformer serve path.

``PackedTernary`` is the resulting weight leaf: a pytree node carrying the
kernel-layout bytes + scale, so it can sit inside model params, be sliced
by ``lax.scan`` over stacked layers, and be consumed by
``packed_matmul`` (which ``models.common.matmul`` dispatches to).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import decompress_pytree, is_wire_leaf
from repro.core.ternary import TernaryTensor


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedTernary:
    """A ternary weight in the ``(K//4, N)`` Pallas kernel layout.

    Fields:
      packed: uint8 ``(K//4, N)`` — or ``(L, K//4, N)`` for stacked scan
              layers; ``lax.scan`` slices the leading axis per layer.
      w_q:    the trained scale (scalar, or ``(L, 1, 1)`` stacked).
      k:      logical contraction dim BEFORE padding to a multiple of 4
              (static aux data; ``packed_matmul`` zero-pads x up to it).
      dtype:  logical dtype name of the dequantized weight.
    """

    packed: jax.Array
    w_q: jax.Array
    k: int
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.packed, self.w_q), (self.k, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, w_q = children
        k, dtype = aux
        return cls(packed=packed, w_q=w_q, k=k, dtype=dtype)


def _repack2d_aligned(flat: np.ndarray, k: int, n: int) -> np.ndarray:
    """Wire flat-packed bytes of a (k, n) leaf → (k//4, n) kernel bytes.

    Requires k % 4 == 0 and n % 4 == 0. Pure uint8 plane arithmetic: the
    wire byte grid reshapes to (k//4, 4, n//4); plane j2 (shift 2·j2) holds
    the codes of output columns j2::4, which then pack along K.
    """
    b4 = flat[: k * n // 4].reshape(k // 4, 4, n // 4)
    out = np.empty((k // 4, n), np.uint8)
    for j2 in range(4):
        plane = ((b4 >> np.uint8(2 * j2)) & np.uint8(0x3)).astype(np.uint8)
        out[:, j2::4] = (
            plane[:, 0]
            | (plane[:, 1] << np.uint8(2))
            | (plane[:, 2] << np.uint8(4))
            | (plane[:, 3] << np.uint8(6))
        )
    return out


def _repack2d_fallback(t_packed: np.ndarray, k: int, n: int) -> np.ndarray:
    """Unaligned shapes: unpack to int8 codes, zero-pad K to a multiple of
    4, repack along K. Materializes the (k, n) int8 codes — acceptable only
    off the aligned fast path (odd conv/embedding shapes, tests)."""
    shifts = np.arange(4, dtype=np.uint8) * 2
    codes = (t_packed[:, None] >> shifts) & 0x3          # wire codes, flat
    it = codes.reshape(-1)[: k * n].astype(np.int8) - 1  # {-1, 0, +1}
    it = it.reshape(k, n)
    k_pad = (-k) % 4
    if k_pad:
        it = np.concatenate([it, np.zeros((k_pad, n), np.int8)])
    c = (it + 1).astype(np.uint8).reshape((k + k_pad) // 4, 4, n)
    return c[:, 0] | (c[:, 1] << np.uint8(2)) | (c[:, 2] << np.uint8(4)) | (
        c[:, 3] << np.uint8(6))


def repack_to_kernel_layout(t: TernaryTensor) -> PackedTernary:
    """Convert a decoded wire ``TernaryTensor`` into the kernel layout.

    2-D leaves become ``(K//4, N)``; stacked 3-D scan leaves ``(L, K, N)``
    become ``(L, K//4, N)`` with their per-layer ``(L, 1, 1)`` scales kept
    as-is. Higher-rank leaves are not matmul weights — raise.
    """
    shape = tuple(int(s) for s in t.shape)
    buf = np.asarray(t.packed)
    if len(shape) == 2:
        k, n = shape
        if k % 4 == 0 and n % 4 == 0:
            packed = _repack2d_aligned(buf, k, n)
        else:
            packed = _repack2d_fallback(buf, k, n)
        return PackedTernary(
            packed=jnp.asarray(packed), w_q=jnp.asarray(t.w_q), k=k,
            dtype=t.dtype,
        )
    if len(shape) == 3:
        l, k, n = shape
        if (k * n) % 4:
            raise ValueError(
                f"stacked leaf {shape}: per-layer segment not byte-aligned"
            )
        seg = k * n // 4
        layers = []
        for i in range(l):
            sub = TernaryTensor(
                packed=buf[i * seg : (i + 1) * seg], w_q=t.w_q,
                shape=(k, n), dtype=t.dtype,
            )
            layers.append(np.asarray(repack_to_kernel_layout(sub).packed))
        # per-layer scales become (L, 1, 1); a single shared scale expands
        # so lax.scan can slice one scale per layer.
        wq = jnp.asarray(t.w_q)
        if wq.size == 1:
            wq = jnp.full((l, 1, 1), wq.reshape(()), wq.dtype)
        elif wq.size == l:
            wq = wq.reshape(l, 1, 1)
        else:
            raise ValueError(
                f"stacked leaf {shape}: scale size {wq.size} is neither "
                f"shared (1) nor per-layer ({l})"
            )
        return PackedTernary(
            packed=jnp.asarray(np.stack(layers)), w_q=wq, k=k, dtype=t.dtype,
        )
    raise ValueError(f"cannot repack rank-{len(shape)} leaf {shape} for matmul")


def packed_matmul(x: jax.Array, w: PackedTernary) -> jax.Array:
    """x @ dequant(w) computed by the packed Pallas kernel.

    Leading dims of x are flattened into M; if the logical K was padded to
    a multiple of 4 at repack time, x is zero-padded to match (zero rows
    contribute nothing). The dense weight is never materialized.
    """
    from repro.kernels import ops  # lazy: ops imports the Pallas modules

    if w.packed.ndim != 2:
        raise ValueError(
            f"packed_matmul wants a per-layer (K//4, N) weight, got "
            f"{w.packed.shape} — scan over the leading axis first"
        )
    *lead, k = x.shape
    if k != w.k:
        raise ValueError(f"x contraction dim {k} != weight logical K {w.k}")
    x2 = x.reshape(-1, k)
    k_pad = w.packed.shape[0] * 4
    if k_pad != k:
        x2 = jnp.pad(x2, ((0, 0), (0, k_pad - k)))
    y = ops.ternary_matmul(x2, w.packed, w.w_q.reshape(()).astype(jnp.float32))
    return y.reshape(*lead, y.shape[-1])


def packed_params_from_wire(tree):
    """Decoded wire tree → servable params: ternary matmul weights become
    ``PackedTernary`` (kernel layout, no dequantization); every other wire
    leaf decodes to a dense array."""

    def one(leaf):
        if isinstance(leaf, TernaryTensor) and len(leaf.shape) in (2, 3):
            return repack_to_kernel_layout(leaf)
        return decompress_pytree(leaf)

    return jax.tree_util.tree_map(one, tree, is_leaf=is_wire_leaf)
