"""Pallas TPU kernel: ternary-weight matmul with in-VMEM 2-bit unpack.

y = x @ (w_q · W_t)  where W_t ∈ {-1,0,+1}^{K×N} is stored PACKED in HBM as
(K//4, N) uint8 (see pack2bit.py). This is the serving-path hot spot of the
paper's technique on TPU: weight HBM traffic drops 16× vs fp32 (4× vs int8),
which is the whole game for memory-bound decode GEMMs.

TPU mapping (the adaptation DESIGN.md §2 describes):
  - grid (M/bm, N/bn, K/bk); the K loop is innermost so the fp32 accumulator
    tile lives in VMEM scratch across K steps (revisiting semantics).
  - each step DMAs a (bk//4, bn) PACKED byte tile HBM→VMEM, unpacks to
    (bk, bn) int8 with VPU shift/and ops (sublane reshape only — the lane
    axis N is untouched, so no cross-lane shuffle is generated),
  - dequantizes to x.dtype and contracts on the MXU with fp32 accumulation,
  - w_q is applied ONCE to the final accumulator (not per K-tile) — it's a
    scalar, so scaling commutes with the K-sum.
  - block defaults (bm=128, bn=256, bk=512): VMEM ≈ x 256 KiB (bf16) +
    packed 32 KiB + unpacked int8 128 KiB + acc 128 KiB ≈ 0.5 MiB.

The b16 MXU cannot consume 2-bit operands directly; the win is bandwidth,
not MACs — see DESIGN.md "Hardware adaptation".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(s_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = w_ref[...].astype(jnp.int32)  # (bk//4, bn) packed bytes
    k4, bn = p.shape
    cols = [((p >> (2 * j)) & 0x3) - 1 for j in range(4)]
    w_t = jnp.stack(cols, axis=1).reshape(k4 * 4, bn)  # (bk, bn) in {-1,0,1}
    x = x_ref[...]
    acc_ref[...] += jnp.dot(
        x, w_t.astype(x.dtype), preferred_element_type=jnp.float32
    )

    @pl.when(k_idx == n_k - 1)
    def _done():
        w_q = s_ref[0, 0]
        o_ref[...] = (acc_ref[...] * w_q).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ternary_matmul(
    x: jax.Array,
    packed_w: jax.Array,
    w_q: jax.Array,
    *,
    block: tuple[int, int, int] = (128, 256, 512),
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) · packed_w: (K//4, N) uint8 · w_q scalar → (M, N) x.dtype."""
    m, k = x.shape
    k4, n = packed_w.shape
    assert k4 * 4 == k, f"packed K mismatch: {k4 * 4} != {k}"
    bm, bn, bk = block
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    bk -= bk % 4
    n_k = pl.cdiv(k, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), n_k)
    scal = w_q.astype(jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(scal, x, packed_w)
