"""Pallas TPU kernels: 2-bit ternary pack / unpack codec.

Wire format (matches repro.core.ternary and ref.py): codes c = I_t + 1 ∈
{0,1,2}; four K-consecutive codes per byte, packed along the contraction
(row) axis:  packed[k4, n] = Σ_j c[4·k4+j, n] << 2j.

Packing along K (not N/lanes) keeps the lane dimension intact — each uint8
lane holds a K-strip — so pack/unpack are pure VPU shift/or ops with no
cross-lane shuffles, and the matmul kernel can unpack a (bk//4, bn) byte
tile into a (bk, bn) int8 tile with a sublane-only reshape. This is the
TPU-native replacement for the byte-shuffle a CUDA port would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref):
    c = x_ref[...].astype(jnp.int32) + 1
    k, n = c.shape
    c4 = c.reshape(k // 4, 4, n)
    b = c4[:, 0] | (c4[:, 1] << 2) | (c4[:, 2] << 4) | (c4[:, 3] << 6)
    o_ref[...] = b.astype(jnp.uint8)


def _unpack_kernel(p_ref, o_ref):
    p = p_ref[...].astype(jnp.int32)
    k4, n = p.shape
    cols = [((p >> (2 * j)) & 0x3) - 1 for j in range(4)]
    out = jnp.stack(cols, axis=1).reshape(k4 * 4, n)
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pack2bit(
    i_t: jax.Array, *, block: tuple[int, int] = (512, 512), interpret: bool = False
) -> jax.Array:
    """(K, N) int8 ternary → (K//4, N) uint8. K must be a multiple of 4."""
    k, n = i_t.shape
    assert k % 4 == 0, "pack2bit: K must be a multiple of 4"
    bk, bn = min(block[0], k), min(block[1], n)
    bk -= bk % 4
    grid = (pl.cdiv(k, bk), pl.cdiv(n, bn))
    return pl.pallas_call(
        _pack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bk // 4, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k // 4, n), jnp.uint8),
        interpret=interpret,
    )(i_t)


def pad_to_packable(i_t: jax.Array, lanes: int = 128) -> tuple[jax.Array, int]:
    """Zero-pad + reshape an arbitrary-size ternary array for ``pack2bit``.

    The Pallas codec wants a (K, N) tile with K % 4 == 0; wire payload
    leaves are arbitrary shapes (biases excluded, but conv kernels, odd
    hidden sizes and stacked scan weights all occur). This flattens,
    pads with code 0 to a multiple of ``4 * lanes`` and returns the
    (K, lanes) view plus the original element count, so

        tiled, n = pad_to_packable(x)
        packed = pack2bit(tiled)                    # kernel path
        flat   = unpack_padded(packed, n)           # exact inverse

    round-trips any shape. Padding is zeros (code 1 on the wire), so a
    decoder that trusts ``n`` never sees it.
    """
    flat = i_t.reshape(-1)
    n = flat.shape[0]
    chunk = 4 * lanes
    pad = (-n) % chunk
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, lanes), n


def unpack_padded(packed: jax.Array, n_elements: int, *, dtype=jnp.int8,
                  interpret: bool = False) -> jax.Array:
    """Inverse of ``pack2bit(pad_to_packable(x))``: flat ternary of n values."""
    out = unpack2bit(packed, dtype=dtype, interpret=interpret)
    return out.reshape(-1)[:n_elements]


@functools.partial(jax.jit, static_argnames=("dtype", "block", "interpret"))
def unpack2bit(
    packed: jax.Array,
    *,
    dtype=jnp.int8,
    block: tuple[int, int] = (128, 512),
    interpret: bool = False,
) -> jax.Array:
    """(K//4, N) uint8 → (K, N) ternary in ``dtype``."""
    k4, n = packed.shape
    bk4, bn = min(block[0], k4), min(block[1], n)
    grid = (pl.cdiv(k4, bk4), pl.cdiv(n, bn))
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bk4, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bk4 * 4, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k4 * 4, n), dtype),
        interpret=interpret,
    )(packed)
