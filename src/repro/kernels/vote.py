"""Pallas TPU kernel: coordinate-wise ternary majority vote over wire bytes.

Byzantine-robust aggregation for 2-bit packed updates. Instead of the
weighted mean (``kernels.aggregate``), each coordinate is decided by a
weighted plurality over the client codes: +1 iff the +1 vote mass beats
both the −1 and 0 masses, −1 symmetrically, else 0. A sign-flipping
minority (< half the vote weight) therefore cannot move any coordinate —
the classic coordinate-wise-median robustness, but exact and cheap in the
ternary domain.

The kernel reuses the ``aggregate.py`` staging contract — a stacked
``(C, R, LANES)`` uint8 tensor of flat-packed codes plus a per-client fp32
coefficient vector — and counts votes by plane arithmetic on the packed
bytes (no dense unpack): per 2-bit plane, code 0 adds its coefficient to
the −1 mass and code 2 to the +1 mass. It emits weighted COUNTS, not the
final votes, so the server can accumulate partial counts across chunk
flushes (C > chunk_c) and decide the plurality once at finalize with
``majority_from_counts``. The zero mass needs no third output: it is
``total_coeff − minus − plus`` (every slot holds exactly one code; code 3
never appears in valid payloads — the ingest gate quarantines it).

Coefficients here are the raw client WEIGHTS (scales are NOT folded in —
a vote is scale-free); the caller derives one robust scale per leaf
separately (weighted median of client scales).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.aggregate import BLOCK_ROWS, LANES


def _vote_kernel(s_ref, p_ref, o_ref, *, n_c: int):
    """One (block_rows, LANES) byte tile: loop the C axis in-register.

    Accumulates two fp32 planes — weighted −1 and +1 vote masses — in a
    single fori_loop so the trace stays one step long regardless of C.
    """

    def body(c, acc):
        p = p_ref[pl.ds(c, 1)][0].astype(jnp.int32)      # (br, LANES) bytes
        w = s_ref[c]
        codes = [(p >> (2 * j)) & 0x3 for j in range(4)]
        minus = jnp.stack(
            [(q == 0).astype(jnp.float32) for q in codes], axis=1
        ).reshape(acc.shape[1:])
        plus = jnp.stack(
            [(q == 2).astype(jnp.float32) for q in codes], axis=1
        ).reshape(acc.shape[1:])
        return acc + w * jnp.stack([minus, plus])

    o_ref[...] = jax.lax.fori_loop(
        0, n_c, body, jnp.zeros(o_ref.shape, jnp.float32)
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def packed_vote_counts(
    stacked: jax.Array,
    coeffs: jax.Array,
    *,
    block_rows: int = BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Weighted −1/+1 vote masses per coordinate, straight off wire bytes.

    stacked: (C, R, LANES) uint8, R % block_rows == 0 — each row-major byte
      stream is a client's flat-packed 2-bit codes (zero-pad the tail).
    coeffs:  (C,) float32 — client vote weights (0 for padding clients;
      note a zero-padding BYTE carries code 0 ×4, so padding clients must
      be cancelled by coeff 0, and padded tail bytes of real clients land
      in the sliced-off flat tail exactly as in ``packed_weighted_sum``).
    Returns (2, 4·R·LANES) fp32 [minus_mass, plus_mass] in logical element
    order; the caller slices [:, :n_elements].
    """
    c, r, lanes = stacked.shape
    assert lanes == LANES, f"lane dim must be {LANES}, got {lanes}"
    br = min(block_rows, r)
    assert r % br == 0, f"rows {r} not a multiple of block_rows {br}"
    out = pl.pallas_call(
        functools.partial(_vote_kernel, n_c=c),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((c, br, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((2, 4 * br, LANES), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((2, 4 * r, LANES), jnp.float32),
        interpret=interpret,
    )(coeffs.astype(jnp.float32), stacked)
    # undo the bit-plane interleave per mass plane (same as aggregate.py).
    return out.reshape(2, r, 4, LANES).transpose(0, 1, 3, 2).reshape(2, -1)


def packed_vote_counts_ref(stacked, coeffs) -> np.ndarray:
    """Pure-numpy oracle with identical flat-order semantics."""
    stacked = np.asarray(stacked)
    c = stacked.shape[0]
    flat = stacked.reshape(c, -1)
    shifts = np.arange(4, dtype=np.uint8) * 2
    codes = ((flat[:, :, None] >> shifts) & 0x3).reshape(c, -1)
    w = np.asarray(coeffs, np.float32)
    minus = np.tensordot(w, (codes == 0).astype(np.float32), axes=1)
    plus = np.tensordot(w, (codes == 2).astype(np.float32), axes=1)
    return np.stack([minus, plus])


def majority_from_counts(
    counts: np.ndarray, total_coeff: float
) -> np.ndarray:
    """Decide the plurality winner per coordinate from accumulated masses.

    counts: (2, n) [minus_mass, plus_mass]; the 0 mass is
    ``total_coeff − minus − plus``. Strict plurality — ties (including the
    empty total_coeff == 0 case) resolve to 0, the conservative "don't
    move" outcome. Returns int8 votes in {−1, 0, +1}.
    """
    minus = np.asarray(counts[0], np.float32)
    plus = np.asarray(counts[1], np.float32)
    zero = np.float32(total_coeff) - minus - plus
    votes = np.zeros(minus.shape, np.int8)
    votes[(plus > minus) & (plus > zero)] = 1
    votes[(minus > plus) & (minus > zero)] = -1
    return votes
