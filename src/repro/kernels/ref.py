"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (tests sweep
shapes/dtypes and assert_allclose kernel-vs-ref). They are also the portable
fallback on backends without Pallas support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ternary_quantize_ref(
    theta: jax.Array, inv_scale: jax.Array, delta: jax.Array, w_q: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Elementwise FTTQ apply (the scalars are precomputed layer stats).

    theta_s = theta * inv_scale           (g(θ), eq. 6 — inv_scale = 1/max|θ|)
    I_t     = sign(θ_s) · [|θ_s| > Δ]     (eqs. 10-11)
    θ_t     = w_q · I_t                   (eq. 12)

    Returns (I_t int8, θ_t in theta.dtype).
    """
    theta_s = theta * inv_scale.astype(theta.dtype)
    mask = jnp.abs(theta_s) > delta.astype(theta.dtype)
    i_t = jnp.where(mask, jnp.sign(theta_s), 0.0)
    theta_t = (w_q.astype(theta.dtype) * i_t).astype(theta.dtype)
    return i_t.astype(jnp.int8), theta_t


def pack2bit_ref(i_t: jax.Array) -> jax.Array:
    """(K, N) int8 ternary → (K//4, N) uint8, 4 codes packed along axis 0.

    Row-packing along the contraction axis keeps each packed byte's codes
    contiguous in K, which is what the ternary matmul kernel unpacks.
    """
    k, n = i_t.shape
    assert k % 4 == 0, "pack2bit_ref: K must be a multiple of 4"
    c = (i_t.astype(jnp.int32) + 1).reshape(k // 4, 4, n)
    b = c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)
    return b.astype(jnp.uint8)


def unpack2bit_ref(packed: jax.Array, dtype=jnp.int8) -> jax.Array:
    """(K//4, N) uint8 → (K, N) ternary in ``dtype``. Inverse of pack2bit_ref."""
    k4, n = packed.shape
    p = packed.astype(jnp.int32)
    rows = [((p >> (2 * j)) & 0x3) - 1 for j in range(4)]
    out = jnp.stack(rows, axis=1).reshape(k4 * 4, n)
    return out.astype(dtype)


def ternary_matmul_ref(
    x: jax.Array, packed_w: jax.Array, w_q: jax.Array
) -> jax.Array:
    """y = x @ (w_q · unpack(packed_w)).

    x: (M, K) activations; packed_w: (K//4, N) uint8; w_q scalar (or (N,)).
    Accumulates in fp32, returns x.dtype.
    """
    w = unpack2bit_ref(packed_w, x.dtype)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return (y * w_q.astype(jnp.float32)).astype(x.dtype)


def ternary_matmul_dense_ref(
    x: jax.Array, i_t: jax.Array, w_q: jax.Array
) -> jax.Array:
    """Same contraction but with unpacked int8 ternary weights (K, N)."""
    y = jnp.dot(x, i_t.astype(x.dtype), preferred_element_type=jnp.float32)
    return (y * w_q.astype(jnp.float32)).astype(x.dtype)
