"""Pallas TPU kernels for the paper's compute hot-spots.

  ternary_quantize — fused FTTQ elementwise apply (QAT forward hot loop)
  pack2bit         — 2-bit wire codec (upload/download path)
  quantize_pack    — fused one-pass quantize→pack for client egress:
                     fp32/bf16 weights in, WIRE-layout packed bytes out,
                     w_q moments from the same pass (the upstream encode
                     hot spot; driven tree-wide by core.encode)
  ternary_matmul   — packed ternary-weight GEMM (16× HBM traffic cut; the
                     edge-inference hot spot mapped to TPU decode)
  repack           — wire flat-packed bytes → (K//4, N) kernel layout
                     (PackedTernary weight leaves for the zero-copy serve
                     path; host-side uint8 plane arithmetic)
  aggregate        — fused packed fan-in: Σ coeff_c·unpack(codes_c) over a
                     stacked (C, R, 128) wire-byte tensor in one pass (the
                     T-FedAvg server aggregation hot spot)
  vote             — coordinate-wise ternary majority vote over the same
                     stacked wire-byte layout: weighted −1/+1 vote masses
                     by plane arithmetic, no dense unpack (the
                     Byzantine-robust aggregation rule)

``ops`` holds the jit'd dispatching wrappers; ``ref`` the pure-jnp oracles.
"""

from repro.kernels import aggregate, ops, quantize_pack, ref, repack, vote

__all__ = ["aggregate", "ops", "quantize_pack", "ref", "repack", "vote"]
