"""Pallas TPU kernel: fused one-pass quantize→pack for client egress.

The paper's upstream step (§III.B Algorithm 2) ships 2-bit ternary codes
every round, so the encode side of the wire must be as cheap as the fan-in
side (PR 3): the per-leaf jnp pipeline (scale → threshold → ternarize →
pack) costs ~5 HBM passes of fp32 per weight tensor. This kernel fuses the
whole elementwise chain: fp32/bf16 weights in, WIRE-layout packed uint8
codes out — one HBM read, one ~1/16-size write — and emits the per-tile
partial sums the trained-scale w_q needs (Σ masked |θ_s| and the selected
count) from the same pass, so no extra reduction over the weights runs.

Staging layout (``stage_encode``): the wire packs 4 CONSECUTIVE flat
elements per byte (``core.ternary.pack2bit``), which on a TPU would be a
cross-lane shuffle. Instead the flat leaf is staged as

    staged[4r + j, l] = flat[4 · (r · LANES + l) + j]

so the 4 elements of wire byte ``m = r · LANES + l`` sit in 4 CONSECUTIVE
SUBLANES of lane ``l`` — the in-kernel pack is the same sublane-only
shift/or idiom as ``pack2bit.py`` and the packed output tile IS the wire
byte stream in order (flatten, slice to ``packed_nbytes(n)``, done). The
staging transpose fuses into whatever pass materializes the staging
buffer; XLA never runs it as a separate copy.

Scalars: each grid block reads its own (denom, Δ) row from SMEM, so ONE
launch encodes many segments (leaves / stacked-scan layers) back to back —
the batched tree encoder in ``core.encode`` concatenates per-segment
staging and drives the whole client update through a single kernel call.

Bit-exactness contract: codes are comparisons and elementwise IEEE ops —
identical to the jnp reference by construction. The w_q numerator is a
float SUM, whose value depends on reduction order, so the canonical order
is defined HERE: per-(block_s, LANES)-tile partials in tile order, summed
by one final (G,) reduction. ``moments_ref`` is the pure-jnp oracle with
the identical structure (``lax.map`` over the same tiles); the reference
encode paths in ``core``/``comm`` compute w_q through it, which is what
makes fused and reference wire buffers byte-identical (property-tested in
``tests/test_encode.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pack2bit import pad_to_packable

LANES = 128
BLOCK_S = 256   # staged sublane rows per grid step: (256, 128) fp32 = 128 KiB
                # in + 8 KiB packed out + (1, 2) SMEM moments — well under VMEM


def staged_rows(n_elements: int, block_s: int = BLOCK_S) -> int:
    """Sublane rows of the staging buffer for a leaf of ``n_elements``:
    ⌈n / LANES⌉ rounded up to a multiple of ``block_s`` (tiles never
    straddle segments)."""
    rows = pl.cdiv(max(n_elements, 1), LANES)
    return int(pl.cdiv(rows, block_s) * block_s)


def stage_encode(x: jax.Array, block_s: int = BLOCK_S) -> tuple[jax.Array, int]:
    """Flatten + zero-pad + transpose one leaf into the kernel's staging.

    Reuses ``pack2bit.pad_to_packable`` for the 4·LANES padding contract
    (zero padding = wire code 1 = value 0), then pads rows to a multiple of
    ``block_s`` and interleaves so 4 consecutive flat elements occupy 4
    consecutive sublanes of one lane. Returns (staged (S, LANES), n).
    """
    tiled, n = pad_to_packable(x.reshape(-1), lanes=LANES)
    flat = tiled.reshape(-1)
    chunk = block_s * LANES
    pad = (-flat.shape[0]) % chunk
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, LANES, 4).transpose(0, 2, 1).reshape(-1, LANES), n


def _kernel(s_ref, x_ref, p_ref, m_ref):
    """One (block_s, LANES) staged tile → (block_s//4, LANES) wire bytes +
    (1, 2) partial moments, all in one VMEM round trip."""
    denom = s_ref[0, 0]
    delta = s_ref[0, 1]
    x = x_ref[...]
    xs = x / denom.astype(x.dtype)          # g(θ): same DIVISION as scale_layer
    d = delta.astype(x.dtype)
    pos = (xs > d).astype(jnp.int32)
    neg = (xs < -d).astype(jnp.int32)       # |xs| > d ⟺ pos ∨ neg for d ≥ 0
    c = 1 + pos - neg                       # wire code = I_t + 1 ∈ {0, 1, 2}
    bs, lanes = x.shape
    c4 = c.reshape(bs // 4, 4, lanes)       # 4 sublanes → 1 byte (pack2bit idiom)
    p_ref[...] = (
        c4[:, 0] | (c4[:, 1] << 2) | (c4[:, 2] << 4) | (c4[:, 3] << 6)
    ).astype(jnp.uint8)
    mask = (pos + neg) > 0
    a = jnp.abs(xs).astype(jnp.float32)
    m_ref[0, 0] = jnp.sum(jnp.where(mask, a, 0.0))   # Σ |θ_s| over selected
    m_ref[0, 1] = jnp.sum(mask.astype(jnp.float32))  # selected count (exact ≤ 2²⁴)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def quantize_pack_segments(
    staged: jax.Array,
    scalars: jax.Array,
    *,
    block_s: int = BLOCK_S,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused ternarize+pack over a multi-segment staging buffer.

    staged:  (S, LANES) float staging (``stage_encode`` layout, possibly a
             concatenation of many segments), S % block_s == 0.
    scalars: (S // block_s, 2) fp32 — per-BLOCK (denom, Δ); every block of
             one segment carries that segment's row.
    Returns (packed (S//4, LANES) uint8 wire bytes, moments (G, 2) fp32 —
    per-tile [Σ masked |θ_s|, selected count]).
    """
    s, lanes = staged.shape
    assert lanes == LANES, f"lane dim must be {LANES}, got {lanes}"
    assert s % block_s == 0, f"rows {s} not a multiple of block_s {block_s}"
    g = s // block_s
    return pl.pallas_call(
        _kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (i, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((block_s, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s // 4, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s // 4, LANES), jnp.uint8),
            jax.ShapeDtypeStruct((g, 2), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, staged)


def quantize_pack(
    theta: jax.Array,
    denom: jax.Array,
    delta: jax.Array,
    *,
    block_s: int = BLOCK_S,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, int]:
    """Single-segment convenience: one leaf → (packed bytes (S//4, LANES),
    moments (G, 2), n_elements). Flatten + slice ``[:packed_nbytes(n)]`` of
    the flattened output to get the exact wire byte stream."""
    staged, n = stage_encode(theta, block_s)
    g = staged.shape[0] // block_s
    scal = jnp.broadcast_to(
        jnp.stack([denom, delta]).astype(jnp.float32)[None, :], (g, 2)
    )
    packed, moments = quantize_pack_segments(
        staged, scal, block_s=block_s, interpret=interpret
    )
    return packed, moments, n


def quantize_pack_stacked(
    theta: jax.Array,
    denoms: jax.Array,
    deltas: jax.Array,
    *,
    block_s: int = BLOCK_S,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, int]:
    """vmapped path for stacked scan leaves: (L, ...) weights with per-layer
    (denom, Δ) → (L, rows//4, LANES) per-layer wire bytes + (L, G, 2)
    moments. Each layer stages independently, so concatenating the per-layer
    streams reproduces the flat wire stream only when the layer size is a
    multiple of 4 (the caller checks; ragged stacks take the reference
    path). Bit-exact with L independent ``quantize_pack`` calls."""

    def one(layer, dn, dl):
        staged, n = stage_encode(layer, block_s)
        g = staged.shape[0] // block_s
        scal = jnp.broadcast_to(
            jnp.stack([dn, dl]).astype(jnp.float32)[None, :], (g, 2)
        )
        return quantize_pack_segments(
            staged, scal, block_s=block_s, interpret=interpret
        )

    packed, moments = jax.vmap(one)(theta, denoms, deltas)
    n_layer = int(np.prod(theta.shape[1:])) if theta.ndim > 1 else 1
    return packed, moments, n_layer


# --------------------------------------------------------------------------
# Pure-jnp oracles (the canonical reduction the reference paths share).
# --------------------------------------------------------------------------


def moments_ref(
    x: jax.Array, denom: jax.Array, delta: jax.Array, *, block_s: int = BLOCK_S
) -> jax.Array:
    """Canonical per-tile (Σ masked |θ_s|, count) partials — bit-identical
    to the kernel's SMEM moment outputs: the same (block_s, LANES) tiles in
    the same order, reduced by an identically-shaped op per tile."""
    staged, _ = stage_encode(x, block_s)
    tiles = staged.reshape(-1, block_s, LANES)

    def tile_moments(t):
        xs = t / denom.astype(t.dtype)
        d = delta.astype(t.dtype)
        mask = (xs > d) | (xs < -d)
        a = jnp.abs(xs).astype(jnp.float32)
        return jnp.stack(
            [jnp.sum(jnp.where(mask, a, 0.0)), jnp.sum(mask.astype(jnp.float32))]
        )

    return jax.lax.map(tile_moments, tiles)


def scale_from_moments(moments: jax.Array, denom: jax.Array) -> jax.Array:
    """The Prop-4.1 trained scale from canonical moments, in ORIGINAL
    units: (Σ masked |θ_s| / (count + 1e-8)) · denom. Shared by the fused
    wrapper and the jnp reference so both produce the same fp bits."""
    num = jnp.sum(moments[:, 0])
    den = jnp.sum(moments[:, 1].astype(jnp.int32))
    return num / (den + 1e-8) * denom


def quantize_pack_ref(
    x: jax.Array, denom: jax.Array, delta: jax.Array
) -> jax.Array:
    """Wire-byte oracle: ternarize then pack 4 consecutive flat codes per
    byte (``core.ternary.pack2bit`` layout, code-1 padding)."""
    xs = x.reshape(-1) / denom.astype(x.dtype)
    d = delta.astype(x.dtype)
    codes = 1 + (xs > d).astype(jnp.int32) - (xs < -d).astype(jnp.int32)
    pad = (-codes.shape[0]) % 4
    if pad:
        codes = jnp.concatenate([codes, jnp.ones((pad,), jnp.int32)])
    c = codes.reshape(-1, 4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(
        jnp.uint8
    )
