"""Pallas TPU kernel: fused packed fan-in aggregation (T-FedAvg Algorithm 2).

The server's aggregation step is Σ_c coeff_c · dequant(codes_c) over C client
updates. The naive path unpacks every client to a dense fp32 tree first —
O(C·P) fp32 HBM traffic and one giant Python loop. This kernel instead
consumes the WIRE bytes directly: a stacked ``(C, R, LANES)`` uint8 tensor of
flat-packed 2-bit codes (4 codes/byte, ``core.ternary.pack2bit`` order) plus
a per-client fp32 coefficient vector, and emits the weighted dense sum in one
pass. Per-client fp32 trees are never materialized; the only dense array is
the single fp32 accumulator tile in VMEM.

Layout contract (matches the wire codec, NOT the matmul kernel):
  - wire byte m of a leaf holds flat elements 4m+j (j = 0..3, 2 bits each,
    little-endian within the byte; code = value + 1).
  - the caller reshapes each client's padded byte stream to (R, LANES) rows,
    so byte m sits at [m // LANES, m % LANES].
  - the kernel unpacks in-register with the ``pack2bit`` shift/and idiom and
    accumulates coeff_c · (code − 1); output rows interleave the 4 bit-planes
    (out[4r+j, l] = element 4·(r·LANES+l)+j), and the jit'd wrapper undoes
    the interleave with one dense transpose, returning the flat weighted sum
    in logical element order.

Scales fold into the coefficients: dequant is w_q·codes, so
coeff_c = weight_c · w_q_c and the kernel never sees a scale tensor (leaves
with per-layer scales are aggregated per scale segment by the caller —
segments are contiguous byte ranges of the wire stream). Zero-padded rows /
clients are cancelled by coeff 0 or sliced off the flat tail by the caller.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

LANES = 128
BLOCK_ROWS = 32  # byte-rows per grid step: 32×128 B packed → 128×128 f32 out


def padded_rows(nbytes: int, block_rows: int = BLOCK_ROWS) -> int:
    """Byte-rows of the stacked buffer for a leaf of ``nbytes`` packed bytes:
    ⌈nbytes / LANES⌉ rounded up to a multiple of ``block_rows``."""
    rows = pl.cdiv(max(nbytes, 1), LANES)
    return int(pl.cdiv(rows, block_rows) * block_rows)


def _fanin_kernel(s_ref, p_ref, o_ref, *, n_c: int):
    """One (block_rows, LANES) byte tile: loop the C axis in-register.

    The C loop is a ``fori_loop`` (not a grid axis) so the trace stays one
    step long regardless of C and the fp32 accumulator never leaves
    registers/VMEM between clients.
    """

    def body(c, acc):
        p = p_ref[pl.ds(c, 1)][0].astype(jnp.int32)      # (br, LANES) bytes
        w = s_ref[c]
        cols = [(((p >> (2 * j)) & 0x3) - 1).astype(jnp.float32) for j in range(4)]
        u = jnp.stack(cols, axis=1).reshape(acc.shape)   # (4·br, LANES)
        return acc + w * u

    o_ref[...] = jax.lax.fori_loop(
        0, n_c, body, jnp.zeros(o_ref.shape, jnp.float32)
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def packed_weighted_sum(
    stacked: jax.Array,
    coeffs: jax.Array,
    *,
    block_rows: int = BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Σ_c coeffs[c] · unpack(stacked[c]) without per-client dense trees.

    stacked: (C, R, LANES) uint8, R % block_rows == 0 — each row-major byte
      stream is a client's flat-packed 2-bit codes (zero-pad the tail).
    coeffs:  (C,) float32 — weight_c · scale_c (0 for padding clients).
    Returns the flat fp32 weighted sum of length 4·R·LANES in logical element
    order; the caller slices [:n_elements].
    """
    c, r, lanes = stacked.shape
    assert lanes == LANES, f"lane dim must be {LANES}, got {lanes}"
    br = min(block_rows, r)
    assert r % br == 0, f"rows {r} not a multiple of block_rows {br}"
    out = pl.pallas_call(
        functools.partial(_fanin_kernel, n_c=c),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((c, br, LANES), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((4 * br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((4 * r, LANES), jnp.float32),
        interpret=interpret,
    )(coeffs.astype(jnp.float32), stacked)
    # undo the bit-plane interleave: out[4r+j, l] → flat 4·(r·LANES+l)+j.
    return out.reshape(r, 4, LANES).transpose(0, 2, 1).reshape(-1)


def packed_weighted_sum_ref(stacked, coeffs) -> np.ndarray:
    """Pure-numpy oracle with identical flat-order semantics."""
    stacked = np.asarray(stacked)
    c = stacked.shape[0]
    flat = stacked.reshape(c, -1)
    shifts = np.arange(4, dtype=np.uint8) * 2
    vals = ((flat[:, :, None] >> shifts) & 0x3).astype(np.float32) - 1.0
    return np.tensordot(
        np.asarray(coeffs, np.float32), vals.reshape(c, -1), axes=1
    )
