"""jit'd public wrappers over the Pallas kernels with automatic backend
dispatch: real Pallas lowering on TPU, interpret=True elsewhere (this
container is CPU-only — interpret mode executes the kernel body in Python
for correctness validation; TPU is the performance target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import pack2bit as _pack
from repro.kernels import ternary_matmul as _mm
from repro.kernels import ternary_quantize as _tq
from repro.kernels import ref as _ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fttq_apply(theta: jax.Array, t_k: float, *, interpret: bool | None = None):
    """Full FTTQ for one 2-D layer: stats (jnp reductions) + fused Pallas apply.

    Returns (I_t int8, θ_t, w_q) — w_q initialized at the Prop-4.1 optimum.
    """
    interp = _use_interpret() if interpret is None else interpret
    absw = jnp.abs(theta)
    mx = jnp.max(absw) + 1e-8
    inv_scale = 1.0 / mx
    delta = t_k * jnp.mean(absw) * inv_scale  # Δ over scaled weights (eq. 8)
    sel = absw * inv_scale > delta
    w_q = jnp.sum(jnp.where(sel, absw * inv_scale, 0.0)) / (jnp.sum(sel) + 1e-8)
    i_t, theta_t = _tq.ternary_quantize(
        theta, inv_scale, delta, w_q, interpret=interp
    )
    return i_t, theta_t, w_q


def pack2bit(i_t: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    interp = _use_interpret() if interpret is None else interpret
    return _pack.pack2bit(i_t, interpret=interp)


def unpack2bit(packed: jax.Array, dtype=jnp.int8, *, interpret: bool | None = None):
    interp = _use_interpret() if interpret is None else interpret
    return _pack.unpack2bit(packed, dtype=dtype, interpret=interp)


def ternary_matmul(
    x: jax.Array, packed_w: jax.Array, w_q: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    interp = _use_interpret() if interpret is None else interpret
    return _mm.ternary_matmul(x, packed_w, w_q, interpret=interp)


# re-export oracles for convenience
ternary_quantize_ref = _ref.ternary_quantize_ref
pack2bit_ref = _ref.pack2bit_ref
unpack2bit_ref = _ref.unpack2bit_ref
ternary_matmul_ref = _ref.ternary_matmul_ref
