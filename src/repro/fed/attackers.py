"""Seeded Byzantine attacker models, injected at the payload level.

Each attacker transforms an HONEST encoded update blob into a poisoned but
wire-valid one (decode → transform → re-encode, so framing, CRC, and the
record grammar all hold — only the content gate or a robust aggregation
rule can catch it). All randomness is keyed on ``(seed, client_id,
round)`` so every attack run is reproducible byte-for-byte.

Attack kinds and which defense layer catches them:

  sign_flip      ternary codes negated (0↔2), float residuals negated —
                 undetectable by the gate (a flipped update is a perfectly
                 plausible one); defeated by majority vote when f < C/2.
  scale_blowup   scales / float payloads × ``blowup`` — caught by the
                 gate's running-median scale bound once history is warm.
  gaussian       codes replaced by uniform random valid codes, residuals by
                 matched-variance noise — gate-invisible; vote-diluted.
  nan_poison     NaN scales + NaN float payloads — caught by the gate's
                 finiteness checks, 100% of the time, from the first round.
  collude        a cohort ships ONE identical sign-flipped payload (the rng
                 is keyed on the round only, not the client) — maximizes
                 the per-coordinate vote mass a fixed f can muster.

Injection sites: ``fed/simulation.py`` / ``fed/fleet.py`` poison the
payload after the honest client computes it; ``comm/faults.py`` re-frames
poisoned bytes inside the ChaosProxy (the man-in-the-middle variant); the
``fed/mp_server.py`` demo clients poison client-side before upload.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.comm.wire import (
    decode_update_leaves, encode_update, tree_from_records,
)
from repro.core.compression import DowncastTensor, TopKTensor
from repro.core.ternary import TernaryTensor

ATTACKS = ("sign_flip", "scale_blowup", "gaussian", "nan_poison", "collude")

# byte → the byte with every 2-bit code c mapped to 2−c (value negation);
# the reserved code 3 maps to itself (never present in honest payloads).
_FLIP_LUT = np.array(
    [sum((((2 - c) if (c := (b >> (2 * j)) & 0x3) < 3 else 3) << (2 * j))
         for j in range(4))
     for b in range(256)],
    dtype=np.uint8,
)

# the 81 byte values whose four 2-bit fields are all valid codes {0,1,2}
_VALID_BYTES = np.array(
    [b for b in range(256)
     if all(((b >> (2 * j)) & 0x3) != 3 for j in range(4))],
    dtype=np.uint8,
)


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    """Who attacks and how. ``n_attackers == 0`` (default) is all-honest."""

    kind: str = "sign_flip"
    n_attackers: int = 0
    seed: int = 0
    blowup: float = 1000.0

    def __post_init__(self):
        if self.kind not in ATTACKS:
            raise ValueError(f"kind must be one of {ATTACKS}, got {self.kind!r}")
        if self.n_attackers < 0:
            raise ValueError("n_attackers must be >= 0")
        if self.blowup <= 1.0:
            raise ValueError("blowup must be > 1")


def attacker_ids(cfg: AttackConfig, n_clients: int) -> frozenset[int]:
    """The seeded attacker cohort — a deterministic f-subset of clients."""
    f = min(cfg.n_attackers, n_clients)
    if f == 0:
        return frozenset()
    rng = np.random.default_rng([cfg.seed, 0xBAD])
    return frozenset(
        int(i) for i in rng.choice(n_clients, size=f, replace=False)
    )


def _poison_leaf(leaf, kind: str, blowup: float, rng: np.random.Generator):
    if isinstance(leaf, TernaryTensor):
        packed = np.array(leaf.packed, dtype=np.uint8, copy=True)
        w_q = np.array(leaf.w_q, copy=True)
        if kind in ("sign_flip", "collude"):
            packed = _FLIP_LUT[packed]
        elif kind == "scale_blowup":
            w_q = w_q * np.asarray(blowup, w_q.dtype)
        elif kind == "gaussian":
            packed = rng.choice(_VALID_BYTES, size=packed.shape)
        elif kind == "nan_poison":
            w_q = np.full_like(w_q, np.nan)
        return TernaryTensor(packed=packed, w_q=w_q,
                             shape=tuple(leaf.shape), dtype=leaf.dtype)
    if isinstance(leaf, TopKTensor):
        values = np.array(leaf.values, copy=True)
        if np.issubdtype(values.dtype, np.floating):
            values = _poison_float(values, kind, blowup, rng)
        return TopKTensor(indices=np.asarray(leaf.indices), values=values,
                          shape=tuple(leaf.shape), dtype=leaf.dtype)
    if isinstance(leaf, DowncastTensor):
        data = np.array(leaf.data, copy=True)
        if np.issubdtype(data.dtype, np.floating):
            data = _poison_float(data, kind, blowup, rng)
        return DowncastTensor(data=data, orig_dtype=leaf.orig_dtype)
    arr = np.asarray(leaf)
    if np.issubdtype(arr.dtype, np.floating):
        return _poison_float(np.array(arr, copy=True), kind, blowup, rng)
    return arr  # integer leaves (step counters) ride through untouched


def _poison_float(arr: np.ndarray, kind: str, blowup: float,
                  rng: np.random.Generator) -> np.ndarray:
    if kind in ("sign_flip", "collude"):
        return -arr
    if kind == "scale_blowup":
        return arr * np.asarray(blowup, arr.dtype)
    if kind == "gaussian":
        std = float(np.std(arr.astype(np.float64))) or 1.0
        return rng.normal(0.0, std, size=arr.shape).astype(arr.dtype)
    if kind == "nan_poison":
        return np.full_like(arr, np.nan)
    raise ValueError(f"unknown attack kind {kind!r}")


def poison_blob(blob: bytes, cfg: AttackConfig, client_id: int,
                round_idx: int = 0) -> bytes:
    """Transform one honest update blob into this attacker's payload.

    Colluders draw from an rng keyed on the round only, so every cohort
    member re-encodes byte-identical poison; all other kinds key on the
    client too (independent attackers).
    """
    key = ([cfg.seed, 0x5161, round_idx] if cfg.kind == "collude"
           else [cfg.seed, 0x5161, round_idx, client_id])
    rng = np.random.default_rng(key)
    pairs = decode_update_leaves(bytes(blob), zero_copy=True)
    poisoned = [(path, _poison_leaf(leaf, cfg.kind, cfg.blowup, rng))
                for path, leaf in pairs]
    return encode_update(tree_from_records(poisoned))
