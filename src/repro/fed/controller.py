"""Per-client adaptive compression control with generic error feedback.

Contract. The static codec registry (``core.compression``) fixes ONE
upstream codec for every client on every round. This module turns that into
a closed control loop: each round, each client's upload codec is chosen
from two measured signals —

  - **channel goodput** (bytes/s), observed from the same per-transfer
    metering the ``comm.channel`` model logs (``TransferEvent``): a client
    whose link runs well below the fleet is a straggler risk, so it ships
    the cheapest rung;
  - **update divergence** (relative L2 of the local update,
    ‖θ_k − θ‖ / ‖θ‖): large early-training updates tolerate coarse codecs,
    small late-training updates are mostly redundant and can be shipped
    SPARSE, provided the dropped mass is not lost —

and the loss each encode incurs is never discarded: the controller keeps a
per-client **error-feedback residual tree** (Sattler et al.,
arXiv:1903.02891), folds it back into the weights before the next encode
(``corrected = θ_k + residual``), and stores the new residual
``corrected − decode(encode(corrected))`` — generic over codecs via
``core.compression.compress_pytree``. The codec ladder spans the registry:
"fp16"/"bf16" downcast, the paper's "ternary", plain "topk"
(TOPK_DELTA varint records), and the composed "topk16"
(top-k → fp16 downcast of the survivors) — mixed-codec rounds need no wire
change because every record already carries its kind byte.

When the chosen rung is the paper's ternary codec on the T-FedAvg path,
the error-feedback-corrected weights flow through the SAME
``client_update_payload`` fused-encode pre-pass as the static path (trained
w_q scales, one fused quantize→pack launch), so the controller composes
with — rather than forks — the QAT wire path.

Determinism. The controller holds no RNG: selections are a pure function
of (config, observation history), and observations arrive in the servers'
deterministic event order — two runs with the same seeds produce the same
rung sequence, the same bytes, and the same final weights
(``tests/test_controller.py``). With ``FedConfig.controller = None`` (the
default) no controller object is ever constructed and every byte, RNG draw
and call order of the pre-controller servers is reproduced exactly.

Telemetry lands in ``FedResult.telemetry["controller"]``: per-round rung
counts, per-round residual-L2 trajectory, and upstream bytes by codec kind.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np

from repro.comm.wire import encode_update
from repro.core import fttq as fttq_mod
from repro.core.compression import (
    CodecSpec,
    available_codecs,
    compress_pytree,
    decompress_pytree,
)
from repro.core.tfedavg import client_update_payload

Pytree = Any

# Ladder rungs the controller may select, highest fidelity first. Every
# rung is a registered codec kind for quantizable leaves; non-quantizable
# leaves follow ``ControllerConfig.residual_codec``.
LADDER = ("fp16", "bf16", "ternary", "topk", "topk16")


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Serializable controller knobs (``FedConfig.controller``).

    Attributes:
      enabled: master switch; False behaves exactly like ``controller=None``
        (no controller is constructed — the legacy path, bit-exact).
      error_feedback: keep per-client residual trees and fold them back
        before each encode. Off → the controller still picks codecs but
        every encode is memoryless.
      warmup_encodes: each client's first N uploads ship the paper's
        ternary codec regardless of signals — the EWMAs need observations
        before the policy can trust them.
      divergence_high: relative-L2 threshold. At or above it the update is
        "informative" and ships ternary (or fidelity_rung if the link is
        fast); below it the update is mostly redundant and ships the
        aggressive sparse rung, with error feedback carrying the rest.
      slow_factor: a client whose goodput EWMA falls below
        ``slow_factor × fleet-mean goodput`` is a straggler risk and ships
        ``aggressive_rung`` regardless of divergence (0 disables).
      fast_factor: a client faster than ``fast_factor × fleet mean`` whose
        update diverges strongly may spend bytes on the fidelity rung
        (0 disables — ternary stays the high-divergence choice).
      aggressive_rung / fidelity_rung: ladder rungs for the two extremes.
      topk_fraction: kept fraction for the topk/topk16 rungs.
      residual_codec: codec for non-quantizable leaves on every rung.
      ewma: smoothing factor for the goodput/divergence EWMAs
        (new = ewma·obs + (1−ewma)·old).
    """

    enabled: bool = True
    error_feedback: bool = True
    warmup_encodes: int = 1
    divergence_high: float = 0.05
    slow_factor: float = 0.5
    fast_factor: float = 0.0
    aggressive_rung: str = "topk16"
    fidelity_rung: str = "fp16"
    topk_fraction: float = 0.05
    residual_codec: str = "none"
    ewma: float = 0.5

    def __post_init__(self):
        for field in ("aggressive_rung", "fidelity_rung"):
            rung = getattr(self, field)
            if rung not in LADDER:
                raise ValueError(f"{field} {rung!r} not in ladder {LADDER}")
        if self.residual_codec not in available_codecs():
            raise ValueError(
                f"unknown residual_codec {self.residual_codec!r}"
            )
        if not 0.0 < self.ewma <= 1.0:
            raise ValueError(f"ewma must be in (0, 1], got {self.ewma}")


def tree_l2(tree: Pytree) -> float:
    """Global L2 norm over every floating leaf of a pytree."""
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            total += float(np.vdot(arr.astype(np.float64),
                                   arr.astype(np.float64)))
    return math.sqrt(total)


def _tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def _tree_add(a: Pytree, b: Pytree | None) -> Pytree:
    if b is None:
        return a
    return jax.tree_util.tree_map(lambda x, r: x + r, a, b)


class CompressionController:
    """The per-client control loop; one instance per federated run.

    Servers drive it through four hooks:
      - ``note_round(r)``   — tag subsequent encodes with round/version r
        (telemetry bucketing only; the policy itself is round-free).
      - ``client_payload(k, params_k, wq_tree, start_params)`` — the
        encode hook ``train_client`` calls in place of the static path:
        selects the rung, applies error feedback, returns the wire blob.
      - ``observe_upload(k, nbytes, seconds)`` — goodput metering, fed
        from the channel's per-transfer timings.
      - ``telemetry()``     — the ``FedResult.telemetry["controller"]``
        payload.
    """

    def __init__(self, cfg: ControllerConfig, fed_cfg: Any):
        self.cfg = cfg
        self.fed = fed_cfg  # FedConfig (duck-typed: algorithm, fttq, ...)
        self._residual: dict[int, Pytree] = {}
        self._goodput: dict[int, float] = {}
        self._divergence: dict[int, float] = {}
        self._encodes: dict[int, int] = {}
        self._round = 0
        # telemetry: per-round rung counts / residual L2 sums / bytes.
        self._rung_counts: dict[int, dict[str, int]] = {}
        self._residual_l2: dict[int, float] = {}
        self._bytes_by_kind: dict[str, int] = {}
        self._specs: dict[str, CodecSpec] = {}

    # -- policy ------------------------------------------------------------

    def spec_for(self, rung: str) -> CodecSpec:
        """The directional codec spec one ladder rung resolves to."""
        spec = self._specs.get(rung)
        if spec is None:
            spec = CodecSpec(
                kind=rung,
                residual=self.cfg.residual_codec,
                fttq=self.fed.fttq,
                topk_fraction=self.cfg.topk_fraction,
                fused_encode=self.fed.fused_encode,
            )
            self._specs[rung] = spec
        return spec

    def select(self, client_id: int) -> str:
        """Pick the ladder rung for client ``client_id``'s next upload —
        a pure function of the observation EWMAs (no RNG)."""
        k = int(client_id)
        if self._encodes.get(k, 0) < self.cfg.warmup_encodes:
            return "ternary"
        div = self._divergence.get(k, float("inf"))
        gp = self._goodput.get(k)
        if gp is not None and self.cfg.slow_factor > 0 and self._goodput:
            fleet_mean = sum(self._goodput.values()) / len(self._goodput)
            if gp < self.cfg.slow_factor * fleet_mean:
                return self.cfg.aggressive_rung
        if div >= self.cfg.divergence_high:
            if (gp is not None and self.cfg.fast_factor > 0 and self._goodput):
                fleet_mean = sum(self._goodput.values()) / len(self._goodput)
                if gp > self.cfg.fast_factor * fleet_mean:
                    return self.cfg.fidelity_rung
            return "ternary"
        return self.cfg.aggressive_rung

    # -- observations ------------------------------------------------------

    def note_round(self, round_idx: int) -> None:
        self._round = int(round_idx)

    def observe_upload(self, client_id: int, nbytes: int,
                       seconds: float) -> None:
        """Fold one measured upload (the channel's ``TransferEvent`` view:
        payload bytes over wall seconds including retransmissions) into the
        client's goodput EWMA."""
        if seconds <= 0:
            return
        k, a = int(client_id), self.cfg.ewma
        gp = float(nbytes) / float(seconds)
        old = self._goodput.get(k)
        self._goodput[k] = gp if old is None else a * gp + (1 - a) * old

    def _observe_divergence(self, k: int, params_k: Pytree,
                            start_params: Pytree) -> float:
        base = tree_l2(start_params)
        div = tree_l2(_tree_sub(params_k, start_params)) / (base + 1e-12)
        a = self.cfg.ewma
        old = self._divergence.get(k)
        self._divergence[k] = div if old is None else a * div + (1 - a) * old
        return div

    # -- the encode hook ---------------------------------------------------

    def client_payload(self, client_id: int, params_k: Pytree,
                       wq_tree: Pytree | None,
                       start_params: Pytree) -> bytes:
        """Encode one client's upload under the selected rung, with error
        feedback: corrected = θ_k + residual_k; residual_k ← corrected −
        decode(wire). Returns the serialized wire blob."""
        k = int(client_id)
        self._observe_divergence(k, params_k, start_params)
        rung = self.select(k)
        spec = self.spec_for(rung)
        res = self._residual.get(k) if self.cfg.error_feedback else None
        if rung == "ternary" and wq_tree is not None:
            # the paper's QAT wire path: error-feedback-corrected weights
            # through the client_update_payload fused-encode pre-pass, so
            # the trained w_q scales survive rung selection.
            corrected = _tree_add(params_k, res)
            payload = client_update_payload(
                corrected, wq_tree, self.fed.fttq, fused=spec.fused_encode
            )
            payload, _ = compress_pytree(payload, spec)
            new_res = (
                _tree_sub(corrected, decompress_pytree(payload))
                if self.cfg.error_feedback else None
            )
        else:
            ef_spec = dataclasses.replace(
                spec, error_feedback=self.cfg.error_feedback
            )
            payload, new_res = compress_pytree(params_k, ef_spec, residual=res)
        if self.cfg.error_feedback:
            self._residual[k] = new_res
        self._encodes[k] = self._encodes.get(k, 0) + 1
        blob = encode_update(payload)
        r = self._round
        counts = self._rung_counts.setdefault(r, {})
        counts[rung] = counts.get(rung, 0) + 1
        if self.cfg.error_feedback:
            self._residual_l2[r] = (
                self._residual_l2.get(r, 0.0) + tree_l2(new_res)
            )
        self._bytes_by_kind[rung] = (
            self._bytes_by_kind.get(rung, 0) + len(blob)
        )
        return blob

    # -- reporting ---------------------------------------------------------

    def residual_l2(self, client_id: int) -> float:
        res = self._residual.get(int(client_id))
        return 0.0 if res is None else tree_l2(res)

    def telemetry(self) -> dict:
        rounds = sorted(self._rung_counts)
        return {
            "enabled": True,
            "error_feedback": self.cfg.error_feedback,
            "rounds": rounds,
            "rung_counts_per_round": [self._rung_counts[r] for r in rounds],
            # Σ over that round's encodes of ‖residual‖₂ — the trajectory
            # should stay bounded when error feedback is healthy.
            "residual_l2_per_round": [
                self._residual_l2.get(r, 0.0) for r in rounds
            ],
            "bytes_by_kind": dict(sorted(self._bytes_by_kind.items())),
            "clients_seen": len(self._encodes),
        }


def make_controller(fed_cfg: Any) -> CompressionController | None:
    """Controller for one run, or None when the config leaves it off —
    the None path constructs NOTHING, so pre-controller runs stay
    bit-exact."""
    ctrl_cfg = getattr(fed_cfg, "controller", None)
    if ctrl_cfg is None or not ctrl_cfg.enabled:
        return None
    return CompressionController(ctrl_cfg, fed_cfg)


# --------------------------------------------------------------------------
# Cohort-level policy for the vectorized fleet path.
# --------------------------------------------------------------------------


class FleetCohortController:
    """The fleet approximation of the per-client loop (``fed/fleet.py``).

    Fleet rounds stub out local SGD (payloads come from a pre-encoded
    pool), so there is no per-client divergence signal and no per-client
    residual state — the policy runs COHORT-LEVEL on the one signal the
    fleet does measure: upload goodput. Payload pools are pre-encoded once
    per rung; each round ships every cohort from the selected rung's pool.

    Policy: warmup rounds ship ternary; afterwards, a round whose measured
    mean upload goodput EWMA falls below ``slow_factor ×`` the first
    observed goodput ships ``aggressive_rung``, else ternary. Deterministic
    (no RNG): the trajectory is a pure function of the channel draws.
    """

    def __init__(self, cfg: ControllerConfig):
        self.cfg = cfg
        self._ewma: float | None = None
        self._baseline: float | None = None
        self._rounds = 0
        self.rung_per_round: list[str] = []

    def observe_round(self, nbytes: int, seconds: float) -> None:
        """Fold one round's aggregate upload (Σ bytes, Σ seconds)."""
        if seconds <= 0:
            return
        gp = float(nbytes) / float(seconds)
        a = self.cfg.ewma
        self._ewma = gp if self._ewma is None else a * gp + (1 - a) * self._ewma
        if self._baseline is None:
            self._baseline = gp

    def select(self) -> str:
        self._rounds += 1
        if self._rounds <= self.cfg.warmup_encodes or self._ewma is None:
            rung = "ternary"
        elif (self.cfg.slow_factor > 0 and self._baseline is not None
              and self._ewma < self.cfg.slow_factor * self._baseline):
            rung = self.cfg.aggressive_rung
        else:
            rung = "ternary"
        self.rung_per_round.append(rung)
        return rung

    def telemetry(self) -> dict:
        return {
            "enabled": True,
            "cohort_policy": True,
            "rung_per_round": list(self.rung_per_round),
            "goodput_ewma": self._ewma,
        }
