"""Byzantine-robust ingest: the payload quarantine gate.

Every robustness layer below this one (CRC, ChaosProxy retries/resume,
quorum) defends against *byte-level* faults — a well-formed but poisoned
update (NaN scales, a 1000× scale blowup, reserved 2-bit codes) sails
straight through framing and CRC into the weighted mean. The gate inspects
decoded update CONTENT against the broadcast model before the payload
reaches the aggregator, and books failures as a third ledger outcome,
extending the PR-8 invariant to

    shipped == ingested + dropped + quarantined

Checks, in order (first failure wins; reasons are the telemetry keys):

  malformed          blob does not decode (``WireError``)
  structure          record paths / logical shapes / dtypes differ from the
                     broadcast tree (treedef match via ``tree_leaf_paths``)
  scale_nonfinite    a ternary scale is NaN/Inf (catches nan_poison always,
                     no history needed)
  scale_bound        max |scale| exceeds ``scale_bound`` × the running
                     cross-client median for that leaf (enforced once
                     ``min_history`` clean payloads have been seen — the
                     cold-start rounds are observe-only by design)
  code_plane         a packed ternary byte contains the reserved code 3
                     (honest encoders never emit it; padding carries code 1)
  payload_nonfinite  a raw / downcast / top-k float payload is NaN/Inf

The gate never mutates blobs and never touches accepted payloads, so
defense-on with honest clients is byte-identical to defense-off. Scale
history is only fed by ACCEPTED payloads (a quarantined blowup cannot drag
the median toward itself), which also means a colluding cohort arriving
before ``min_history`` honest scales can seed the history — the bound is a
rate-limiter for gross outliers, not a consensus mechanism; subtle poisons
are the majority-vote rule's job (``kernels.vote``).

Determinism: the gate holds no RNG — verdicts and the running scale
history are pure functions of the blob sequence presented, so identical
seeds (hence identical client payload streams) give identical quarantine
sets, ledger counts, and telemetry on every run. ``DefenseConfig = None``
or ``enabled=False`` constructs no gate and reproduces the ungated ingest
path bit-exactly.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any

import numpy as np

from repro.comm.wire import WireError, decode_update_leaves, tree_leaf_paths
from repro.core.compression import DowncastTensor, TopKTensor
from repro.core.ternary import TernaryTensor
from repro.fed.aggregator import AGG_RULES

# Quarantine reasons, in check order.
REASONS = ("malformed", "structure", "scale_nonfinite", "scale_bound",
           "code_plane", "payload_nonfinite")

# byte → does any of its four 2-bit fields hold the reserved code 3?
_HAS_CODE3 = np.array(
    [any(((b >> (2 * j)) & 0x3) == 3 for j in range(4)) for b in range(256)],
    dtype=bool,
)


@dataclasses.dataclass(frozen=True)
class DefenseConfig:
    """Content-defense knobs threaded through every server path.

    enabled=False (the default) keeps the gate entirely out of the ingest
    path — zero overhead, bit-identical behavior. rule picks the
    aggregation statistic; only "mean" reproduces the legacy weighted mean
    bit-exactly (the robust rules differ by design).
    """

    enabled: bool = False
    rule: str = "mean"
    scale_bound: float = 10.0   # max |scale| / running median before quarantine
    min_history: int = 4        # accepted payloads before the bound is live
    trim_frac: float = 0.2      # per-side trim for the trimmed_mean rule

    def __post_init__(self):
        if self.rule not in AGG_RULES:
            raise ValueError(f"rule must be one of {AGG_RULES}, got {self.rule!r}")
        if self.scale_bound <= 1.0:
            raise ValueError("scale_bound must be > 1 (it is a ratio)")
        if self.min_history < 1:
            raise ValueError("min_history must be >= 1")
        if not 0.0 <= self.trim_frac < 0.5:
            raise ValueError("trim_frac must be in [0, 0.5)")


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Outcome of one gate check. ``ok`` ⇒ pass through to the aggregator;
    otherwise ``reason`` is one of ``REASONS`` and ``detail`` names the
    offending record."""

    ok: bool
    reason: str = ""
    detail: str = ""


def _leaf_signature(leaf: Any) -> tuple[tuple, str]:
    """(logical shape, logical dtype) of any wire or dense leaf."""
    if isinstance(leaf, TernaryTensor):
        return tuple(leaf.shape), str(leaf.dtype)
    if isinstance(leaf, DowncastTensor):
        return tuple(leaf.data.shape), str(leaf.orig_dtype)
    if isinstance(leaf, TopKTensor):
        return tuple(leaf.shape), str(leaf.dtype)
    arr = np.asarray(leaf)
    return tuple(arr.shape), str(arr.dtype)


class UpdateGate:
    """Stateful per-round (or longer-lived) content gate.

    Built from the BROADCAST params tree — the one structure every honest
    update must mirror. ``check(blob)`` returns a ``Verdict`` and updates
    the pass/quarantine telemetry; the caller books quarantined bytes into
    its ledger (``Aggregator.note_quarantined`` / the socket round state).
    """

    def __init__(self, cfg: DefenseConfig, params: Any):
        self.cfg = cfg
        self._ref = {
            path: _leaf_signature(leaf) for path, leaf in tree_leaf_paths(params)
        }
        self._scale_hist: dict[str, list[float]] = {}
        self.passed_updates = 0
        self.passed_bytes = 0
        self.quarantined_updates = 0
        self.quarantined_bytes = 0
        self.reasons: Counter[str] = Counter()

    # -- checks ------------------------------------------------------------

    def _check_records(self, pairs) -> Verdict:
        seen = {}
        for path, leaf in pairs:
            seen[path] = leaf
        if set(seen) != set(self._ref):
            missing = sorted(set(self._ref) - set(seen))
            extra = sorted(set(seen) - set(self._ref))
            return Verdict(False, "structure",
                           f"missing={missing[:3]} extra={extra[:3]}")
        for path, leaf in seen.items():
            if _leaf_signature(leaf) != self._ref[path]:
                return Verdict(
                    False, "structure",
                    f"{path!r}: {_leaf_signature(leaf)} != {self._ref[path]}")
        # content checks, cheapest-to-catch first
        for path, leaf in seen.items():
            if isinstance(leaf, TernaryTensor):
                scale = np.asarray(leaf.w_q)
                if not np.all(np.isfinite(scale)):
                    return Verdict(False, "scale_nonfinite", path)
                v = self._scale_verdict(path, scale)
                if v is not None:
                    return v
                packed = np.asarray(leaf.packed)
                if _HAS_CODE3[packed].any():
                    return Verdict(False, "code_plane", path)
            else:
                payload = (leaf.data if isinstance(leaf, DowncastTensor)
                           else leaf.values if isinstance(leaf, TopKTensor)
                           else np.asarray(leaf))
                payload = np.asarray(payload)
                if (np.issubdtype(payload.dtype, np.floating)
                        and not np.all(np.isfinite(payload))):
                    return Verdict(False, "payload_nonfinite", path)
        return Verdict(True)

    def _scale_verdict(self, path: str, scale: np.ndarray) -> Verdict | None:
        hist = self._scale_hist.get(path, ())
        if len(hist) < self.cfg.min_history:
            return None
        med = float(np.median(hist))
        rep = float(np.max(np.abs(scale)))
        if rep > self.cfg.scale_bound * max(med, np.finfo(np.float32).tiny):
            return Verdict(False, "scale_bound",
                           f"{path!r}: |scale| {rep:.3g} vs median {med:.3g}")
        return None

    # -- public API --------------------------------------------------------

    def check(self, blob: bytes) -> Verdict:
        """Gate one update payload; pass ⇒ its scales feed the history."""
        try:
            pairs = decode_update_leaves(bytes(blob), zero_copy=True)
        except WireError as e:
            verdict = Verdict(False, "malformed", str(e)[:120])
        else:
            verdict = self._check_records(pairs)
        if verdict.ok:
            self.passed_updates += 1
            self.passed_bytes += len(blob)
            for path, leaf in pairs:
                if isinstance(leaf, TernaryTensor):
                    self._scale_hist.setdefault(path, []).append(
                        float(np.max(np.abs(np.asarray(leaf.w_q)))))
        else:
            self.quarantined_updates += 1
            self.quarantined_bytes += len(blob)
            self.reasons[verdict.reason] += 1
        return verdict

    def telemetry(self) -> dict:
        return {
            "enabled": self.cfg.enabled,
            "rule": self.cfg.rule,
            "passed_updates": self.passed_updates,
            "passed_bytes": self.passed_bytes,
            "quarantined_updates": self.quarantined_updates,
            "quarantined_bytes": self.quarantined_bytes,
            "reasons": dict(self.reasons),
        }
