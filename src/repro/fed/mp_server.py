"""Cross-process federation over real TCP sockets.

Everything upstream of this module simulates its event loop; here the wire
codec finally crosses a REAL process boundary. ``run_socket_round`` puts the
long-lived streaming ``Aggregator`` behind an accept loop on a loopback
socket and spawns N genuine client OS processes (``multiprocessing`` spawn
context — each child is a fresh interpreter with its own JAX runtime). Each
client:

  1. connects and sends HELLO {client_id},
  2. receives the broadcast (a complete ``comm.wire`` buffer inside one
     transport frame) and decodes it — CRC re-verified on the client,
  3. derives its update deterministically from (decoded params, seed,
     client_id), compresses it through the FUSED ternary egress path
     (``core.encode`` via ``compress_pytree(fused_encode=True)``), and
     streams the wire buffer back as an UPDATE frame,
  4. waits for DONE.

Arrival handling feeds the same mix logic the simulators use:

  - mode="sync": a barrier collects every update, then streams them into
    the ``Aggregator`` in client_id order — exactly the order the
    in-process reference uses — so the root aggregate is BYTE-IDENTICAL
    to ``run_inprocess_reference`` for the same seeds (same add order ⇒
    same chunk-flush boundaries ⇒ same float op order).
  - mode="buffered": every ``buffer_k`` arrivals are folded into the
    global with the buffered-async server's ``_weighted_mix`` (FedBuf-style
    η-mixing), in true arrival order. Byte-identity against the reference
    holds when the reference replays the server's recorded arrival order
    (``order=result.arrivals``).

Byte accounting is metered from ACTUAL socket traffic: upload bytes are the
per-connection ``FrameDecoder.bytes_in`` sums (every byte the server read),
download bytes are the ``send_frame`` return sums (every byte it wrote) —
not payload-length arithmetic.

Determinism contract: the fused encode path runs on the CPU backend in
interpret mode, where JAX is deterministic across processes, so a client's
update blob is a pure function of (broadcast bytes, seed, client_id) and
the in-process/subprocess hashes must match (``tests/test_mp_server.py``).

CLI demo (also the CI smoke)::

    PYTHONPATH=src python -m repro.fed.mp_server --clients 4 --check
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import multiprocessing as mp
import socket
import sys
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.transport import (
    FT_BCAST,
    FT_DONE,
    FT_ERR,
    FT_HELLO,
    FT_UPDATE,
    FrameDecoder,
    TransportError,
    recv_frame,
    send_frame,
)
from repro.comm.wire import decode_update, encode_update
from repro.core.compression import CodecSpec, compress_pytree
from repro.fed.aggregator import Aggregator

Pytree = Any

DEFAULT_TIMEOUT_S = 600.0   # single-core CI: N children serialize their imports


# --------------------------------------------------------------------------
# The deterministic client program (shared by subprocess and reference).
# --------------------------------------------------------------------------


def demo_params(seed: int = 0, d: int = 48, depth: int = 2,
                n_out: int = 10) -> Pytree:
    """A small dense tree with both quantizable (2-D w) and residual (1-D b)
    leaves — enough to exercise the fused ternary AND fallback agg paths."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(depth):
        tree[f"layer{i}"] = {
            "w": jnp.asarray(0.1 * rng.normal(size=(d, d)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(d,)).astype(np.float32)),
        }
    tree["head"] = {
        "w": jnp.asarray(0.1 * rng.normal(size=(d, n_out)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_out,)).astype(np.float32)),
    }
    return tree


def client_weight(client_id: int) -> float:
    """Deterministic per-client sample count (|D_k|) for the demo clients."""
    return float(40 + 7 * (client_id % 5))


def client_update_blob(start_params: Pytree, client_id: int, seed: int,
                       *, fused_encode: bool = True) -> bytes:
    """One client's egress, as a pure function of its inputs: perturb the
    decoded broadcast with a (seed, client_id)-keyed rng, compress through
    the fused one-pass quantize→pack pipeline, serialize to the wire."""
    leaves, treedef = jax.tree_util.tree_flatten(start_params)
    rng = np.random.default_rng([int(seed), int(client_id)])
    new = [
        jnp.asarray(
            np.asarray(leaf, np.float32)
            + rng.normal(scale=0.05, size=np.shape(leaf)).astype(np.float32)
        )
        for leaf in leaves
    ]
    tree = jax.tree_util.tree_unflatten(treedef, new)
    wire_tree, _ = compress_pytree(
        tree,
        CodecSpec(kind="ternary", residual="fp16", fused_encode=fused_encode),
    )
    return encode_update(wire_tree)


def params_hash(tree: Pytree) -> str:
    """Canonical digest of a dense pytree: sha256 over its wire encoding."""
    return hashlib.sha256(encode_update(tree)).hexdigest()


def _client_main(host: str, port: int, client_id: int, seed: int,
                 timeout_s: float) -> None:
    """Subprocess entry point: one client's whole conversation."""
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        dec = FrameDecoder()
        send_frame(s, FT_HELLO, meta={"client_id": int(client_id)})
        bcast = recv_frame(s, dec, timeout_s=timeout_s)
        if bcast.ftype != FT_BCAST:
            send_frame(s, FT_ERR,
                       meta={"error": f"expected BCAST, got {bcast.ftype}"})
            return
        start = decode_update(bcast.payload)   # CRC re-verified here
        blob = client_update_blob(start, client_id, seed)
        send_frame(s, FT_UPDATE, blob, meta={
            "client_id": int(client_id),
            "weight": client_weight(client_id),
        })
        done = recv_frame(s, dec, timeout_s=timeout_s)
        if done.ftype != FT_DONE:
            raise TransportError(f"expected DONE, got frame type {done.ftype}")


# --------------------------------------------------------------------------
# Mixing (shared by the socket server and the in-process reference).
# --------------------------------------------------------------------------


def _mix_arrivals(global_params: Pytree, arrivals, mode: str, *,
                  chunk_c: int, buffer_k: int, eta: float) -> Pytree:
    """Fold (client_id, weight, blob) arrivals — ALREADY in the order they
    should be consumed — through the existing mix logic."""
    agg = Aggregator(chunk_c=chunk_c)
    if mode == "sync":
        for _cid, weight, blob in arrivals:
            agg.add(blob, weight=weight)
        return agg.finalize()
    if mode == "buffered":
        from repro.fed.async_server import _weighted_mix  # lazy: heavy deps

        out = global_params
        pending = []
        for _cid, weight, blob in arrivals:
            pending.append((weight, blob))
            if len(pending) >= buffer_k:
                out = _weighted_mix(out, pending, eta, agg=agg)
                pending = []
        if pending:
            out = _weighted_mix(out, pending, eta, agg=agg)
        return out
    raise ValueError(f"unknown mode {mode!r} (sync | buffered)")


def run_inprocess_reference(
    global_params: Pytree, n_clients: int, *, seed: int = 0,
    mode: str = "sync", chunk_c: int = 16, buffer_k: int = 4,
    eta: float = 0.5, order: list[int] | None = None,
) -> Pytree:
    """The no-sockets reference: identical broadcast decode, identical
    per-client update derivation, identical mix — in ``order`` (default
    client_id order, which is what the socket sync barrier replays)."""
    blob = encode_update(global_params)
    start = decode_update(blob)                 # decode exactly like a client
    ids = list(range(n_clients)) if order is None else list(order)
    arrivals = [
        (cid, client_weight(cid), client_update_blob(start, cid, seed))
        for cid in ids
    ]
    return _mix_arrivals(global_params, arrivals, mode,
                         chunk_c=chunk_c, buffer_k=buffer_k, eta=eta)


# --------------------------------------------------------------------------
# The socket server.
# --------------------------------------------------------------------------


@dataclasses.dataclass
class SocketRoundResult:
    params: Pytree              # the post-round global model (dense)
    n_clients: int
    arrivals: list[int]         # client ids in true socket-arrival order
    upload_bytes: int           # Σ FrameDecoder.bytes_in — actual socket reads
    download_bytes: int         # Σ send_frame returns — actual socket writes
    payload_bytes: int          # Σ len(update wire buffer) (for overhead calc)
    wall_s: float
    mode: str

    @property
    def framing_overhead_bytes(self) -> int:
        """Upload bytes that were transport framing, not wire payload."""
        return self.upload_bytes - self.payload_bytes

    def ledger(self) -> dict:
        return {
            "mode": self.mode,
            "n_clients": self.n_clients,
            "arrivals": self.arrivals,
            "upload_bytes": self.upload_bytes,
            "download_bytes": self.download_bytes,
            "payload_bytes": self.payload_bytes,
            "framing_overhead_bytes": self.framing_overhead_bytes,
            "wall_s": self.wall_s,
            "params_sha256": params_hash(self.params),
        }


def _handle_connection(conn: socket.socket, bcast_blob: bytes,
                       timeout_s: float) -> tuple[int, float, bytes, int, int]:
    """One client conversation on the server side.

    Returns (client_id, weight, update_blob, bytes_read, bytes_written).
    """
    conn.settimeout(timeout_s)
    dec = FrameDecoder()
    sent = 0
    hello = recv_frame(conn, dec, timeout_s=timeout_s)
    if hello.ftype == FT_ERR:
        raise TransportError(f"client error: {hello.meta.get('error')}")
    if hello.ftype != FT_HELLO or "client_id" not in hello.meta:
        raise TransportError(f"expected HELLO with client_id, got {hello.ftype}")
    cid = int(hello.meta["client_id"])
    sent += send_frame(conn, FT_BCAST, bcast_blob)
    update = recv_frame(conn, dec, timeout_s=timeout_s)
    if update.ftype == FT_ERR:
        raise TransportError(f"client {cid} error: {update.meta.get('error')}")
    if update.ftype != FT_UPDATE:
        raise TransportError(f"client {cid}: expected UPDATE, got {update.ftype}")
    if int(update.meta.get("client_id", -1)) != cid:
        raise TransportError(f"client id changed mid-conversation for {cid}")
    weight = float(update.meta["weight"])
    sent += send_frame(conn, FT_DONE)
    return cid, weight, update.payload, dec.bytes_in, sent


def run_socket_round(
    global_params: Pytree, n_clients: int, *, seed: int = 0,
    mode: str = "sync", chunk_c: int = 16, buffer_k: int = 4,
    eta: float = 0.5, host: str = "127.0.0.1",
    timeout_s: float = DEFAULT_TIMEOUT_S, start_method: str = "spawn",
) -> SocketRoundResult:
    """One federated round over real TCP with ``n_clients`` OS processes.

    The server binds an ephemeral loopback port, spawns the clients, and
    services connections from a sequential accept loop (the OS backlog
    holds late connectors; each conversation is short). A hung or dead
    client surfaces as a socket timeout → ``TransportError``, and every
    child is terminated on the way out — the accept loop cannot hang CI.
    """
    if n_clients < 1:
        raise ValueError(f"n_clients must be ≥ 1, got {n_clients}")
    if mode not in ("sync", "buffered"):
        raise ValueError(f"unknown mode {mode!r} (sync | buffered)")
    ctx = mp.get_context(start_method)
    bcast_blob = encode_update(global_params)

    t0 = time.perf_counter()
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    procs: list = []
    up_bytes = down_bytes = payload_bytes = 0
    arrivals: list[tuple[int, float, bytes]] = []
    try:
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, 0))
        srv.listen(n_clients)
        srv.settimeout(timeout_s)
        port = srv.getsockname()[1]
        for cid in range(n_clients):
            p = ctx.Process(
                target=_client_main,
                args=(host, port, cid, seed, timeout_s),
                daemon=True,
            )
            p.start()
            procs.append(p)
        seen: set[int] = set()
        for _ in range(n_clients):
            conn, _addr = srv.accept()
            try:
                cid, weight, blob, got, sent = _handle_connection(
                    conn, bcast_blob, timeout_s
                )
            finally:
                conn.close()
            if cid in seen:
                raise TransportError(f"duplicate client_id {cid}")
            seen.add(cid)
            arrivals.append((cid, weight, blob))
            up_bytes += got
            down_bytes += sent
            payload_bytes += len(blob)
        for p in procs:
            p.join(timeout=timeout_s)
            if p.exitcode != 0:
                raise RuntimeError(
                    f"client process exited with code {p.exitcode}"
                )
    finally:
        srv.close()
        for p in procs:
            if p.is_alive():
                p.terminate()
                p.join(timeout=5)

    arrival_order = [cid for cid, _, _ in arrivals]
    # sync: the barrier has everything — replay in client_id order, the
    # same order the in-process reference uses (byte-identity contract).
    # buffered: fold in true arrival order, FedBuf-style.
    consume = sorted(arrivals) if mode == "sync" else arrivals
    params = _mix_arrivals(global_params, consume, mode,
                           chunk_c=chunk_c, buffer_k=buffer_k, eta=eta)
    return SocketRoundResult(
        params=params,
        n_clients=n_clients,
        arrivals=arrival_order,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        payload_bytes=payload_bytes,
        wall_s=time.perf_counter() - t0,
        mode=mode,
    )


# --------------------------------------------------------------------------
# CLI demo / CI smoke.
# --------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Federated round over real TCP with N client processes"
    )
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--mode", choices=("sync", "buffered"), default="sync")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-c", type=int, default=16)
    ap.add_argument("--buffer-k", type=int, default=4)
    ap.add_argument("--eta", type=float, default=0.5)
    ap.add_argument("--timeout-s", type=float, default=DEFAULT_TIMEOUT_S)
    ap.add_argument("--check", action="store_true",
                    help="also run the in-process reference and require a "
                         "byte-identical aggregate")
    args = ap.parse_args(argv)

    params = demo_params(seed=args.seed)
    res = run_socket_round(
        params, args.clients, seed=args.seed, mode=args.mode,
        chunk_c=args.chunk_c, buffer_k=args.buffer_k, eta=args.eta,
        timeout_s=args.timeout_s,
    )
    ledger = res.ledger()
    if args.check:
        order = None if args.mode == "sync" else res.arrivals
        ref = run_inprocess_reference(
            params, args.clients, seed=args.seed, mode=args.mode,
            chunk_c=args.chunk_c, buffer_k=args.buffer_k, eta=args.eta,
            order=order,
        )
        ledger["reference_sha256"] = params_hash(ref)
        ledger["byte_identical"] = (
            ledger["reference_sha256"] == ledger["params_sha256"]
        )
    print(json.dumps(ledger, indent=2))
    if args.check and not ledger["byte_identical"]:
        print("FAIL: socket aggregate differs from in-process reference",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
