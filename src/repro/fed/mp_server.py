"""Cross-process federation over real TCP sockets, fault-tolerant.

Everything upstream of this module simulates its event loop; here the wire
codec crosses a REAL process boundary — and survives that boundary failing.
``run_socket_round`` puts the long-lived streaming ``Aggregator`` behind a
CONCURRENT (threaded accept + per-connection handler) server on a loopback
socket and spawns N genuine client OS processes (``multiprocessing`` spawn
context — each child is a fresh interpreter with its own JAX runtime).

The conversation (HELLO protocol v2)::

  client                             server
    HELLO {client_id, proto, nonce,
           attempt[, resume]}  ───►
                               ◄───  BCAST (global model wire buffer)
                                     · or RESUME {have} when this nonce
                                       already shipped `have` bytes of its
                                       UPDATE frame (re-connect resumes the
                                       upload instead of re-sending)
                                     · or DONE when the update already
                                       landed (idempotent HELLO — a client
                                       that lost the DONE re-asks safely)
                                     · or ERR {error} (unsupported proto →
                                       outcome "rejected")
    UPDATE frame bytes[have:]  ───►
                               ◄───  DONE

A v1 HELLO (no ``proto`` key — the PR-7 client) still speaks the original
one-shot conversation; the server negotiates down and never sends RESUME.

Fault tolerance (the paper's clients are flaky mobile/IoT devices):

  - clients reconnect with exponential backoff + seeded jitter
    (``transport.RetryPolicy``) and RESUME mid-frame — the server keeps a
    per-(client, nonce) session whose ``FrameDecoder`` retains the partial
    UPDATE across connections, so a torn link costs the tail, not the blob;
  - the round commits under a QUORUM: once ``quorum_frac`` of clients land
    and the deadline passes (or every live client lands), stragglers are
    booked as dropped bytes instead of failing the round;
  - crashed client processes are detected by exit code and removed from the
    expected set; unjoinable children escalate ``terminate()`` → ``kill()``;
  - every client ends the round with an outcome in
    ``ok | timeout | torn | crashed | rejected | quarantined``, and the
    update-byte ledger balances:
    shipped == ingested + dropped + quarantined (asserted in ``ledger()``).

Byzantine robustness (PR 9): with ``defense=DefenseConfig(enabled=True)``
every landed update passes the content gate (``fed.defense.UpdateGate``)
before it is booked — structure vs the broadcast, finite/bounded scales,
code-plane sanity. A refused payload gets outcome ``quarantined``: the
client is acked with DONE (it must not retry), its frame bytes are booked
in the quarantine ledger bucket, and it never reaches the aggregator. The
``attack=AttackConfig(...)`` knob turns a seeded subset of the demo
clients into Byzantine senders (``fed.attackers``) for smoke tests.

Arrival handling feeds the same mix logic the simulators use:

  - mode="sync": handlers stream arrivals concurrently into a barrier; at
    commit they are replayed into the ``Aggregator`` in client_id order —
    exactly the order the in-process reference uses — so the root aggregate
    is BYTE-IDENTICAL to ``run_inprocess_reference`` restricted to the
    surviving client set (same add order ⇒ same chunk-flush boundaries ⇒
    same float op order).
  - mode="buffered": the driver folds every ``buffer_k`` arrivals into the
    global with the buffered-async server's ``_weighted_mix`` WHILE other
    clients are still uploading (recv overlaps aggregation), in true
    arrival order. Byte-identity against the reference holds when the
    reference replays the recorded arrival order (``order=result.arrivals``).

Chaos determinism: with ``fault_cfg`` a ``comm.faults.ChaosProxy`` sits
in-path, injecting drops/delays/mid-frame truncation keyed by
``(fault seed, client_id, attempt)`` at absolute byte offsets — the
surviving-client set and therefore the committed aggregate are pure
functions of the seeds (``tests/test_chaos_round.py``).

Byte accounting is metered from ACTUAL socket traffic: upload bytes are
summed from every ``recv()`` the server issued, download bytes from
``send_frame`` returns — not payload-length arithmetic.

CLI demo (also the CI smoke)::

    PYTHONPATH=src python -m repro.fed.mp_server --clients 4 --check
    PYTHONPATH=src python -m repro.fed.mp_server --clients 6 --chaos --check
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import math
import multiprocessing as mp
import os
import socket
import sys
import threading
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.faults import ChaosProxy, FaultConfig
from repro.comm.transport import (
    FT_BCAST,
    FT_DONE,
    FT_ERR,
    FT_HELLO,
    FT_RESUME,
    FT_UPDATE,
    PROTO_V1,
    PROTO_VERSION,
    RECV_CHUNK,
    SUPPORTED_PROTOS,
    Frame,
    FrameDecoder,
    FrameError,
    ProtocolError,
    RetryExhausted,
    RetryPolicy,
    TornConnectionError,
    TransportError,
    call_with_retries,
    pack_frame,
    recv_frame,
    send_frame,
)
from repro.comm.wire import decode_update, encode_update
from repro.core.compression import CodecSpec, compress_pytree
from repro.fed.aggregator import Aggregator
from repro.fed.attackers import AttackConfig, attacker_ids, poison_blob
from repro.fed.defense import DefenseConfig, UpdateGate

Pytree = Any

DEFAULT_TIMEOUT_S = 600.0   # single-core CI: N children serialize their imports

# child exit codes — the server's process watcher maps them onto outcomes
EXIT_OK = 0
EXIT_RETRY_EXHAUSTED = 3    # outcome "torn": the link never let it finish
EXIT_REJECTED = 4           # outcome "rejected": server refused the protocol
EXIT_CRASH = 40             # outcome "crashed": injected mid-upload crash

OUTCOMES = ("ok", "timeout", "torn", "crashed", "rejected", "quarantined")


class QuorumNotMetError(RuntimeError):
    """The round deadline passed (or every live client resolved) with fewer
    than ``quorum_frac · n_clients`` updates landed."""


# --------------------------------------------------------------------------
# The deterministic client program (shared by subprocess and reference).
# --------------------------------------------------------------------------


def demo_params(seed: int = 0, d: int = 48, depth: int = 2,
                n_out: int = 10) -> Pytree:
    """A small dense tree with both quantizable (2-D w) and residual (1-D b)
    leaves — enough to exercise the fused ternary AND fallback agg paths."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(depth):
        tree[f"layer{i}"] = {
            "w": jnp.asarray(0.1 * rng.normal(size=(d, d)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(d,)).astype(np.float32)),
        }
    tree["head"] = {
        "w": jnp.asarray(0.1 * rng.normal(size=(d, n_out)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_out,)).astype(np.float32)),
    }
    return tree


def client_weight(client_id: int) -> float:
    """Deterministic per-client sample count (|D_k|) for the demo clients."""
    return float(40 + 7 * (client_id % 5))


def client_update_blob(start_params: Pytree, client_id: int, seed: int,
                       *, fused_encode: bool = True) -> bytes:
    """One client's egress, as a pure function of its inputs: perturb the
    decoded broadcast with a (seed, client_id)-keyed rng, compress through
    the fused one-pass quantize→pack pipeline, serialize to the wire."""
    leaves, treedef = jax.tree_util.tree_flatten(start_params)
    rng = np.random.default_rng([int(seed), int(client_id)])
    new = [
        jnp.asarray(
            np.asarray(leaf, np.float32)
            + rng.normal(scale=0.05, size=np.shape(leaf)).astype(np.float32)
        )
        for leaf in leaves
    ]
    tree = jax.tree_util.tree_unflatten(treedef, new)
    wire_tree, _ = compress_pytree(
        tree,
        CodecSpec(kind="ternary", residual="fp16", fused_encode=fused_encode),
    )
    return encode_update(wire_tree)


def params_hash(tree: Pytree) -> str:
    """Canonical digest of a dense pytree: sha256 over its wire encoding."""
    return hashlib.sha256(encode_update(tree)).hexdigest()


def client_nonce(seed: int, client_id: int) -> str:
    """The per-process upload identity: deterministic (tests replay it),
    unique per (seed, client) — a reconnect with the same nonce may resume,
    a different nonce voids the old session."""
    rng = np.random.default_rng([int(seed), int(client_id), 0xA0CE])
    return bytes(rng.integers(0, 256, size=8, dtype=np.uint8)).hex()


class _Rejected(Exception):
    """Client-side: the server refused us outright — do not retry."""


def _client_main(host: str, port: int, client_id: int, seed: int,
                 timeout_s: float, policy: RetryPolicy | None = None,
                 crash_after_frac: float | None = None,
                 proto: int = PROTO_VERSION,
                 attack: AttackConfig | None = None) -> None:
    """Subprocess entry point: one client's whole (retrying) conversation.

    Reconnects with exponential backoff + seeded jitter on any transport
    failure; on reconnect the HELLO carries the same nonce so the server
    can offer a RESUME offset, and the client ships only the un-received
    tail of its UPDATE frame. ``proto=1`` speaks the legacy PR-7
    conversation (single shot, no resume). ``crash_after_frac`` simulates
    a device dying mid-upload: send that fraction of the remaining body,
    then hard-exit."""
    if proto == PROTO_V1:
        _client_main_v1(host, port, client_id, seed, timeout_s)
        return
    policy = policy or RetryPolicy(io_timeout_s=timeout_s)
    nonce = client_nonce(seed, client_id)
    backoff_rng = np.random.default_rng([int(seed), int(client_id), 0xB0FF])
    state: dict[str, Any] = {"frame": None}

    def attempt(k: int) -> None:
        with socket.create_connection(
            (host, port), timeout=policy.connect_timeout_s
        ) as s:
            s.settimeout(timeout_s)
            dec = FrameDecoder()
            meta = {"client_id": int(client_id), "proto": int(proto),
                    "nonce": nonce, "attempt": int(k)}
            if state["frame"] is not None:
                meta["resume"] = True
            send_frame(s, FT_HELLO, meta=meta)
            reply = recv_frame(s, dec, timeout_s=timeout_s)
            if reply.ftype == FT_ERR:
                raise _Rejected(reply.meta.get("error", "rejected"))
            if reply.ftype == FT_DONE:
                return          # idempotent HELLO: the server already has it
            if reply.ftype == FT_RESUME:
                have = int(reply.meta["have"])
                if state["frame"] is None or have > len(state["frame"]):
                    raise ProtocolError(f"un-resumable offset {have}")
            elif reply.ftype == FT_BCAST:
                start = decode_update(reply.payload)   # CRC re-verified here
                blob = client_update_blob(start, client_id, seed)
                if attack is not None:
                    # a Byzantine demo client: poison the honest payload
                    # client-side (still framed/CRC'd normally — wire-valid)
                    blob = poison_blob(blob, attack, client_id)
                state["frame"] = pack_frame(FT_UPDATE, blob, {
                    "client_id": int(client_id),
                    "weight": client_weight(client_id),
                })
                have = 0
            else:
                raise ProtocolError(f"unexpected reply frame {reply.ftype}")
            body = state["frame"][have:]
            if crash_after_frac is not None:
                s.sendall(body[: int(len(body) * float(crash_after_frac))])
                os._exit(EXIT_CRASH)     # the injected device death
            s.sendall(body)
            done = recv_frame(s, dec, timeout_s=timeout_s)
            if done.ftype != FT_DONE:
                raise ProtocolError(
                    f"expected DONE, got frame type {done.ftype}")

    try:
        call_with_retries(attempt, policy, rng=backoff_rng, fatal=(_Rejected,))
    except _Rejected:
        sys.exit(EXIT_REJECTED)
    except RetryExhausted:
        sys.exit(EXIT_RETRY_EXHAUSTED)


def _client_main_v1(host: str, port: int, client_id: int, seed: int,
                    timeout_s: float) -> None:
    """The PR-7 client, byte-for-byte: HELLO {client_id} → BCAST → UPDATE →
    DONE, no retry, no resume. Kept runnable to prove version negotiation."""
    with socket.create_connection((host, port), timeout=timeout_s) as s:
        dec = FrameDecoder()
        send_frame(s, FT_HELLO, meta={"client_id": int(client_id)})
        bcast = recv_frame(s, dec, timeout_s=timeout_s)
        if bcast.ftype != FT_BCAST:
            send_frame(s, FT_ERR,
                       meta={"error": f"expected BCAST, got {bcast.ftype}"})
            return
        start = decode_update(bcast.payload)
        blob = client_update_blob(start, client_id, seed)
        send_frame(s, FT_UPDATE, blob, meta={
            "client_id": int(client_id),
            "weight": client_weight(client_id),
        })
        done = recv_frame(s, dec, timeout_s=timeout_s)
        if done.ftype != FT_DONE:
            raise TransportError(f"expected DONE, got frame type {done.ftype}")


# --------------------------------------------------------------------------
# Mixing (shared by the socket server and the in-process reference).
# --------------------------------------------------------------------------


def _mix_arrivals(global_params: Pytree, arrivals, mode: str, *,
                  chunk_c: int, buffer_k: int, eta: float,
                  rule: str = "mean", trim_frac: float = 0.2) -> Pytree:
    """Fold (client_id, weight, blob) arrivals — ALREADY in the order they
    should be consumed — through the existing mix logic."""
    agg = Aggregator(chunk_c=chunk_c, rule=rule, trim_frac=trim_frac)
    if mode == "sync":
        for _cid, weight, blob in arrivals:
            agg.add(blob, weight=weight)
        return agg.finalize()
    if mode == "buffered":
        from repro.fed.async_server import _weighted_mix  # lazy: heavy deps

        out = global_params
        pending = []
        for _cid, weight, blob in arrivals:
            pending.append((weight, blob))
            if len(pending) >= buffer_k:
                out = _weighted_mix(out, pending, eta, agg=agg)
                pending = []
        if pending:
            out = _weighted_mix(out, pending, eta, agg=agg)
        return out
    raise ValueError(f"unknown mode {mode!r} (sync | buffered)")


def run_inprocess_reference(
    global_params: Pytree, n_clients: int, *, seed: int = 0,
    mode: str = "sync", chunk_c: int = 16, buffer_k: int = 4,
    eta: float = 0.5, order: list[int] | None = None,
    rule: str = "mean", trim_frac: float = 0.2,
) -> Pytree:
    """The no-sockets reference: identical broadcast decode, identical
    per-client update derivation, identical mix — in ``order`` (default
    client_id order, which is what the socket sync barrier replays). Under
    a quorum commit pass the SURVIVING client ids: sorted for sync,
    ``result.arrivals`` for buffered. Under a defense round pass the
    HONEST survivors (quarantined clients never reach the socket
    aggregator either) and the same ``rule``."""
    blob = encode_update(global_params)
    start = decode_update(blob)                 # decode exactly like a client
    ids = list(range(n_clients)) if order is None else list(order)
    arrivals = [
        (cid, client_weight(cid), client_update_blob(start, cid, seed))
        for cid in ids
    ]
    return _mix_arrivals(global_params, arrivals, mode,
                         chunk_c=chunk_c, buffer_k=buffer_k, eta=eta,
                         rule=rule, trim_frac=trim_frac)


# --------------------------------------------------------------------------
# The concurrent socket server.
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Session:
    """One client's resumable upload: survives connections, owned by the
    NEWEST connection (``generation`` fences superseded handlers)."""

    cid: int
    nonce: str
    dec: FrameDecoder = dataclasses.field(default_factory=FrameDecoder)
    generation: int = 0
    attempts: int = 0
    completed: bool = False
    frame_bytes: int = 0        # nbytes_framed once completed


class _RoundState:
    """Everything the handler threads and the round driver share."""

    def __init__(self):
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        self.sessions: dict[int, _Session] = {}
        self.completed: list[tuple[int, float, bytes]] = []  # arrival order
        self.completed_ids: set[int] = set()
        self.rejected: dict[int, str] = {}
        self.quarantined: dict[int, tuple[str, int]] = {}  # cid → (reason, B)
        self.quarantined_update_bytes = 0
        self.gate: UpdateGate | None = None   # set when defense is enabled
        self.closing = False
        self.up_bytes = 0
        self.down_bytes = 0
        self.payload_bytes = 0
        self.ingested_update_bytes = 0
        self.dropped_update_bytes = 0
        self.v1_update_bytes = 0        # v1 frames never live in a session
        self.superseded_bytes = 0       # voided sessions (nonce changed)
        self.resumed_bytes = 0
        self.retries = 0
        self.errors: list[str] = []       # handler-side failures (debugging)

    def note_error(self, msg: str) -> None:
        with self.lock:
            if len(self.errors) < 64:
                self.errors.append(msg)


def _book_completed(state: _RoundState, cid: int, weight: float,
                    payload: bytes, frame_bytes: int) -> bool:
    """Record one landed update — through the content gate when defense is
    on. True iff NEWLY booked, as completed OR quarantined (idempotent: a
    duplicate or post-commit arrival books nothing and returns False). A
    quarantined client is still acked with DONE — its upload is over; the
    poison just never reaches the aggregate."""
    with state.cond:
        if (cid in state.completed_ids or cid in state.quarantined
                or state.closing):
            return False
        if state.gate is not None:
            verdict = state.gate.check(payload)
            if not verdict.ok:
                state.quarantined[cid] = (verdict.reason, frame_bytes)
                state.quarantined_update_bytes += frame_bytes
                state.cond.notify_all()
                return True
        state.completed_ids.add(cid)
        state.completed.append((cid, weight, payload))
        state.payload_bytes += len(payload)
        state.ingested_update_bytes += frame_bytes
        state.cond.notify_all()
    return True


def _poll_frame(conn: socket.socket, dec: FrameDecoder, state: _RoundState,
                timeout_s: float) -> Frame | None:
    """Receive one frame with SHORT socket polls so handler threads notice
    the round committing — a client that never speaks must not pin a
    handler (and a 5s commit join) for the full conversation timeout.
    Returns None when the round closed underneath the wait."""
    deadline = time.monotonic() + timeout_s
    conn.settimeout(0.25)
    while True:
        frame = dec.pop()
        if frame is not None:
            return frame
        with state.lock:
            if state.closing:
                return None
        if time.monotonic() > deadline:
            raise TornConnectionError(f"no frame within {timeout_s}s")
        try:
            chunk = conn.recv(RECV_CHUNK)
        except socket.timeout:
            continue
        except OSError as e:
            raise TornConnectionError(f"connection lost: {e}") from e
        if not chunk:
            dec.close()      # raises TornConnectionError on a partial frame
            raise TornConnectionError("connection closed before a frame")
        dec.feed(chunk)


def _validate_update(frame: Frame, cid: int) -> float:
    if frame.ftype == FT_ERR:
        raise ProtocolError(f"client {cid} error: {frame.meta.get('error')}")
    if frame.ftype != FT_UPDATE:
        raise ProtocolError(
            f"client {cid}: expected UPDATE, got {frame.ftype}")
    if int(frame.meta.get("client_id", -1)) != cid:
        raise ProtocolError(f"client id changed mid-conversation for {cid}")
    # a missing / non-numeric / non-finite / negative weight would crash the
    # handler (KeyError) or poison the aggregate denominator — it is a
    # malformed frame, and FrameError maps it onto the "rejected" outcome.
    weight = frame.meta.get("weight")
    try:
        weight = float(weight)
    except (TypeError, ValueError):
        raise FrameError(
            f"client {cid}: UPDATE weight meta missing or non-numeric: "
            f"{frame.meta.get('weight')!r}") from None
    if not math.isfinite(weight) or weight < 0:
        raise FrameError(
            f"client {cid}: UPDATE weight must be finite and >= 0, "
            f"got {weight!r}")
    return weight


def _serve_v2(conn: socket.socket, hello: Frame, hello_dec: FrameDecoder,
              state: _RoundState, bcast_blob: bytes, timeout_s: float) -> None:
    """One v2 connection: session claim → BCAST/RESUME/DONE → stream the
    UPDATE frame into the session's long-lived decoder → DONE. On any
    failure the session (and its partial bytes) survives for the next
    reconnect; only the handler dies."""
    cid = int(hello.meta["client_id"])
    nonce = str(hello.meta.get("nonce", ""))
    attempt = int(hello.meta.get("attempt", 0))
    deadline = time.monotonic() + timeout_s
    with state.cond:
        if attempt > 0:
            state.retries += 1
        if cid in state.completed_ids or cid in state.quarantined:
            sess = None                       # already landed: just ack
        else:
            sess = state.sessions.get(cid)
            if sess is None or sess.nonce != nonce:
                if sess is not None:          # a new upload voids the old
                    state.dropped_update_bytes += sess.dec.bytes_in
                    state.superseded_bytes += sess.dec.bytes_in
                sess = _Session(cid=cid, nonce=nonce)
                state.sessions[cid] = sess
            sess.generation += 1
            sess.attempts += 1
            gen = sess.generation
    if sess is None:
        with state.lock:
            state.down_bytes += send_frame(conn, FT_DONE,
                                           meta={"proto": PROTO_VERSION})
        return
    # over-read past the HELLO belongs to the UPDATE stream (already counted
    # in up_bytes via hello_dec — do not re-count, but DO re-offset)
    leftover = hello_dec.take_buffer()
    have = sess.dec.bytes_in
    if hello.meta.get("resume") and not sess.completed:
        reply = pack_frame(FT_RESUME, meta={"have": have,
                                            "proto": PROTO_VERSION})
        with state.lock:
            state.resumed_bytes += have
    else:
        reply = pack_frame(FT_BCAST, bcast_blob, meta={"proto": PROTO_VERSION})
    conn.sendall(reply)
    with state.lock:
        state.down_bytes += len(reply)
    frame: Frame | None = None
    if leftover:
        frames = sess.dec.feed(leftover)
        frame = frames[0] if frames else None
    conn.settimeout(0.25)      # short poll: handlers must notice closing
    while frame is None:
        with state.lock:
            superseded = sess.generation != gen
            closing = state.closing
        if superseded or closing:
            return             # the reconnect (or the commit) owns it now
        if time.monotonic() > deadline:
            raise TornConnectionError(f"client {cid}: conversation timed out")
        try:
            chunk = conn.recv(RECV_CHUNK)
        except socket.timeout:
            continue
        except OSError as e:
            raise TornConnectionError(f"client {cid}: {e}") from e
        if not chunk:
            raise TornConnectionError(
                f"client {cid}: closed with {sess.dec.pending_bytes} bytes "
                "of its update pending")
        with state.lock:
            state.up_bytes += len(chunk)
        frames = sess.dec.feed(chunk)      # FrameError on garbage → rejected
        frame = frames[0] if frames else None
    weight = _validate_update(frame, cid)
    with state.lock:
        sess.completed = True
        sess.frame_bytes = frame.nbytes_framed
    _book_completed(state, cid, weight, frame.payload, frame.nbytes_framed)
    with state.lock:
        state.down_bytes += send_frame(conn, FT_DONE,
                                       meta={"proto": PROTO_VERSION})


def _serve_v1(conn: socket.socket, hello: Frame, hello_dec: FrameDecoder,
              state: _RoundState, bcast_blob: bytes, timeout_s: float) -> None:
    """The PR-7 conversation for legacy clients: one shot, no session."""
    cid = int(hello.meta["client_id"])
    with state.lock:
        state.down_bytes += send_frame(conn, FT_BCAST, bcast_blob)
    update = _poll_frame(conn, hello_dec, state, timeout_s)
    if update is None:      # round closed while waiting
        return
    weight = _validate_update(update, cid)
    if not _book_completed(state, cid, weight, update.payload,
                           update.nbytes_framed):
        raise ProtocolError(f"duplicate client_id {cid}")
    with state.lock:
        state.v1_update_bytes += update.nbytes_framed
        state.down_bytes += send_frame(conn, FT_DONE)


def _serve_connection(conn: socket.socket, state: _RoundState,
                      bcast_blob: bytes, timeout_s: float) -> None:
    """Handler-thread body: dispatch one accepted connection by protocol
    version; book rejections; never let an exception escape the thread."""
    hello_dec = FrameDecoder()
    cid = -1
    try:
        try:
            hello = _poll_frame(conn, hello_dec, state, timeout_s)
            if hello is None:   # round closed before the client spoke
                return
            if hello.ftype == FT_ERR:
                raise ProtocolError(
                    f"client error: {hello.meta.get('error')}")
            if hello.ftype != FT_HELLO or "client_id" not in hello.meta:
                raise ProtocolError(
                    f"expected HELLO with client_id, got {hello.ftype}")
            cid = int(hello.meta["client_id"])
            proto = int(hello.meta.get("proto", PROTO_V1))
            if proto not in SUPPORTED_PROTOS:
                err = pack_frame(FT_ERR, meta={
                    "error": f"unsupported proto {proto}",
                    "supported": sorted(SUPPORTED_PROTOS),
                })
                conn.sendall(err)
                with state.cond:
                    state.rejected[cid] = f"unsupported proto {proto}"
                    state.down_bytes += len(err)
                    state.cond.notify_all()
                return
            if proto == PROTO_V1:
                _serve_v1(conn, hello, hello_dec, state, bcast_blob,
                          timeout_s)
            else:
                _serve_v2(conn, hello, hello_dec, state, bcast_blob,
                          timeout_s)
        finally:
            with state.lock:
                state.up_bytes += hello_dec.bytes_in - hello_dec.pending_bytes
    except FrameError as e:
        # garbage on the wire is a rejection, not a retryable tear
        with state.cond:
            if cid >= 0:
                state.rejected[cid] = str(e)
            state.cond.notify_all()
        state.note_error(f"frame error (cid {cid}): {e}")
    except (TransportError, OSError) as e:
        state.note_error(f"torn (cid {cid}): {e}")   # session retained
    finally:
        try:
            conn.close()
        except OSError:
            pass


def _accept_loop(srv: socket.socket, state: _RoundState, bcast_blob: bytes,
                 timeout_s: float, handlers: list[threading.Thread]) -> None:
    while True:
        with state.lock:
            if state.closing:
                return
        try:
            conn, _addr = srv.accept()
        except socket.timeout:
            continue
        except OSError:
            return              # listener closed at commit
        t = threading.Thread(
            target=_serve_connection,
            args=(conn, state, bcast_blob, timeout_s),
            daemon=True,
        )
        t.start()
        handlers.append(t)


def reap_processes(procs: list, grace_s: float = 5.0) -> dict:
    """join → terminate → kill escalation for child processes.

    Every child gets ``grace_s`` (shared) to exit on its own; survivors are
    ``terminate()``d (SIGTERM), given another grace, then ``kill()``ed
    (SIGKILL — unmaskable) so a client wedged in an uninterruptible recv
    can NEVER outlive the round. Returns the escalation tally."""
    esc = {"terminated": 0, "killed": 0}
    end = time.monotonic() + grace_s
    for p in procs:
        p.join(timeout=max(0.0, end - time.monotonic()))
    for p in procs:
        if p.is_alive():
            p.terminate()
            esc["terminated"] += 1
    if esc["terminated"]:
        end = time.monotonic() + grace_s
        for p in procs:
            if p.is_alive():
                p.join(timeout=max(0.0, end - time.monotonic()))
        for p in procs:
            if p.is_alive():
                p.kill()
                esc["killed"] += 1
                p.join(timeout=grace_s)
    return esc


@dataclasses.dataclass
class SocketRoundResult:
    params: Pytree              # the post-round global model (dense)
    n_clients: int
    arrivals: list[int]         # surviving client ids in true arrival order
    upload_bytes: int           # Σ server recv() bytes — actual socket reads
    download_bytes: int         # Σ send_frame returns — actual socket writes
    payload_bytes: int          # Σ len(ingested update wire buffers)
    wall_s: float
    mode: str
    # fault-tolerance surface (defaults = the no-fault PR-7 shape)
    outcomes: dict[int, str] = dataclasses.field(default_factory=dict)
    committed: str = "full"     # "full" | "quorum"
    quorum_frac: float = 1.0
    quorum_n: int = 0
    shipped_update_bytes: int = 0   # every UPDATE-frame byte that arrived
    ingested_update_bytes: int = 0  # ... folded into the aggregate
    dropped_update_bytes: int = 0   # ... paid for but never folded
    quarantined_update_bytes: int = 0  # ... refused by the content gate
    resumed_bytes: int = 0      # upload bytes SAVED by mid-frame resume
    retries: int = 0            # reconnect attempts observed (attempt > 0)
    escalations: dict = dataclasses.field(
        default_factory=lambda: {"terminated": 0, "killed": 0})
    chaos: dict | None = None   # ChaosProxy.stats when a fault_cfg ran
    defense: dict | None = None  # UpdateGate.telemetry() when defense ran

    @property
    def framing_overhead_bytes(self) -> int:
        """Upload bytes that were transport framing, not wire payload."""
        return self.upload_bytes - self.payload_bytes

    @property
    def n_survivors(self) -> int:
        return len(self.arrivals)

    def ledger(self) -> dict:
        """The round's byte/outcome ledger. The update-byte balance
        invariant — shipped == ingested + dropped + quarantined — is
        checked here; a ``False`` means the server lost track of bytes it
        read."""
        balance_ok = (self.shipped_update_bytes
                      == self.ingested_update_bytes
                      + self.dropped_update_bytes
                      + self.quarantined_update_bytes)
        return {
            "mode": self.mode,
            "n_clients": self.n_clients,
            "n_survivors": self.n_survivors,
            "arrivals": self.arrivals,
            "outcomes": {str(k): v for k, v in sorted(self.outcomes.items())},
            "committed": self.committed,
            "quorum_frac": self.quorum_frac,
            "quorum_n": self.quorum_n,
            "upload_bytes": self.upload_bytes,
            "download_bytes": self.download_bytes,
            "payload_bytes": self.payload_bytes,
            "framing_overhead_bytes": self.framing_overhead_bytes,
            "shipped_update_bytes": self.shipped_update_bytes,
            "ingested_update_bytes": self.ingested_update_bytes,
            "dropped_update_bytes": self.dropped_update_bytes,
            "quarantined_update_bytes": self.quarantined_update_bytes,
            "balance_ok": balance_ok,
            "defense": self.defense,
            "resumed_bytes": self.resumed_bytes,
            "retries": self.retries,
            "escalations": self.escalations,
            "chaos": self.chaos,
            "wall_s": self.wall_s,
            "params_sha256": params_hash(self.params),
        }


def _final_outcomes(state: _RoundState, procs: dict[int, Any]) -> dict[int, str]:
    """Map every client onto
    ok | timeout | torn | crashed | rejected | quarantined."""
    out: dict[int, str] = {}
    for cid, p in procs.items():
        if cid in state.completed_ids:
            out[cid] = "ok"
        elif cid in state.quarantined:
            out[cid] = "quarantined"
        elif cid in state.rejected:
            out[cid] = "rejected"
        elif p.exitcode == EXIT_REJECTED:
            out[cid] = "rejected"
        elif p.exitcode == EXIT_RETRY_EXHAUSTED:
            out[cid] = "torn"
        elif p.exitcode not in (None, EXIT_OK):
            out[cid] = "crashed"
        else:
            out[cid] = "timeout"    # still running / never landed by commit
    return out


def run_socket_round(
    global_params: Pytree, n_clients: int, *, seed: int = 0,
    mode: str = "sync", chunk_c: int = 16, buffer_k: int = 4,
    eta: float = 0.5, host: str = "127.0.0.1",
    timeout_s: float = DEFAULT_TIMEOUT_S, start_method: str = "spawn",
    quorum_frac: float = 1.0, round_deadline_s: float = float("inf"),
    fault_cfg: FaultConfig | None = None, retry: RetryPolicy | None = None,
    legacy_clients: tuple = (), join_grace_s: float = 5.0,
    defense: DefenseConfig | None = None, attack: AttackConfig | None = None,
) -> SocketRoundResult:
    """One federated round over real TCP with ``n_clients`` OS processes.

    The server binds an ephemeral loopback port and services connections
    CONCURRENTLY: an accept thread spawns one handler per connection, so a
    stalled client can no longer head-of-line-block the round, and in
    buffered mode aggregation overlaps the other clients' uploads. The
    round commits when every live client lands, or — once
    ``round_deadline_s`` passes — when ``quorum_frac`` of clients have
    (stragglers booked as dropped bytes); fewer survivors than the quorum
    raise ``QuorumNotMetError``. Crashed children are detected by exit
    code and stop being waited for. With ``fault_cfg`` a ``ChaosProxy``
    injects deterministic in-path faults and clients reconnect/resume
    through it. Every child is reaped on the way out, escalating
    ``terminate()`` → ``kill()`` — a hung client cannot outlive the round.
    """
    if n_clients < 1:
        raise ValueError(f"n_clients must be ≥ 1, got {n_clients}")
    if mode not in ("sync", "buffered"):
        raise ValueError(f"unknown mode {mode!r} (sync | buffered)")
    if not 0.0 < quorum_frac <= 1.0:
        raise ValueError(f"quorum_frac must be in (0, 1], got {quorum_frac}")
    ctx = mp.get_context(start_method)
    bcast_blob = encode_update(global_params)
    quorum_n = max(1, math.ceil(quorum_frac * n_clients))

    t0 = time.perf_counter()
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    state = _RoundState()
    procs: dict[int, Any] = {}
    handlers: list[threading.Thread] = []
    threads: list[threading.Thread] = []
    proxy: ChaosProxy | None = None
    agg = Aggregator(
        chunk_c=chunk_c,
        rule=defense.rule if defense is not None else "mean",
        trim_frac=defense.trim_frac if defense is not None else 0.2,
    )
    out_params = global_params
    folded = 0
    if defense is not None and defense.enabled:
        state.gate = UpdateGate(defense, global_params)
    attackers = (attacker_ids(attack, n_clients) if attack is not None
                 else frozenset())
    try:
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((host, 0))
        srv.listen(max(n_clients, 8))
        srv.settimeout(0.1)
        port = srv.getsockname()[1]
        acceptor = threading.Thread(
            target=_accept_loop,
            args=(srv, state, bcast_blob, timeout_s, handlers),
            daemon=True,
        )
        acceptor.start()
        threads.append(acceptor)

        client_port = port
        if fault_cfg is not None:
            proxy = ChaosProxy((host, port), fault_cfg, host=host)
            client_port = proxy.port
        crash_set = set(fault_cfg.crash_clients) if fault_cfg else set()
        bad_proto = set(fault_cfg.bad_proto_clients) if fault_cfg else set()
        for cid in range(n_clients):
            p = ctx.Process(
                target=_client_main,
                args=(host, client_port, cid, seed, timeout_s, retry,
                      fault_cfg.crash_after_frac if cid in crash_set else None,
                      PROTO_V1 if cid in legacy_clients
                      else (99 if cid in bad_proto else PROTO_VERSION),
                      attack if cid in attackers else None),
                daemon=True,
            )
            p.start()
            procs[cid] = p

        # ---- the round driver: wait / fold / watch / commit --------------
        deadline = time.monotonic() + (
            round_deadline_s if math.isfinite(round_deadline_s) else timeout_s
        )
        committed = "full"
        while True:
            with state.cond:
                state.cond.wait(timeout=0.05)
                n_done = len(state.completed)
            if mode == "buffered":
                # overlap: fold whole buffers while uploads are in flight
                from repro.fed.async_server import _weighted_mix
                while n_done - folded >= buffer_k:
                    with state.lock:
                        batch = state.completed[folded:folded + buffer_k]
                    out_params = _weighted_mix(
                        out_params, [(w, b) for _, w, b in batch], eta,
                        agg=agg)
                    folded += buffer_k
            # the process watcher: a dead child without a landed update can
            # never arrive — shrink the expected set instead of waiting
            resolved = set()
            for cid, p in procs.items():
                if (cid in state.completed_ids or cid in state.rejected
                        or cid in state.quarantined):
                    resolved.add(cid)
                elif p.exitcode is not None:
                    resolved.add(cid)     # crashed / exhausted / rejected
            n_completed = len(state.completed_ids)
            expected = n_clients - len(resolved - state.completed_ids)
            if n_completed >= expected:
                if n_completed < quorum_n:
                    raise QuorumNotMetError(
                        f"only {n_completed}/{n_clients} clients landed "
                        f"(quorum {quorum_n}); outcomes "
                        f"{_final_outcomes(state, procs)}")
                committed = "full" if n_completed == n_clients else "quorum"
                break
            if time.monotonic() >= deadline:
                if n_completed >= quorum_n:
                    committed = "quorum"
                    break
                raise QuorumNotMetError(
                    f"deadline hit with {n_completed}/{n_clients} landed "
                    f"(quorum {quorum_n}); outcomes "
                    f"{_final_outcomes(state, procs)}")

        # ---- commit ------------------------------------------------------
        with state.cond:
            state.closing = True
            state.cond.notify_all()
        srv.close()
        # handlers poll at 0.25s and bail on state.closing, so a shared
        # deadline suffices — never 5s per straggler thread.
        join_end = time.monotonic() + 5.0
        for t in handlers:
            t.join(timeout=max(0.0, join_end - time.monotonic()))
        # stragglers: their bytes were paid for but never fold in. shipped
        # is metered INDEPENDENTLY (session decoders' bytes_in — the socket
        # meter) so the ledger's shipped == ingested + dropped balance is a
        # real cross-check against frame-size arithmetic, not an identity.
        with state.lock:
            shipped = state.v1_update_bytes + state.superseded_bytes
            for cid, sess in state.sessions.items():
                shipped += sess.dec.bytes_in
                if cid in state.quarantined:
                    # frame bytes are already in the quarantine bucket;
                    # anything beyond the frame (resume overshoot) is waste
                    extra = sess.dec.bytes_in - state.quarantined[cid][1]
                    if extra > 0:
                        state.dropped_update_bytes += extra
                elif cid not in state.completed_ids:
                    state.dropped_update_bytes += sess.dec.bytes_in
                    agg.note_dropped(sess.dec.bytes_in)
                elif sess.completed:
                    extra = sess.dec.bytes_in - sess.frame_bytes
                    if extra > 0:
                        state.dropped_update_bytes += extra
            for _reason, nbytes in state.quarantined.values():
                agg.note_quarantined(nbytes)
            arrivals_final = list(state.completed)
        if mode == "sync":
            for _cid, weight, blob in sorted(arrivals_final):
                agg.add(blob, weight=weight)
            out_params = agg.finalize()
        else:
            from repro.fed.async_server import _weighted_mix
            tail = arrivals_final[folded:]
            if tail:
                out_params = _weighted_mix(
                    out_params, [(w, b) for _, w, b in tail], eta, agg=agg)
    finally:
        with state.cond:
            state.closing = True
            state.cond.notify_all()
        srv.close()
        esc = reap_processes(list(procs.values()), grace_s=join_grace_s)
        if proxy is not None:
            proxy.close()
        join_end = time.monotonic() + 5.0
        for t in threads + handlers:
            t.join(timeout=max(0.0, join_end - time.monotonic()))

    return SocketRoundResult(
        params=out_params,
        n_clients=n_clients,
        arrivals=[cid for cid, _, _ in arrivals_final],
        upload_bytes=state.up_bytes,
        download_bytes=state.down_bytes,
        payload_bytes=state.payload_bytes,
        wall_s=time.perf_counter() - t0,
        mode=mode,
        outcomes=_final_outcomes(state, procs),
        committed=committed,
        quorum_frac=quorum_frac,
        quorum_n=quorum_n,
        shipped_update_bytes=shipped,
        ingested_update_bytes=state.ingested_update_bytes,
        dropped_update_bytes=state.dropped_update_bytes,
        quarantined_update_bytes=state.quarantined_update_bytes,
        resumed_bytes=state.resumed_bytes,
        retries=state.retries,
        escalations=esc,
        chaos=dict(proxy.stats) if proxy is not None else None,
        defense=(state.gate.telemetry() if state.gate is not None else None),
    )


# --------------------------------------------------------------------------
# CLI demo / CI smoke.
# --------------------------------------------------------------------------


def default_chaos(seed: int = 0, n_clients: int = 6) -> FaultConfig:
    """The CI chaos preset: bursty Gilbert–Elliott weather (delays + kills
    + refused connects), mid-frame truncation at 4 KiB granularity, and the
    last client crashing mid-upload — every taxonomy entry reachable."""
    return FaultConfig(
        seed=seed,
        chunk_bytes=512,     # several boundaries INSIDE a demo update frame,
        ge_p_good_bad=0.15,  # so kills truncate mid-frame and force resume
        ge_p_bad_good=0.4,
        fault_good=0.0,
        fault_bad=0.4,
        p_kill=0.5,
        p_refuse=0.5,
        delay_s=0.01,
        crash_clients=(n_clients - 1,),
        crash_after_frac=0.5,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Federated round over real TCP with N client processes"
    )
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--mode", choices=("sync", "buffered"), default="sync")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunk-c", type=int, default=16)
    ap.add_argument("--buffer-k", type=int, default=4)
    ap.add_argument("--eta", type=float, default=0.5)
    ap.add_argument("--timeout-s", type=float, default=DEFAULT_TIMEOUT_S)
    ap.add_argument("--quorum-frac", type=float, default=None,
                    help="commit once this fraction of clients lands "
                         "(default: 1.0, or 0.5 under --chaos)")
    ap.add_argument("--deadline-s", type=float, default=float("inf"))
    ap.add_argument("--chaos", action="store_true",
                    help="run through the deterministic ChaosProxy preset "
                         "(drops, delays, truncation, one client crash)")
    ap.add_argument("--chaos-seed", type=int, default=19,
                    help="fault seed (19: mid-frame kills AND a refused "
                         "connect are reachable, so resume is exercised)")
    ap.add_argument("--check", action="store_true",
                    help="also run the in-process reference (restricted to "
                         "the surviving client set) and require a "
                         "byte-identical aggregate")
    ap.add_argument("--defense", action="store_true",
                    help="enable the content quarantine gate")
    ap.add_argument("--rule", default="mean",
                    choices=("mean", "majority", "trimmed_mean", "median"),
                    help="aggregation rule (with --defense)")
    ap.add_argument("--attack", default=None,
                    choices=("sign_flip", "scale_blowup", "gaussian",
                             "nan_poison", "collude"),
                    help="turn a seeded subset of clients Byzantine")
    ap.add_argument("--attackers", type=int, default=2,
                    help="attacker cohort size (with --attack)")
    ap.add_argument("--attack-seed", type=int, default=11)
    args = ap.parse_args(argv)

    fault_cfg = None
    quorum_frac = args.quorum_frac
    if args.chaos:
        fault_cfg = default_chaos(seed=args.chaos_seed,
                                  n_clients=args.clients)
        if quorum_frac is None:
            quorum_frac = 0.5
    attack = None
    if args.attack is not None:
        attack = AttackConfig(kind=args.attack, n_attackers=args.attackers,
                              seed=args.attack_seed)
        if quorum_frac is None:
            # quarantined attackers never count as landed updates
            quorum_frac = max(0.1, (args.clients - args.attackers)
                              / max(args.clients, 1))
    defense = (DefenseConfig(enabled=True, rule=args.rule)
               if args.defense else None)
    if quorum_frac is None:
        quorum_frac = 1.0

    params = demo_params(seed=args.seed)
    res = run_socket_round(
        params, args.clients, seed=args.seed, mode=args.mode,
        chunk_c=args.chunk_c, buffer_k=args.buffer_k, eta=args.eta,
        timeout_s=args.timeout_s, quorum_frac=quorum_frac,
        round_deadline_s=args.deadline_s, fault_cfg=fault_cfg,
        defense=defense, attack=attack,
    )
    ledger = res.ledger()
    if args.check:
        order = (sorted(res.arrivals) if args.mode == "sync"
                 else res.arrivals)
        ref = run_inprocess_reference(
            params, args.clients, seed=args.seed, mode=args.mode,
            chunk_c=args.chunk_c, buffer_k=args.buffer_k, eta=args.eta,
            order=order, rule=args.rule if args.defense else "mean",
        )
        ledger["reference_sha256"] = params_hash(ref)
        ledger["byte_identical"] = (
            ledger["reference_sha256"] == ledger["params_sha256"]
        )
    print(json.dumps(ledger, indent=2))
    ok = True
    if args.check and not ledger["byte_identical"]:
        print("FAIL: socket aggregate differs from in-process reference",
              file=sys.stderr)
        ok = False
    if not ledger["balance_ok"]:
        print("FAIL: update-byte ledger does not balance "
              "(shipped != ingested + dropped + quarantined)",
              file=sys.stderr)
        ok = False
    if args.chaos and ledger["n_survivors"] < res.quorum_n:
        print("FAIL: chaos round committed below quorum", file=sys.stderr)
        ok = False
    if args.attack == "nan_poison" and args.defense:
        # the poison smoke's teeth: every attacker must be quarantined
        n_quar = sum(1 for v in ledger["outcomes"].values()
                     if v == "quarantined")
        if n_quar != min(args.attackers, args.clients):
            print(f"FAIL: only {n_quar} of {args.attackers} nan_poison "
                  "attackers were quarantined", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
