"""Round-based federated simulation (paper Algorithm 2 + §II.A protocol).

Each round:
  1. SELECTION      — sample ⌈λN⌉ clients.
  2. CONFIGURATION  — the server SERIALIZES the current global model through
                      ``repro.comm.wire`` (ternary wire for T-FedAvg —
                      downstream compression, §III.B) and broadcasts the
                      buffer; clients DECODE it. Download bytes are
                      ``len(buffer)`` per recipient.
  3. REPORTING      — clients run E local epochs (FTTQ QAT for T-FedAvg),
                      serialize their update, and upload; the server decodes,
                      aggregates |D_k|-weighted and (T-FedAvg) re-quantizes.

Transfer and compute times come from the ``repro.comm.channel`` model, so a
straggler is a client whose download + compute + upload exceeded the round
deadline — an emergent property of bytes ÷ bandwidth, not a coin flip. The
protocol tolerates partial participation by design: a dropped client only
reweights the average, and the fastest client is always kept so no round is
ever lost.

``run_federated`` is the unified entry point: ``cfg.mode`` selects this
synchronous server or the event-driven buffered-asynchronous one in
``fed/async_server.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import Channel, ChannelConfig
from repro.comm.wire import decode_update, encode_update
from repro.core import fttq as fttq_mod
from repro.core.compression import (
    CodecSpec,
    CompressionSpec,
    compress_pytree,
    decompress_pytree,
)
from repro.core.tfedavg import (
    TernaryUpdate,
    client_update_payload,
    server_aggregate,
    server_requantize,
)
from repro.data.federated import ClientDataset
from repro.fed.aggregator import Aggregator
from repro.fed.attackers import AttackConfig, attacker_ids, poison_blob
from repro.fed.availability import (
    AvailabilityConfig,
    draw_participants,
    make_availability,
)
from repro.fed.controller import (
    CompressionController,
    ControllerConfig,
    make_controller,
)
from repro.fed.defense import DefenseConfig, UpdateGate
from repro.fed.hierarchy import EdgeTier, HierarchyConfig
from repro.optim import Optimizer

Pytree = Any


@dataclasses.dataclass
class FedConfig:
    algorithm: str = "tfedavg"          # "fedavg" | "tfedavg"
    mode: str = "sync"                  # "sync" | "async" (buffered, FedBuf-style)
    n_clients: int = 100
    participation: float = 0.1          # λ
    local_epochs: int = 5               # E
    batch_size: int = 64                # B
    rounds: int = 100                   # sync rounds / async aggregations
    fttq: fttq_mod.FTTQConfig = dataclasses.field(default_factory=fttq_mod.FTTQConfig)
    channel: ChannelConfig = dataclasses.field(default_factory=ChannelConfig)
    # per-direction codec selection (None → derived from `algorithm`:
    # tfedavg → symmetric ternary, fedavg → identity). Asymmetric specs —
    # e.g. fp16 residuals upstream only — change the measured byte split.
    compression: CompressionSpec | None = None
    seed: int = 0
    # --- server aggregation ----------------------------------------------
    # True → stream survivor blobs through fed.aggregator.Aggregator (fused
    # packed fan-in kernel, O(chunk) server memory); False → the list-based
    # reference loop (core.tfedavg.server_aggregate).
    fused_aggregation: bool = True
    agg_chunk_c: int = 16               # clients per fused kernel launch
    # --- client/server egress encode -------------------------------------
    # True → quantize→pack through the fused one-pass kernel pipeline
    # (core.encode: byte-identical wire buffers, one HBM read per leaf);
    # False → the pinned per-leaf jnp reference chain.
    fused_encode: bool = True
    # --- async (buffered) server knobs -----------------------------------
    buffer_k: int = 4                   # aggregate every K arrivals
    max_concurrency: int = 0            # in-flight clients (0 → ⌈λN⌉)
    staleness_exponent: float = 0.5     # arrival weight ∝ (1+staleness)^-α
    mixing_rate: float = 1.0            # η: global ← (1-η)·global + η·buffer avg
    # --- scenario layer ---------------------------------------------------
    # who is reachable when (always_on reproduces pre-scenario runs
    # bit-exactly; "diurnal"/"trace" feed both servers' participant draws).
    availability: AvailabilityConfig = dataclasses.field(
        default_factory=AvailabilityConfig
    )
    # hierarchical edge-aggregation tier (n_edges=0 → flat, the historical
    # topology — pre-hierarchy runs reproduce bit-exactly). With edges on,
    # survivors fan into regional edge aggregators that each ship ONE
    # (optionally re-quantized) record to the root, so root ingress bytes
    # scale with the edge count instead of the participant count.
    hierarchy: HierarchyConfig = dataclasses.field(
        default_factory=HierarchyConfig
    )
    # hard staleness cap for async arrivals (0 → no cap). Past the cap an
    # update is dropped ("drop") or extra-discounted ("downweight").
    max_staleness: int = 0
    staleness_policy: str = "drop"
    # adaptive buffer_k: retune K after every mix so the time between
    # aggregations tracks target_mix_latency_s as arrival rates drift
    # (0 → lock the target to the initial K's observed latency).
    adaptive_buffer: bool = False
    target_mix_latency_s: float = 0.0
    # --- Byzantine robustness ---------------------------------------------
    # content defense (None / enabled=False → the legacy ingest path,
    # bit-exact) and seeded attacker injection (None → all clients honest).
    # With the gate on, every arrival is checked against the broadcast tree
    # BEFORE it reaches the aggregator; failures become the third ledger
    # outcome:  shipped == ingested + dropped + quarantined.
    defense: DefenseConfig | None = None
    attack: AttackConfig | None = None
    # --- adaptive compression controller ----------------------------------
    # None / enabled=False → the static upstream codec path, bit-exact with
    # pre-controller runs. Enabled → fed/controller.py selects each
    # client's upload codec per round from measured goodput + update
    # divergence, with per-client error-feedback residual state; telemetry
    # lands in FedResult.telemetry["controller"].
    controller: "ControllerConfig | None" = None


@dataclasses.dataclass
class FedResult:
    accuracy: list
    loss: list
    upload_bytes: int
    download_bytes: int
    rounds_run: int
    participants_per_round: list
    # wall-clock view from the channel model (simulated seconds):
    round_times: list = dataclasses.field(default_factory=list)
    dropped_per_round: list = dataclasses.field(default_factory=list)
    transfer_summary: dict = dataclasses.field(default_factory=dict)
    staleness_per_agg: list = dataclasses.field(default_factory=list)
    # scenario telemetry: staleness histogram, dropped/retransmitted bytes,
    # adaptive buffer_k trajectory, availability kind (see the servers).
    telemetry: dict = dataclasses.field(default_factory=dict)

    @property
    def total_time_s(self) -> float:
        return float(sum(self.round_times))


def _ce_loss(apply_fn, params, xb, yb):
    logits = apply_fn(params, xb)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=-1))


def _make_local_steps(apply_fn, optimizer: Optimizer, cfg: FedConfig):
    """jit'd per-batch SGD steps for the FP (FedAvg) and QAT (T-FedAvg) paths."""

    @jax.jit
    def fp_step(params, opt_state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: _ce_loss(apply_fn, p, xb, yb)
        )(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        return params, opt_state, loss

    fcfg = cfg.fttq

    @jax.jit
    def qat_step(params, wq, opt_state, xb, yb):
        def loss_fn(p, w):
            q = fttq_mod.quantize_tree(p, w, fcfg)
            return _ce_loss(apply_fn, q, xb, yb)

        loss, (g_p, g_w) = jax.value_and_grad(loss_fn, argnums=(0, 1))(params, wq)
        updates, opt_state = optimizer.update(g_p, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        # w_q trains by SGD (paper Alg. 1); its gradient is a SUM over every
        # quantized position of the layer, so normalize per-element to keep
        # the step size layer-size-invariant.

        def upd_wq(w, g, p):
            if w is None:
                return None
            return w - 0.05 * g / float(p.size)

        wq = jax.tree_util.tree_map(
            upd_wq, wq, g_w, params, is_leaf=lambda x: x is None
        )
        return params, wq, opt_state, loss

    return fp_step, qat_step


# --------------------------------------------------------------------------
# Shared protocol pieces (used by both the sync and async servers).
# --------------------------------------------------------------------------


def resolve_rule(cfg: FedConfig) -> tuple[str, float]:
    """The (aggregation rule, trim fraction) every server in this run uses.

    Defense off (the default) pins "mean" — the legacy bit-exact weighted
    average. The robust rules live on the fused ``fed.aggregator`` path;
    the list-based reference loop only knows the mean, so they require
    ``fused_aggregation=True``.
    """
    if cfg.defense is None or not cfg.defense.enabled:
        return "mean", 0.2
    if cfg.defense.rule != "mean" and not cfg.fused_aggregation:
        raise ValueError(
            f"robust rule {cfg.defense.rule!r} requires fused_aggregation=True "
            "(the reference loop only computes the weighted mean)"
        )
    return cfg.defense.rule, cfg.defense.trim_frac


def resolve_compression(cfg: FedConfig) -> CompressionSpec:
    """The run's per-direction codec pair (explicit, or derived from the
    algorithm: T-FedAvg ships ternary both ways, FedAvg ships raw fp32)."""
    if cfg.compression is not None:
        return cfg.compression
    kind = "ternary" if cfg.algorithm == "tfedavg" else "none"
    return CompressionSpec.symmetric(
        kind=kind, fttq=cfg.fttq, fused_encode=cfg.fused_encode
    )


def dequantize_tree(tree: Pytree) -> Pytree:
    """Decode any wire leaves (ternary/downcast/top-k); raw leaves pass."""
    return decompress_pytree(tree)


def broadcast_blob(global_params: Pytree, cfg: FedConfig) -> bytes:
    """Serialize the downstream payload through the downstream codec spec.

    The ternary weights path keeps Algorithm 2's server re-quantization
    (fixed Δ = server_delta); the residual codec then compresses whatever
    leaves are still raw (biases, norms) — that is where the remaining
    downstream bytes live.
    """
    dspec = resolve_compression(cfg).downstream
    if dspec.kind == "ternary":
        tree = server_requantize(global_params, dspec.fttq,
                                 fused=dspec.fused_encode)
        tree, _ = compress_pytree(tree, dspec)  # residual codec on raw leaves
    else:
        tree, _ = compress_pytree(global_params, dspec)
    return encode_update(tree)


def receive_broadcast(blob: bytes) -> Pytree:
    """Client side of CONFIGURATION: decode the wire buffer, dequantize.
    Decoded once per broadcast — the result is shared by every recipient of
    the same (immutable) buffer."""
    return dequantize_tree(decode_update(blob))


def train_client(
    client: ClientDataset,
    start_params: Pytree,
    cfg: FedConfig,
    optimizer: Optimizer,
    fp_step,
    qat_step,
    rng: np.random.Generator,
    *,
    controller: CompressionController | None = None,
    client_id: int = -1,
) -> bytes:
    """One client's round: train locally from the decoded broadcast
    (``receive_broadcast``), serialize the upstream payload through the
    upstream codec spec (QAT ternary weights pass through untouched; the
    residual codec compresses the raw bias/norm leaves). With an adaptive
    ``controller``, the encode instead goes through its per-client rung
    selection + error feedback (``controller.client_payload``); training
    itself is identical either way."""
    params_k = start_params
    opt_state = optimizer.init(params_k)
    wq = None
    if cfg.algorithm == "tfedavg":
        wq = fttq_mod.init_wq_tree(params_k, cfg.fttq)
        for xb, yb in client.batches(cfg.batch_size, rng, cfg.local_epochs):
            params_k, wq, opt_state, _ = qat_step(
                params_k, wq, opt_state, jnp.asarray(xb), jnp.asarray(yb)
            )
        if controller is not None:
            return controller.client_payload(client_id, params_k, wq,
                                             start_params)
        # gate on the RESOLVED upstream spec (not cfg.fused_encode directly)
        # so an explicit cfg.compression's fused_encode flag is honored on
        # this path exactly as broadcast_blob honors the downstream one.
        payload = client_update_payload(
            params_k, wq, cfg.fttq,
            fused=resolve_compression(cfg).upstream.fused_encode,
        )
    else:
        for xb, yb in client.batches(cfg.batch_size, rng, cfg.local_epochs):
            params_k, opt_state, _ = fp_step(
                params_k, opt_state, jnp.asarray(xb), jnp.asarray(yb)
            )
        if controller is not None:
            return controller.client_payload(client_id, params_k, None,
                                             start_params)
        payload = params_k
    payload, _ = compress_pytree(payload, resolve_compression(cfg).upstream)
    return encode_update(payload)


# --------------------------------------------------------------------------
# Synchronous server (paper Algorithm 2).
# --------------------------------------------------------------------------


def run_federated_sync(
    apply_fn: Callable,
    global_params: Pytree,
    clients: list[ClientDataset],
    cfg: FedConfig,
    optimizer: Optimizer,
    eval_fn: Callable[[Pytree], tuple[float, float]],
    *,
    eval_every: int = 10,
) -> FedResult:
    rng = np.random.default_rng(cfg.seed)
    fp_step, qat_step = _make_local_steps(apply_fn, optimizer, cfg)
    channel = Channel(cfg.channel, len(clients), seed=cfg.seed + 1)
    avail = make_availability(cfg.availability, len(clients), seed=cfg.seed)
    deadline = cfg.channel.deadline_s if cfg.channel.deadline_s > 0 else float("inf")

    up_bytes = 0
    down_bytes = 0
    dropped_blob_bytes = 0     # uploads that arrived past the deadline
    acc_hist, loss_hist, parts_hist = [], [], []
    round_times, dropped_hist = [], []
    n_sel = max(int(np.ceil(cfg.participation * len(clients))), 1)
    t_now = 0.0                # cumulative simulated time (availability clock)
    rule, trim_frac = resolve_rule(cfg)
    # long-lived edge tier (when enabled): per-edge staging buffers, leaf
    # plans and the cumulative byte ledger persist across rounds.
    tier = (EdgeTier(cfg.hierarchy, cfg.fttq, len(clients),
                     fused_encode=cfg.fused_encode,
                     rule=rule, trim_frac=trim_frac)
            if cfg.hierarchy.enabled else None)
    # Byzantine layer: seeded attacker cohort + the content gate. The gate
    # lives across rounds so its cross-client scale history warms up.
    attackers = (attacker_ids(cfg.attack, len(clients))
                 if cfg.attack is not None else frozenset())
    gate = (UpdateGate(cfg.defense, global_params)
            if cfg.defense is not None and cfg.defense.enabled else None)
    gated_bytes = 0            # survivor bytes presented to the gate
    # adaptive compression controller (None → static codec path, bit-exact).
    ctrl = make_controller(cfg)
    if ctrl is not None and rule != "mean":
        raise ValueError(
            "adaptive compression requires aggregation rule 'mean': "
            "mixed-codec rounds have no robust-vote decomposition"
        )
    up_bytes_per_round = []

    for r in range(cfg.rounds):
        if ctrl is not None:
            ctrl.note_round(r)
        round_up0 = up_bytes
        # ---- selection (from the clients ONLINE right now) --------------
        wait_s = 0.0
        selected = draw_participants(avail, t_now, n_sel, len(clients), rng)
        while selected.size == 0:   # fleet empty: wait for the next arrival
            t_next = avail.next_change(t_now + wait_s)
            if not np.isfinite(t_next):
                raise RuntimeError("no client is ever available")
            wait_s = t_next - t_now
            selected = draw_participants(avail, t_next, n_sel,
                                         len(clients), rng)

        # ---- configuration (downstream broadcast, one serialized buffer) -
        blob = broadcast_blob(global_params, cfg)
        down_bytes += len(blob) * len(selected)
        start_params = receive_broadcast(blob)

        # ---- local training + reporting (upstream) ----------------------
        # Download + compute time are known before training; a client whose
        # link/device alone blows the deadline is dropped WITHOUT paying for
        # local training (the upload could only add time). The fastest
        # pre-time client always trains, so no round is ever lost.
        # The broadcast downloads run SIMULTANEOUSLY and contend for the
        # server NIC (cfg.channel.server_bandwidth_bytes_s).
        sel = [int(k) for k in selected]
        down_times = channel.transfer_concurrent(
            sel, [len(blob)] * len(sel), "down"
        )
        pre = []  # (t_down + t_comp, client_id)
        for t_down, k in zip(down_times, sel):
            t_comp = channel.compute_time(k, len(clients[k]) * cfg.local_epochs)
            pre.append((t_down + t_comp, k))
        pre.sort()

        arrivals = []  # (total_time, client_id, up_blob) — trained clients
        for pt, k in pre:
            if pt > deadline and arrivals:
                continue            # decidably late; round already safe
            up_blob = train_client(
                clients[k], start_params, cfg, optimizer, fp_step, qat_step,
                rng, controller=ctrl, client_id=k,
            )
            if k in attackers:
                # decode → poison → re-encode: the frame stays wire-valid,
                # only the content defense can catch it.
                up_blob = poison_blob(up_blob, cfg.attack, k, round_idx=r)
            t_up = channel.transfer(k, len(up_blob), "up")
            if ctrl is not None:
                # the same metered view Channel.log records (TransferEvent):
                # payload bytes over seconds including retransmissions.
                ctrl.observe_upload(k, len(up_blob), t_up)
            arrivals.append((pt + t_up, k, up_blob))

        # ---- straggler mitigation: emergent from the channel ------------
        arrivals.sort(key=lambda a: a[0])
        survivors = [a for a in arrivals if a[0] <= deadline]
        if not survivors:            # never lose a round: keep the fastest one
            survivors = [arrivals[0]]
        # uploads that arrived but missed the barrier: paid-for waste.
        # survivors is always a prefix of the time-sorted arrivals.
        dropped_blob_bytes += sum(
            len(a[2]) for a in arrivals[len(survivors):]
        )
        n_dropped = len(pre) - len(survivors)
        dropped_hist.append(n_dropped)
        parts_hist.append(len(survivors))
        # sync barrier: no drops → the last survivor closes the round; any
        # drop → the server waited out the full deadline (and, in the
        # all-dropped fallback, for the fastest client beyond it).
        last_survivor = max(a[0] for a in survivors)
        round_times.append(
            wait_s + (max(deadline, last_survivor) if n_dropped
                      else last_survivor)
        )
        t_now += round_times[-1]

        # ---- ingest gate (content defense) ------------------------------
        # Survivors cleared framing/CRC/deadline; the gate now vets their
        # CONTENT. Quarantined uploads were shipped and paid for, so their
        # bytes are booked as upload AND as quarantine — the third ledger
        # outcome next to ingested and dropped.
        if gate is not None:
            accepted = []
            for total, k, up_blob in survivors:
                gated_bytes += len(up_blob)
                if gate.check(up_blob).ok:
                    accepted.append((total, k, up_blob))
                else:
                    up_bytes += len(up_blob)
                    if tier is not None:
                        tier.note_quarantined(len(up_blob))
            survivors = accepted

        # ---- aggregation (server decodes the real upstream buffers) -----
        if not survivors:
            # every arrival was quarantined: hold the model this round
            # (losing a round to a poisoned cohort beats folding it in).
            pass
        elif tier is not None:
            # hierarchical: survivors fan into their regional edges; each
            # edge ships one (optionally re-quantized) record to the root.
            # The edge→root hop is real wire traffic, booked as upload.
            for total, k, up_blob in survivors:
                up_bytes += len(up_blob)
                tier.add(k, up_blob, weight=len(clients[k]))
            global_params, fold_info = tier.fold()
            up_bytes += fold_info["edge_to_root_bytes"]
        elif cfg.fused_aggregation:
            # streaming fused fan-in: zero-copy record decode into stacked
            # packed buffers, one Pallas launch per chunk_c clients — the
            # per-client dense trees of the reference loop never exist.
            agg = Aggregator(chunk_c=cfg.agg_chunk_c, rule=rule,
                             trim_frac=trim_frac)
            for total, k, up_blob in survivors:
                up_bytes += len(up_blob)
                agg.add(up_blob, weight=len(clients[k]))
            global_params = agg.finalize()
        else:
            updates = []
            for total, k, up_blob in survivors:
                up_bytes += len(up_blob)
                updates.append(TernaryUpdate(
                    payload=decode_update(up_blob),
                    n_samples=len(clients[k]),
                    client_id=k,
                ))
            global_params = server_aggregate(updates)

        up_bytes_per_round.append(up_bytes - round_up0)

        if (r + 1) % eval_every == 0 or r == cfg.rounds - 1:
            acc, ls = eval_fn(global_params)
            acc_hist.append(float(acc))
            loss_hist.append(float(ls))

    summary = channel.summary()
    telemetry = {
        # every straggler (pre-skipped before training OR arrived past
        # the deadline); the bytes cover only the latter — pre-skipped
        # clients never uploaded, so they waste no wire bytes.
        "dropped_updates": int(sum(dropped_hist)),
        "dropped_update_bytes": dropped_blob_bytes,
        "retrans_bytes": summary.get("retrans_bytes", 0),
        "retries": summary.get("retries", 0),
        "goodput_fraction": summary.get("goodput_fraction", 1.0),
        "availability": cfg.availability.kind,
        # upstream wire bytes booked per round (client hop + any edge→root
        # hop) — the bytes-to-target-accuracy benches integrate this.
        "upload_bytes_per_round": up_bytes_per_round,
    }
    if ctrl is not None:
        telemetry["controller"] = ctrl.telemetry()
    if gate is not None:
        telemetry["defense"] = gate.telemetry()
        # extended ledger at the gate: every survivor byte presented is
        # either ingested (passed) or quarantined — nothing leaks.
        telemetry["defense"]["ledger_balanced"] = (
            gated_bytes == gate.passed_bytes + gate.quarantined_bytes
        )
    if tier is not None:
        telemetry["hierarchy"] = tier.telemetry()
    return FedResult(
        accuracy=acc_hist,
        loss=loss_hist,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        rounds_run=cfg.rounds,
        participants_per_round=parts_hist,
        round_times=round_times,
        dropped_per_round=dropped_hist,
        transfer_summary=summary,
        telemetry=telemetry,
    )


def run_federated(
    apply_fn: Callable,
    global_params: Pytree,
    clients: list[ClientDataset],
    cfg: FedConfig,
    optimizer: Optimizer,
    eval_fn: Callable[[Pytree], tuple[float, float]],
    *,
    eval_every: int = 10,
) -> FedResult:
    """Unified entry point: dispatches on ``cfg.mode``.

    - "sync":  Algorithm 2's round-synchronous server (this module).
    - "async": event-driven buffered-asynchronous server
               (``fed.async_server``, FedBuf-style).
    """
    if cfg.mode == "async":
        from repro.fed.async_server import run_federated_async

        return run_federated_async(
            apply_fn, global_params, clients, cfg, optimizer, eval_fn,
            eval_every=eval_every,
        )
    if cfg.mode != "sync":
        raise ValueError(f"unknown federated mode {cfg.mode!r}")
    return run_federated_sync(
        apply_fn, global_params, clients, cfg, optimizer, eval_fn,
        eval_every=eval_every,
    )
