"""Round-based federated simulation (paper Algorithm 2 + §II.A protocol).

Each round:
  1. SELECTION      — sample ⌈λN⌉ clients; clients may fail or exceed the
                      straggler deadline (simulated) and are dropped — the
                      protocol tolerates partial participation by design, so
                      a lost client only reweights the average (fault
                      tolerance: no round is ever lost).
  2. CONFIGURATION  — broadcast the current global model (ternary wire for
                      T-FedAvg — downstream compression, §III.B).
  3. REPORTING      — clients run E local epochs (FTTQ QAT for T-FedAvg) and
                      upload (ternary wire for T-FedAvg); the server
                      aggregates |D_k|-weighted and (T-FedAvg) re-quantizes.

Bytes are metered from the ACTUAL wire payloads, not formulas, so Table IV
is reproduced by measurement.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fttq as fttq_mod
from repro.core.compression import wire_nbytes
from repro.core.tfedavg import (
    TernaryUpdate,
    client_update_payload,
    server_aggregate,
    server_requantize,
)
from repro.core.ternary import TernaryTensor
from repro.data.federated import ClientDataset
from repro.optim import Optimizer

Pytree = Any


@dataclasses.dataclass
class FedConfig:
    algorithm: str = "tfedavg"          # "fedavg" | "tfedavg"
    n_clients: int = 100
    participation: float = 0.1          # λ
    local_epochs: int = 5               # E
    batch_size: int = 64                # B
    rounds: int = 100
    fttq: fttq_mod.FTTQConfig = dataclasses.field(default_factory=fttq_mod.FTTQConfig)
    straggler_drop_prob: float = 0.0    # P(client misses the round deadline)
    seed: int = 0


@dataclasses.dataclass
class FedResult:
    accuracy: list
    loss: list
    upload_bytes: int
    download_bytes: int
    rounds_run: int
    participants_per_round: list


def _ce_loss(apply_fn, params, xb, yb):
    logits = apply_fn(params, xb)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=-1))


def _make_local_steps(apply_fn, optimizer: Optimizer, cfg: FedConfig):
    """jit'd per-batch SGD steps for the FP (FedAvg) and QAT (T-FedAvg) paths."""

    @jax.jit
    def fp_step(params, opt_state, xb, yb):
        loss, grads = jax.value_and_grad(
            lambda p: _ce_loss(apply_fn, p, xb, yb)
        )(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        return params, opt_state, loss

    fcfg = cfg.fttq

    @jax.jit
    def qat_step(params, wq, opt_state, xb, yb):
        def loss_fn(p, w):
            q = fttq_mod.quantize_tree(p, w, fcfg)
            return _ce_loss(apply_fn, q, xb, yb)

        loss, (g_p, g_w) = jax.value_and_grad(loss_fn, argnums=(0, 1))(params, wq)
        updates, opt_state = optimizer.update(g_p, opt_state, params)
        params = jax.tree_util.tree_map(
            lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
        )
        # w_q trains by SGD (paper Alg. 1); its gradient is a SUM over every
        # quantized position of the layer, so normalize per-element to keep
        # the step size layer-size-invariant.

        def upd_wq(w, g, p):
            if w is None:
                return None
            return w - 0.05 * g / float(p.size)

        wq = jax.tree_util.tree_map(
            upd_wq, wq, g_w, params, is_leaf=lambda x: x is None
        )
        return params, wq, opt_state, loss

    return fp_step, qat_step


def run_federated(
    apply_fn: Callable,
    global_params: Pytree,
    clients: list[ClientDataset],
    cfg: FedConfig,
    optimizer: Optimizer,
    eval_fn: Callable[[Pytree], tuple[float, float]],
    *,
    eval_every: int = 10,
) -> FedResult:
    """Run the protocol; eval_fn(params) → (accuracy, loss) on held-out data."""
    rng = np.random.default_rng(cfg.seed)
    fp_step, qat_step = _make_local_steps(apply_fn, optimizer, cfg)
    is_t = cfg.algorithm == "tfedavg"
    fcfg = cfg.fttq

    up_bytes = 0
    down_bytes = 0
    acc_hist, loss_hist, parts_hist = [], [], []
    n_sel = max(int(np.ceil(cfg.participation * len(clients))), 1)

    for r in range(cfg.rounds):
        # ---- selection + straggler/failure mitigation -------------------
        selected = rng.choice(len(clients), size=n_sel, replace=False)
        survivors = [
            k for k in selected if rng.random() >= cfg.straggler_drop_prob
        ]
        if not survivors:           # never lose a round: keep the fastest one
            survivors = [int(selected[0])]
        parts_hist.append(len(survivors))

        # ---- configuration (downstream broadcast) -----------------------
        if is_t:
            wire_global = server_requantize(global_params, fcfg)
            down_bytes += wire_nbytes(wire_global) * len(survivors)
            start_params = jax.tree_util.tree_map(
                lambda l: l.dequantize() if isinstance(l, TernaryTensor) else l,
                wire_global,
                is_leaf=lambda x: isinstance(x, TernaryTensor),
            )
        else:
            down_bytes += wire_nbytes(global_params) * len(survivors)
            start_params = global_params

        # ---- local training + reporting (upstream) ----------------------
        updates = []
        for k in survivors:
            c = clients[k]
            params_k = start_params
            opt_state = optimizer.init(params_k)
            if is_t:
                wq = fttq_mod.init_wq_tree(params_k, fcfg)
                for xb, yb in c.batches(cfg.batch_size, rng, cfg.local_epochs):
                    params_k, wq, opt_state, _ = qat_step(
                        params_k, wq, opt_state, jnp.asarray(xb), jnp.asarray(yb)
                    )
                payload = client_update_payload(params_k, wq, fcfg)
            else:
                for xb, yb in c.batches(cfg.batch_size, rng, cfg.local_epochs):
                    params_k, opt_state, _ = fp_step(
                        params_k, opt_state, jnp.asarray(xb), jnp.asarray(yb)
                    )
                payload = params_k
            u = TernaryUpdate(payload=payload, n_samples=len(c), client_id=int(k))
            up_bytes += u.nbytes_upstream()
            updates.append(u)

        # ---- aggregation -------------------------------------------------
        global_params = server_aggregate(updates)

        if (r + 1) % eval_every == 0 or r == cfg.rounds - 1:
            acc, ls = eval_fn(global_params)
            acc_hist.append(float(acc))
            loss_hist.append(float(ls))

    return FedResult(
        accuracy=acc_hist,
        loss=loss_hist,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        rounds_run=cfg.rounds,
        participants_per_round=parts_hist,
    )
