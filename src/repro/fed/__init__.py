"""Federated learning runtime: the paper's round-based protocol (selection →
configuration → reporting), FedAvg and T-FedAvg, over a real wire/transport
model (``repro.comm``) with channel-emergent straggler mitigation — plus an
event-driven buffered-asynchronous server (FedBuf-style), Byzantine
defense, a hierarchical edge tier, the vectorized fleet simulator, and the
adaptive compression controller (``fed.controller``). ``run_federated`` is
the unified entry point; ``cfg.mode`` picks "sync" or "async". See
``docs/ARCHITECTURE.md`` for the module map and per-server round
lifecycle."""

from repro.fed.aggregator import AGG_RULES, Aggregator
from repro.fed.attackers import ATTACKS, AttackConfig, attacker_ids, poison_blob
from repro.fed.availability import (
    AlwaysOn,
    AvailabilityConfig,
    ClientAvailability,
    DiurnalChurn,
    TraceReplay,
    make_availability,
)
from repro.fed.async_server import run_federated_async
from repro.fed.controller import (
    CompressionController,
    ControllerConfig,
    FleetCohortController,
    make_controller,
)
from repro.fed.defense import DefenseConfig, UpdateGate, Verdict
from repro.fed.fleet import EventHeap, FleetConfig, FleetResult, run_fleet
from repro.fed.mp_server import (
    SocketRoundResult,
    run_inprocess_reference,
    run_socket_round,
)
from repro.fed.hierarchy import EdgeTier, HierarchyConfig, edge_of, edges_of
from repro.fed.simulation import (
    FedConfig,
    FedResult,
    run_federated,
    run_federated_sync,
)

__all__ = [
    "Aggregator", "FedConfig", "FedResult",
    "run_federated", "run_federated_sync", "run_federated_async",
    "AvailabilityConfig", "ClientAvailability", "AlwaysOn", "DiurnalChurn",
    "TraceReplay", "make_availability",
    "HierarchyConfig", "EdgeTier", "edge_of", "edges_of",
    "FleetConfig", "FleetResult", "EventHeap", "run_fleet",
    "SocketRoundResult", "run_socket_round", "run_inprocess_reference",
    "AGG_RULES", "ATTACKS", "AttackConfig", "attacker_ids", "poison_blob",
    "DefenseConfig", "UpdateGate", "Verdict",
    "CompressionController", "ControllerConfig", "FleetCohortController",
    "make_controller",
]
