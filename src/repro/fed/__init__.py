"""Federated learning runtime: the paper's round-based protocol (selection →
configuration → reporting), FedAvg and T-FedAvg, with straggler mitigation
and exact communication metering."""

from repro.fed.simulation import FedConfig, FedResult, run_federated

__all__ = ["FedConfig", "FedResult", "run_federated"]
