"""Hierarchical edge-aggregation tier (client → edge → root).

A flat T-FedAvg server fans every client blob into one aggregator, so the
root's ingress bytes grow with the PARTICIPANT count. Deployed-scale FL
(the Le et al. survey's main lever) splits the fan-in: clients upload to a
regional EDGE aggregator, each edge folds its region with the existing
streaming ``fed.aggregator.Aggregator`` (fused packed fan-in kernel,
O(chunk) memory), and ships ONE record upstream — so the root's ingress
scales with the number of edges, not clients.

Two upstream modes per ``HierarchyConfig.requantize_at_edge``:

  - True (default): the edge re-quantizes its regional mean with the
    server-side FTTQ path (``core.tfedavg.server_requantize`` — fixed
    Δ = server_delta, Prop-4.1 optimal scale, fused one-pass encode), so
    the edge→root hop ships 2-bit ternary + per-layer scales: the SAME
    ~16× byte cut the paper's client→server hop gets, now on both hops.
    Requantization is lossy (one extra ternary round per tier), which is
    exactly the trade the tier buys bytes with.
  - False: the edge ships its dense regional mean as raw fp32 wire
    records. Lossless — 2-tier aggregation computes the same weighted
    mean as a flat ``Aggregator`` over the union of clients (bit-identical
    when the per-edge partial sums are exact, property-tested in
    ``tests/test_hierarchy.py``) — but the edge→root hop pays fp32 bytes.

Every hop stays on the versioned ``repro.comm.wire`` format, and the tier
keeps an exact BYTE LEDGER: Σ client blob bytes ingested by edges ==
client_to_edge_bytes, Σ edge blob bytes ingested by the root ==
edge_to_root_bytes, and the two tiers' ledgers must balance against the
server's metered upload bytes (asserted by the bench smoke run and the
telemetry consumers).

Weights compose exactly: an edge's upstream record carries weight
W_e = Σ_{k∈e} w_k, so the root mean Σ_e W_e·mean_e / Σ_e W_e equals the
flat mean Σ_k w_k·θ_k / Σ_k w_k whenever the edge hop is lossless.

Determinism: client→edge placement is a pure hash of the client id
(``edge_of``), folds hold no RNG, and every requantize uses the fixed
server Δ — so a seeded run with the tier on is reproducible end to end,
and ``HierarchyConfig(n_edges=0)`` (the default, flat topology)
reproduces pre-hierarchy runs bit-exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.comm.wire import encode_update
from repro.core import fttq as fttq_mod
from repro.core.tfedavg import server_requantize
from repro.fed.aggregator import Aggregator

Pytree = Any


@dataclasses.dataclass(frozen=True)
class HierarchyConfig:
    """Serializable tier knobs (``FedConfig.hierarchy``).

    Attributes:
      n_edges: number of edge aggregators; 0 = flat (no tier — every
        pre-hierarchy run reproduces bit-exactly).
      requantize_at_edge: True → edges re-quantize their regional mean to
        ternary before the upstream hop (lossy, ~16× fewer edge→root
        bytes); False → edges ship the dense regional mean (lossless).
      assignment: "mod" → client k reports to edge k % n_edges (interleaves
        the DiurnalChurn timezone cohorts across edges); "block" → edge
        k·E // N (contiguous regions, cohort-aligned when E divides the
        cohort count).
      edge_chunk_c: clients per fused kernel launch at each edge.
      root_chunk_c: edge records per fused kernel launch at the root.
    """

    n_edges: int = 0
    requantize_at_edge: bool = True
    assignment: str = "mod"
    edge_chunk_c: int = 16
    root_chunk_c: int = 16

    @property
    def enabled(self) -> bool:
        return self.n_edges > 0


def edge_of(client_id: int, n_clients: int, cfg: HierarchyConfig) -> int:
    """The edge client ``client_id`` reports to."""
    if cfg.assignment == "mod":
        return int(client_id) % cfg.n_edges
    if cfg.assignment == "block":
        return (int(client_id) * cfg.n_edges) // max(int(n_clients), 1)
    raise ValueError(f"unknown edge assignment {cfg.assignment!r}")


def edges_of(client_ids: np.ndarray, n_clients: int,
             cfg: HierarchyConfig) -> np.ndarray:
    """Vectorized ``edge_of`` for a batch of client ids (fleet path)."""
    ids = np.asarray(client_ids, dtype=np.int64)
    if cfg.assignment == "mod":
        return ids % cfg.n_edges
    if cfg.assignment == "block":
        return (ids * cfg.n_edges) // max(int(n_clients), 1)
    raise ValueError(f"unknown edge assignment {cfg.assignment!r}")


class EdgeTier:
    """One tier of edge aggregators plus the root fan-in.

    Long-lived like ``Aggregator``: per-edge and root staging buffers and
    leaf plans persist across rounds (``fold`` resets the accumulated
    state, not the plans). The cumulative byte ledger survives resets —
    it is run-level accounting, mirroring ``Aggregator.dropped_bytes``.
    """

    def __init__(self, cfg: HierarchyConfig, fttq: fttq_mod.FTTQConfig,
                 n_clients: int, *, fused_encode: bool = True,
                 interpret: bool | None = None, rule: str = "mean",
                 trim_frac: float = 0.2):
        if cfg.n_edges < 1:
            raise ValueError(f"EdgeTier needs n_edges ≥ 1, got {cfg.n_edges}")
        self.cfg = cfg
        self.fttq = fttq
        self.n_clients = int(n_clients)
        self.fused_encode = fused_encode
        self.interpret = interpret
        # Byzantine-robust rule, applied at BOTH tiers: edges reduce their
        # region with it (a poisoned minority dies regionally), the root
        # reduces the edge records with it too. "mean" = legacy bit-exact.
        self.rule = rule
        self.trim_frac = trim_frac
        # edge aggregators materialize lazily: a million-client fleet with
        # sparse participation only pays for the edges that see traffic.
        self._edges: dict[int, Aggregator] = {}
        self._edge_weight = np.zeros(cfg.n_edges, dtype=np.float64)
        self._edge_clients = np.zeros(cfg.n_edges, dtype=np.int64)
        self._edge_staleness = np.zeros(cfg.n_edges, dtype=np.float64)
        self._root = Aggregator(chunk_c=cfg.root_chunk_c, interpret=interpret,
                                rule=rule, trim_frac=trim_frac)
        # cumulative ledger (never reset): bytes per tier, per edge.
        self.ingest_bytes = np.zeros(cfg.n_edges, dtype=np.int64)
        self.upstream_bytes = np.zeros(cfg.n_edges, dtype=np.int64)
        self.clients_seen = np.zeros(cfg.n_edges, dtype=np.int64)
        self.root_ingest_bytes = 0
        self.folds = 0
        # quarantine ledger: client blobs the defense gate refused BEFORE
        # they reached any edge — paid-for wire bytes, never ingested.
        self.quarantined_updates = 0
        self.quarantined_bytes = 0

    # -- ingest ------------------------------------------------------------

    def _edge_agg(self, e: int) -> Aggregator:
        agg = self._edges.get(e)
        if agg is None:
            agg = Aggregator(chunk_c=self.cfg.edge_chunk_c,
                             interpret=self.interpret,
                             rule=self.rule, trim_frac=self.trim_frac)
            self._edges[e] = agg
        return agg

    def note_quarantined(self, nbytes: int, updates: int = 1) -> None:
        """Book gate-refused client bytes that would otherwise have fanned
        into an edge; extends the tier ledger with the quarantine bucket
        (shipped == ingested + quarantined on the client→edge hop)."""
        self.quarantined_updates += int(updates)
        self.quarantined_bytes += int(nbytes)

    def add(self, client_id: int, blob: bytes, weight: float,
            staleness: float = 0.0) -> None:
        """Route one client's wire blob to its edge (zero-copy ingest)."""
        e = edge_of(client_id, self.n_clients, self.cfg)
        self._edge_agg(e).add(blob, weight=weight)
        self._edge_weight[e] += float(weight)
        self._edge_clients[e] += 1
        self._edge_staleness[e] += float(staleness)
        self.ingest_bytes[e] += len(blob)
        self.clients_seen[e] += 1

    def add_cohort(self, edge: int, blob: bytes, weight: float,
                   n_clients: int, staleness_sum: float = 0.0) -> None:
        """Vectorized-fleet ingest: ``n_clients`` clients of one edge
        shipped byte-identical payloads (a cohort), so the edge folds ONE
        weighted add (``weight`` = the cohort's summed client weights —
        exactly Σ w_k·θ over the cohort since the θs are identical) while
        the ledger books every client's wire bytes individually."""
        self._edge_agg(edge).add(blob, weight=weight)
        self._edge_weight[edge] += float(weight)
        self._edge_clients[edge] += int(n_clients)
        self._edge_staleness[edge] += float(staleness_sum)
        self.ingest_bytes[edge] += int(n_clients) * len(blob)
        self.clients_seen[edge] += int(n_clients)

    @property
    def pending_clients(self) -> int:
        return int(self._edge_clients.sum())

    # -- the edge→root hop -------------------------------------------------

    def collect(self) -> list[tuple[int, bytes, float]]:
        """Flush every edge with pending clients into ONE upstream wire
        blob each: (edge, blob, regional weight W_e). Resets the per-round
        edge state; the cumulative ledger keeps counting."""
        out = []
        for e in sorted(self._edges):
            if self._edge_clients[e] == 0:
                continue
            mean = self._edges[e].finalize(reset=True)
            if self.cfg.requantize_at_edge:
                mean = server_requantize(mean, self.fttq,
                                         fused=self.fused_encode)
            blob = encode_update(mean)
            w = float(self._edge_weight[e])
            self.upstream_bytes[e] += len(blob)
            out.append((e, blob, w))
        self._edge_weight[:] = 0.0
        self._edge_clients[:] = 0
        return out

    def fold(self) -> tuple[Pytree, dict]:
        """One full tier round: edges flush upstream, the root aggregates
        the edge records (weighted by W_e), and the global mean comes back
        with the round's per-tier telemetry."""
        records = self.collect()
        if not records:
            raise ValueError("EdgeTier.fold: no client updates were added")
        round_up = 0
        for _e, blob, w in records:
            self._root.add(blob, weight=w)
            self.root_ingest_bytes += len(blob)
            round_up += len(blob)
        mean = self._root.finalize(reset=True)
        self.folds += 1
        return mean, {
            "edges_active": len(records),
            "edge_to_root_bytes": round_up,
        }

    # -- ledger ------------------------------------------------------------

    def telemetry(self) -> dict:
        """Cumulative per-tier breakdown. The ledger invariant — what the
        edges shipped is exactly what the root ingested — is checked here
        and surfaced so smoke runs can assert it."""
        c2e = int(self.ingest_bytes.sum())
        e2r = int(self.upstream_bytes.sum())
        return {
            "n_edges": self.cfg.n_edges,
            "requantize_at_edge": self.cfg.requantize_at_edge,
            "rule": self.rule,
            "quarantined_updates": self.quarantined_updates,
            "quarantined_bytes": self.quarantined_bytes,
            "client_to_edge_bytes": c2e,
            "edge_to_root_bytes": e2r,
            "root_ingest_bytes": self.root_ingest_bytes,
            "ledger_balanced": e2r == self.root_ingest_bytes,
            "clients_per_edge": self.clients_seen.tolist(),
            "bytes_per_edge": self.ingest_bytes.tolist(),
            "upstream_bytes_per_edge": self.upstream_bytes.tolist(),
            "mean_staleness_per_edge": (
                self._edge_staleness / np.maximum(self.clients_seen, 1)
            ).tolist(),
            "folds": self.folds,
        }
