"""Vectorized cohort simulation for million-client fleets.

``fed/simulation.py`` and ``fed/async_server.py`` are faithful protocol
simulators: every client is a Python object, every transfer a scalar rng
draw, every arrival a tuple on a ``heapq``. That is the right tool for
O(10²) clients with real local SGD — and three orders of magnitude short
of the deployed fleets the hierarchy tier targets. This module is the
fleet-scale counterpart: the SAME protocol (wire format, channel model,
availability traces, edge tier, byte ledger) with the per-client work
batched into array ops.

What gets vectorized, and what each approximation means:

  - **Availability + selection** — ``DiurnalChurn``/``TraceReplay`` masks
    are already array ops; the participant draw is the shared
    ``draw_participants`` (one ``rng.choice`` per round).
  - **Channel draws** — ``Channel.transfer_batch`` folds the rng ONCE per
    batch (one uniform jitter vector, one geometric loss vector) and
    returns closed-form seconds. Lossless batches are stream-compatible
    with the scalar path by construction; ``FleetConfig.compat`` forces
    the scalar call order so small-fleet seeds reproduce the legacy
    channel bit-exactly under loss too.
  - **Client updates** — fleet rounds measure COMMUNICATION and
    AGGREGATION, not SGD: clients ship payloads from a pre-encoded pool of
    ``FleetConfig.update_pool`` distinct ternary wire blobs (client k
    ships ``pool[k % P]``). Clients sharing a payload form a COHORT: the
    server folds one weighted ``Aggregator`` add per (edge, cohort) with
    the cohort's summed weight — exactly Σ w_k·θ_k since the θs are
    byte-identical — while the ledger books every client's wire bytes.
    A 10⁶-client round therefore costs O(edges × pool) kernel launches
    and O(participants) array arithmetic, nothing per-client in Python.
  - **Async arrivals** — the event queue is ``EventHeap``, an array-backed
    binary min-heap keyed (time, seq): O(log n) push/pop with three numpy
    arrays instead of a tuple object per in-flight client, plus a
    vectorized bulk ``push_many`` for batch dispatches. Pop order is
    identical to ``heapq`` on (time, seq) tuples (unique seq → total
    order). Refills happen in fold-sized batches (the cohort
    approximation of the per-arrival refill).

Memory stays flat in the client count: the fleet state is a handful of
float64/int64 arrays (links, masks, times) plus the chunk-bounded
aggregator staging buffers — no per-client Python objects anywhere.

Determinism: all draws (availability, links, participation, pool
assignment, attacker ids) come from ``np.random.default_rng`` streams
keyed on ``FedConfig.seed``, and the cohort controller
(``fed.controller.FleetCohortController``) is RNG-free — a fleet round
is reproducible byte-for-byte under a fixed seed, and every optional
subsystem (hierarchy, defense, attack, controller) reproduces the
pre-subsystem byte stream exactly when off.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np

from repro.comm import Channel
from repro.core import fttq as fttq_mod
from repro.core.compression import CodecSpec, compress_pytree
from repro.core.tfedavg import client_update_payload
from repro.comm.wire import encode_update
from repro.fed.aggregator import Aggregator
from repro.fed.attackers import attacker_ids, poison_blob
from repro.fed.availability import draw_participants, make_availability
from repro.fed.controller import FleetCohortController
from repro.fed.defense import UpdateGate
from repro.fed.hierarchy import EdgeTier, edges_of
from repro.fed.simulation import FedConfig, broadcast_blob, resolve_rule

Pytree = Any


class EventHeap:
    """Array-backed binary min-heap keyed by (time, seq).

    The async server's event queue holds one entry per in-flight client.
    ``heapq`` stores each as a Python tuple — fine at 10², hostile at 10⁶.
    Here keys live in two numpy arrays (float64 time, int64 seq) and
    payloads in a slot list indexed by a third array, so a million pending
    arrivals cost three arrays + one list. ``seq`` is assigned internally
    (monotonic), making every key unique — pop order is therefore the
    EXACT total order ``heapq`` would produce on (time, seq) tuples.
    """

    def __init__(self, capacity: int = 1024):
        cap = max(int(capacity), 1)
        self._time = np.empty(cap, dtype=np.float64)
        self._seq = np.empty(cap, dtype=np.int64)
        self._slot = np.empty(cap, dtype=np.int64)
        self._n = 0
        self._payload: list[Any] = []
        self._free: list[int] = []
        self._next_seq = 0

    def __len__(self) -> int:
        return self._n

    # -- internals ---------------------------------------------------------

    def _grow(self, need: int) -> None:
        cap = self._time.size
        if need <= cap:
            return
        new = max(need, 2 * cap)
        for name in ("_time", "_seq", "_slot"):
            arr = getattr(self, name)
            grown = np.empty(new, dtype=arr.dtype)
            grown[: self._n] = arr[: self._n]
            setattr(self, name, grown)

    def _less(self, i: int, j: int) -> bool:
        if self._time[i] != self._time[j]:
            return bool(self._time[i] < self._time[j])
        return bool(self._seq[i] < self._seq[j])

    def _swap(self, i: int, j: int) -> None:
        for arr in (self._time, self._seq, self._slot):
            arr[i], arr[j] = arr[j], arr[i]

    def _sift_up(self, i: int) -> None:
        while i > 0:
            parent = (i - 1) // 2
            if not self._less(i, parent):
                break
            self._swap(i, parent)
            i = parent

    def _sift_down(self, i: int) -> None:
        n = self._n
        while True:
            left = 2 * i + 1
            if left >= n:
                return
            child = left
            right = left + 1
            if right < n and self._less(right, left):
                child = right
            if not self._less(child, i):
                return
            self._swap(i, child)
            i = child

    def _store(self, payload: Any) -> int:
        if self._free:
            slot = self._free.pop()
            self._payload[slot] = payload
        else:
            slot = len(self._payload)
            self._payload.append(payload)
        return slot

    # -- api ---------------------------------------------------------------

    def push(self, t: float, payload: Any) -> int:
        """Insert one event; returns its (unique, monotonic) seq."""
        self._grow(self._n + 1)
        seq = self._next_seq
        self._next_seq += 1
        i = self._n
        self._time[i] = t
        self._seq[i] = seq
        self._slot[i] = self._store(payload)
        self._n += 1
        self._sift_up(i)
        return seq

    def push_many(self, times: np.ndarray, payloads: list[Any]) -> None:
        """Vectorized bulk insert: merge the pending keys with the new
        batch and re-establish the heap by lexsort — a sorted array IS a
        valid binary min-heap, and one O((n+k)·log) vectorized sort beats
        k sift-ups in Python."""
        ts = np.asarray(times, dtype=np.float64)
        k = ts.size
        if k != len(payloads):
            raise ValueError(f"{k} times for {len(payloads)} payloads")
        if k == 0:
            return
        self._grow(self._n + k)
        n = self._n
        seqs = np.arange(self._next_seq, self._next_seq + k, dtype=np.int64)
        self._next_seq += k
        self._time[n:n + k] = ts
        self._seq[n:n + k] = seqs
        self._slot[n:n + k] = [self._store(p) for p in payloads]
        self._n = n + k
        order = np.lexsort((self._seq[: self._n], self._time[: self._n]))
        self._time[: self._n] = self._time[order]
        self._seq[: self._n] = self._seq[order]
        self._slot[: self._n] = self._slot[order]

    def peek_time(self) -> float:
        if self._n == 0:
            raise IndexError("peek on empty EventHeap")
        return float(self._time[0])

    def pop(self) -> tuple[float, int, Any]:
        """Remove and return the earliest event as (time, seq, payload)."""
        if self._n == 0:
            raise IndexError("pop from empty EventHeap")
        t = float(self._time[0])
        seq = int(self._seq[0])
        slot = int(self._slot[0])
        payload = self._payload[slot]
        self._payload[slot] = None
        self._free.append(slot)
        self._n -= 1
        if self._n:
            last = self._n
            for arr in (self._time, self._seq, self._slot):
                arr[0] = arr[last]
            self._sift_down(0)
        return t, seq, payload


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet-only knobs layered on top of ``FedConfig``.

    Attributes:
      update_pool: number of distinct pre-encoded client payloads (client k
        ships ``pool[k % update_pool]``; clients sharing one form a cohort).
      examples_per_client: uniform |D_k| — the aggregation weight and the
        compute-time workload per client.
      compat: route transfers through the scalar channel path in legacy
        call order (bit-exact rng streams vs the per-client servers; small
        fleets only — O(participants) Python calls).
      share_nic: apply the closed-form NIC sharing approximation to the
        broadcast batch (each flow at min(link, NIC/batch)) instead of the
        O(flows²) water-filling the small-fleet server runs.
      heap_capacity: initial EventHeap allocation (grows as needed).
    """

    update_pool: int = 8
    examples_per_client: int = 50
    compat: bool = False
    share_nic: bool = True
    heap_capacity: int = 1024


@dataclasses.dataclass
class FleetResult:
    """What a fleet run reports (communication/aggregation view)."""

    rounds_run: int
    participants_per_round: list
    dropped_per_round: list
    round_times: list
    upload_bytes: int
    download_bytes: int
    final_update: Any
    telemetry: dict

    @property
    def total_time_s(self) -> float:
        return float(sum(self.round_times))


def _payload_pool(
    params: Pytree, cfg: FedConfig, fleet: FleetConfig,
    spec: CodecSpec | None = None,
) -> tuple[list[bytes], np.ndarray]:
    """``update_pool`` distinct client payloads, pre-encoded once.

    Each is the template perturbed by seeded noise, pushed through the
    REAL upstream encode path (FTTQ quantize → fused pack → wire), so
    fleet bytes and aggregation exercise the same kernels and codecs as
    the per-client servers — only local SGD is stubbed out. A non-ternary
    ``spec`` (a controller ladder rung) encodes the same perturbed trees
    through that codec instead — the rng stream is identical per call, so
    slot j of every rung's pool encodes the same underlying update.
    """
    rng = np.random.default_rng(cfg.seed + 17)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    pool: list[bytes] = []
    for _ in range(max(1, fleet.update_pool)):
        perturbed = [
            np.asarray(leaf)
            + 0.1 * rng.standard_normal(np.shape(leaf)).astype(np.float32)
            for leaf in leaves
        ]
        tree = jax.tree_util.tree_unflatten(treedef, perturbed)
        if spec is not None and spec.kind != "ternary":
            tree, _ = compress_pytree(tree, spec)
        elif cfg.algorithm == "tfedavg":
            wq = fttq_mod.init_wq_tree(tree, cfg.fttq)
            tree = client_update_payload(tree, wq, cfg.fttq,
                                         fused=cfg.fused_encode)
        pool.append(encode_update(tree))
    sizes = np.array([len(b) for b in pool], dtype=np.int64)
    return pool, sizes


def _pool_indices(ids: np.ndarray, n_honest: int,
                  atk: np.ndarray) -> np.ndarray:
    """Pool slot per client: honest client k ships ``pool[k % P]``;
    an attacker ships the poisoned twin at ``P + (k % P)``. Attacker
    cohorts therefore stay cohorts — byte-identical poisoned payloads —
    which is the fleet approximation of per-client attack rng (the poison
    keys on the pool index, not the client id)."""
    base = ids % n_honest
    return base + n_honest * atk[ids]


def _draw_or_wait(avail, t_now, n_sel, n_clients, rng):
    """Participant draw that advances time while the fleet is empty
    (same contract as the per-client servers)."""
    wait = 0.0
    ids = draw_participants(avail, t_now, n_sel, n_clients, rng)
    while ids.size == 0:
        t_next = avail.next_change(t_now + wait)
        if not np.isfinite(t_next):
            raise RuntimeError("no client is ever available")
        wait = t_next - t_now
        ids = draw_participants(avail, t_next, n_sel, n_clients, rng)
    return ids, wait


def _ingest_grouped(
    surv: np.ndarray,
    pool_idx: np.ndarray,
    weights: np.ndarray,
    pool: list[bytes],
    cfg: FedConfig,
    tier: EdgeTier | None,
    agg: Aggregator | None,
    *,
    staleness: np.ndarray | None = None,
    compat: bool = False,
    gate: UpdateGate | None = None,
) -> tuple[int, int]:
    """Cohort-grouped server ingest: one weighted add per (edge, payload)
    group — the weights sum exactly because cohort payloads are
    byte-identical. ``compat`` keeps the legacy one-add-per-client order.

    With a ``gate``, the defense check runs COHORT-LEVEL: once per distinct
    payload per call (cohort members are byte-identical, so one verdict
    covers them all — the gate's own counters therefore count cohorts);
    every member of a refused cohort is quarantined, booked on the
    tier/aggregator ledger, and counted in the returned
    ``(quarantined_clients, quarantined_bytes)``.
    """
    P = len(pool)
    stale = staleness if staleness is not None else np.zeros(surv.size)
    q_clients = q_bytes = 0
    if compat:
        for k, j, w, s in zip(surv, pool_idx, weights, stale):
            if gate is not None and not gate.check(pool[int(j)]).ok:
                q_clients += 1
                q_bytes += len(pool[int(j)])
                if tier is not None:
                    tier.note_quarantined(len(pool[int(j)]))
                elif agg is not None:
                    agg.note_quarantined(len(pool[int(j)]))
                continue
            if tier is not None:
                tier.add(int(k), pool[int(j)], float(w), staleness=float(s))
            else:
                agg.add(pool[int(j)], weight=float(w))
        return q_clients, q_bytes
    if gate is not None and surv.size:
        ok_by_j = {int(j): gate.check(pool[int(j)]).ok
                   for j in np.unique(pool_idx)}
        okm = np.array([ok_by_j[int(j)] for j in pool_idx], dtype=bool)
        if not okm.all():
            bad = pool_idx[~okm]
            q_clients = int(bad.size)
            q_bytes = int(sum(len(pool[int(j)]) for j in bad))
            if tier is not None:
                tier.note_quarantined(q_bytes, updates=q_clients)
            elif agg is not None:
                for j in bad:
                    agg.note_quarantined(len(pool[int(j)]))
            surv, pool_idx, weights, stale = (
                surv[okm], pool_idx[okm], weights[okm], stale[okm]
            )
    if surv.size == 0:
        return q_clients, q_bytes
    if tier is not None:
        e = edges_of(surv, cfg.n_clients, cfg.hierarchy)
        key = e * P + pool_idx
    else:
        key = pool_idx
    uniq, inv = np.unique(key, return_inverse=True)
    wsum = np.bincount(inv, weights=weights, minlength=uniq.size)
    count = np.bincount(inv, minlength=uniq.size)
    ssum = np.bincount(inv, weights=stale, minlength=uniq.size)
    for g, ke in enumerate(uniq):
        if tier is not None:
            tier.add_cohort(int(ke // P), pool[int(ke % P)],
                            weight=float(wsum[g]), n_clients=int(count[g]),
                            staleness_sum=float(ssum[g]))
        else:
            agg.add(pool[int(ke)], weight=float(wsum[g]))
    return q_clients, q_bytes


def run_fleet(
    params: Pytree, cfg: FedConfig, fleet: FleetConfig | None = None
) -> FleetResult:
    """Run ``cfg.rounds`` fleet-scale rounds (sync) or folds (async).

    Dispatches on ``cfg.mode`` like ``run_federated``; the hierarchy tier
    engages behind ``cfg.hierarchy`` exactly as in the per-client servers.
    The byte ledger is asserted balanced before returning.
    """
    fleet = fleet or FleetConfig()
    if cfg.mode == "async":
        return _run_fleet_async(params, cfg, fleet)
    if cfg.mode != "sync":
        raise ValueError(f"unknown federated mode {cfg.mode!r}")
    return _run_fleet_sync(params, cfg, fleet)


def _setup(params, cfg, fleet):
    rng = np.random.default_rng(cfg.seed)
    channel = Channel(cfg.channel, cfg.n_clients, seed=cfg.seed + 1)
    avail = make_availability(cfg.availability, cfg.n_clients, seed=cfg.seed)
    pool, sizes = _payload_pool(params, cfg, fleet)
    # cohort-level adaptive compression (``fed/controller.py``): payload
    # pools are pre-encoded once per ladder rung; each round ships from the
    # rung the goodput policy selects. Off (the default) → single pool,
    # bit-exact with pre-controller fleets.
    fctrl = None
    pools: dict[str, tuple[list, np.ndarray]] = {}
    if cfg.controller is not None and cfg.controller.enabled:
        fctrl = FleetCohortController(cfg.controller)
        agg_rung = cfg.controller.aggressive_rung
        agg_spec = CodecSpec(
            kind=agg_rung, residual=cfg.controller.residual_codec,
            fttq=cfg.fttq, topk_fraction=cfg.controller.topk_fraction,
            fused_encode=cfg.fused_encode,
        )
        pools["ternary"] = (pool, sizes)
        pools[agg_rung] = _payload_pool(params, cfg, fleet, spec=agg_spec)
    # Byzantine layer: the attacker cohort ships POISONED TWINS of the pool
    # (slot P+j twins slot j — see ``_pool_indices``); the gate, when the
    # defense is on, vets payloads cohort-level at ingest.
    atk = np.zeros(cfg.n_clients, dtype=bool)
    if cfg.attack is not None and cfg.attack.n_attackers > 0:
        atk[np.fromiter(attacker_ids(cfg.attack, cfg.n_clients),
                        dtype=np.int64)] = True
        pool = pool + [poison_blob(b, cfg.attack, client_id=j)
                       for j, b in enumerate(pool)]
        sizes = np.array([len(b) for b in pool], dtype=np.int64)
        for rung, (rp, _rs) in list(pools.items()):
            twinned = rp + [poison_blob(b, cfg.attack, client_id=j)
                            for j, b in enumerate(rp)]
            pools[rung] = (
                twinned, np.array([len(b) for b in twinned], dtype=np.int64)
            )
    gate = (UpdateGate(cfg.defense, params)
            if cfg.defense is not None and cfg.defense.enabled else None)
    bcast = broadcast_blob(params, cfg)
    rule, trim_frac = resolve_rule(cfg)
    if fctrl is not None and rule != "mean":
        raise ValueError(
            "adaptive compression requires aggregation rule 'mean': "
            "mixed-codec rounds have no robust-vote decomposition"
        )
    tier = (EdgeTier(cfg.hierarchy, cfg.fttq, cfg.n_clients,
                     fused_encode=cfg.fused_encode,
                     rule=rule, trim_frac=trim_frac)
            if cfg.hierarchy.enabled else None)
    agg = (Aggregator(chunk_c=cfg.agg_chunk_c, rule=rule, trim_frac=trim_frac)
           if tier is None else None)
    return (rng, channel, avail, pool, sizes, bcast, tier, agg, atk, gate,
            fctrl, pools)


def _defense_extra(gate, tier, client_up_bytes, q_clients, q_bytes):
    """The ``telemetry["defense"]`` section for a fleet run, with the
    extended client-hop ledger: shipped == ingested + quarantined. For the
    tier path the ingested side is the tier's own (independent) ingest
    ledger, so the balance is a genuine cross-check."""
    if gate is None:
        return None
    dt = gate.telemetry()
    dt["quarantined_clients"] = q_clients
    dt["quarantined_client_bytes"] = q_bytes
    ingested = (int(tier.ingest_bytes.sum()) if tier is not None
                else client_up_bytes - q_bytes)
    dt["ledger_balanced"] = client_up_bytes == ingested + q_bytes
    return {"defense": dt}


def _telemetry(channel, tier, cfg, *, extra=None):
    summary = channel.summary()
    out = {
        "availability": cfg.availability.kind,
        "retrans_bytes": summary.get("retrans_bytes", 0),
        "retries": summary.get("retries", 0),
        "goodput_fraction": summary.get("goodput_fraction", 1.0),
        "transfer_summary": summary,
    }
    if tier is not None:
        hier = tier.telemetry()
        if not hier["ledger_balanced"]:
            raise AssertionError(
                "hierarchy byte ledger out of balance: "
                f"edges shipped {hier['edge_to_root_bytes']} B, root "
                f"ingested {hier['root_ingest_bytes']} B"
            )
        out["hierarchy"] = hier
    if extra:
        out.update(extra)
    return out


def _run_fleet_sync(params, cfg, fleet) -> FleetResult:
    (rng, channel, avail, pool, sizes, bcast, tier, agg, atk, gate,
     fctrl, pools) = _setup(params, cfg, fleet)
    P = max(1, fleet.update_pool)     # honest pool size (twins live at P+j)
    deadline = (cfg.channel.deadline_s
                if cfg.channel.deadline_s > 0 else float("inf"))
    n_sel = max(int(np.ceil(cfg.participation * cfg.n_clients)), 1)
    w_k = float(fleet.examples_per_client)

    up_bytes = down_bytes = 0
    client_up_bytes = 0               # client-hop only (no edge→root bytes)
    q_clients_total = q_bytes_total = 0
    parts_hist, dropped_hist, round_times = [], [], []
    mean = None
    t_now = 0.0
    for _ in range(cfg.rounds):
        if fctrl is not None:
            # cohort policy: the whole round ships from one rung's pool.
            pool, sizes = pools[fctrl.select()]
        ids, wait_s = _draw_or_wait(avail, t_now, n_sel, cfg.n_clients, rng)
        pool_idx = _pool_indices(ids, P, atk)
        down = channel.transfer_batch(
            ids, len(bcast), "down",
            share_nic=fleet.share_nic, compat=fleet.compat,
        )
        comp = channel.compute_time_batch(
            ids, fleet.examples_per_client * cfg.local_epochs
        )
        up = channel.transfer_batch(ids, sizes[pool_idx], "up",
                                    compat=fleet.compat)
        if fctrl is not None:
            fctrl.observe_round(int(sizes[pool_idx].sum()), float(up.sum()))
        total = down + comp + up
        ok = total <= deadline
        if not ok.any():          # never lose a round: keep the fastest
            ok[np.argmin(total)] = True
        surv, sj = ids[ok], pool_idx[ok]
        n_dropped = int(ids.size - surv.size)

        down_bytes += len(bcast) * int(ids.size)
        up_bytes += int(sizes[sj].sum())
        client_up_bytes += int(sizes[sj].sum())
        weights = np.full(surv.size, w_k)
        q_upd, q_b = _ingest_grouped(surv, sj, weights, pool, cfg, tier, agg,
                                     compat=fleet.compat, gate=gate)
        q_clients_total += q_upd
        q_bytes_total += q_b
        if surv.size > q_upd:
            if tier is not None:
                mean, info = tier.fold()
                up_bytes += info["edge_to_root_bytes"]
            else:
                mean = agg.finalize(reset=True)
        # else: every survivor was quarantined — hold the model this round.

        last = float(total[ok].max())
        round_times.append(
            wait_s + (max(deadline, last) if n_dropped else last)
        )
        t_now += round_times[-1]
        parts_hist.append(int(surv.size) - q_upd)
        dropped_hist.append(n_dropped)

    extra = _defense_extra(gate, tier, client_up_bytes,
                           q_clients_total, q_bytes_total) or {}
    if fctrl is not None:
        extra["controller"] = fctrl.telemetry()
    return FleetResult(
        rounds_run=cfg.rounds,
        participants_per_round=parts_hist,
        dropped_per_round=dropped_hist,
        round_times=round_times,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        final_update=mean,
        telemetry=_telemetry(channel, tier, cfg, extra=extra),
    )


def _run_fleet_async(params, cfg, fleet) -> FleetResult:
    (rng, channel, avail, pool, sizes, bcast, tier, agg, atk, gate,
     fctrl, pools) = _setup(params, cfg, fleet)
    if fctrl is not None:
        # arrivals outlive rung switches, so the rung pools concatenate
        # into ONE indexable pool: an event's payload index stays valid no
        # matter which rung later dispatches select.
        rung_offset: dict[str, int] = {}
        combined: list[bytes] = []
        for rung, (rp, _rs) in pools.items():
            rung_offset[rung] = len(combined)
            combined = combined + rp
        pool = combined
        sizes = np.array([len(b) for b in pool], dtype=np.int64)
    P = max(1, fleet.update_pool)     # honest pool size (twins live at P+j)
    n_conc = cfg.max_concurrency or max(
        int(np.ceil(cfg.participation * cfg.n_clients)), 1
    )
    n_conc = min(n_conc, cfg.n_clients)
    buffer_k = max(1, min(cfg.buffer_k, n_conc))
    max_stale = cfg.max_staleness if cfg.max_staleness > 0 else float("inf")
    w_k = float(fleet.examples_per_client)
    heap = EventHeap(capacity=max(fleet.heap_capacity, n_conc))

    version = 0
    up_bytes = down_bytes = 0
    client_up_bytes = 0
    q_clients_total = q_bytes_total = 0
    dropped = 0
    dropped_bytes = 0
    staleness_hist: list[int] = []
    fold_times, parts_hist = [], []
    mean = None

    def dispatch(ids: np.ndarray, t0: float) -> None:
        nonlocal down_bytes
        pool_idx = _pool_indices(ids, P, atk)
        if fctrl is not None:
            # cohort policy at dispatch time: this batch ships from the
            # selected rung's slice of the combined pool.
            pool_idx = pool_idx + rung_offset[fctrl.select()]
        down = channel.transfer_batch(ids, len(bcast), "down",
                                      share_nic=fleet.share_nic,
                                      compat=fleet.compat)
        comp = channel.compute_time_batch(
            ids, fleet.examples_per_client * cfg.local_epochs
        )
        up = channel.transfer_batch(ids, sizes[pool_idx], "up",
                                    compat=fleet.compat)
        if fctrl is not None:
            fctrl.observe_round(int(sizes[pool_idx].sum()), float(up.sum()))
        down_bytes += len(bcast) * int(ids.size)
        heap.push_many(
            t0 + down + comp + up,
            [(int(k), int(j), version) for k, j in zip(ids, pool_idx)],
        )

    ids0, wait0 = _draw_or_wait(avail, 0.0, n_conc, cfg.n_clients, rng)
    dispatch(ids0, wait0)

    buf_k: list[int] = []
    buf_j: list[int] = []
    buf_w: list[float] = []
    buf_s: list[float] = []
    last_fold_t = 0.0
    while version < cfg.rounds:
        if len(heap) == 0:  # pragma: no cover - dispatch always refills
            raise RuntimeError("fleet starved: no in-flight clients")
        now, _seq, (k, j, born) = heap.pop()
        staleness = version - born
        staleness_hist.append(staleness)
        up_bytes += int(sizes[j])
        client_up_bytes += int(sizes[j])
        if staleness > max_stale and cfg.staleness_policy == "drop":
            dropped += 1
            dropped_bytes += int(sizes[j])
        else:
            w = w_k * (1.0 + staleness) ** (-cfg.staleness_exponent)
            if staleness > max_stale:     # "downweight"
                w *= (1.0 + staleness - max_stale) ** (
                    -cfg.staleness_exponent
                )
            buf_k.append(k)
            buf_j.append(j)
            buf_w.append(w)
            buf_s.append(float(staleness))

        if len(buf_k) >= buffer_k:
            q_upd, q_b = _ingest_grouped(
                np.asarray(buf_k), np.asarray(buf_j), np.asarray(buf_w),
                pool, cfg, tier, agg,
                staleness=np.asarray(buf_s), compat=fleet.compat, gate=gate,
            )
            q_clients_total += q_upd
            q_bytes_total += q_b
            if len(buf_k) > q_upd:
                if tier is not None:
                    mean, info = tier.fold()
                    up_bytes += info["edge_to_root_bytes"]
                else:
                    mean = agg.finalize(reset=True)
            # else: the whole buffer was quarantined — the fold still
            # closes (version advances) so a poisoned fleet cannot stall
            # the event loop; the model just holds.
            parts_hist.append(len(buf_k) - q_upd)
            buf_k, buf_j, buf_w, buf_s = [], [], [], []
            version += 1
            fold_times.append(now - last_fold_t)
            last_fold_t = now
            # batch refill at the fold boundary (the cohort approximation
            # of the per-arrival refill): top the fleet back up to n_conc.
            if version < cfg.rounds:
                need = n_conc - len(heap)
                if need > 0:
                    ids, wait = _draw_or_wait(avail, now, need,
                                              cfg.n_clients, rng)
                    dispatch(ids, now + wait)

    extra = {
        "staleness_hist": np.bincount(
            np.asarray(staleness_hist, dtype=np.int64)
        ).tolist() if staleness_hist else [],
        "dropped_updates": dropped,
        "dropped_update_bytes": dropped_bytes,
    }
    # staleness drops never reach the gate, so the gated hop is the
    # arrivals net of them: shipped == ingested + quarantined still holds.
    defense = _defense_extra(gate, tier, client_up_bytes - dropped_bytes,
                             q_clients_total, q_bytes_total)
    if defense:
        extra.update(defense)
    if fctrl is not None:
        extra["controller"] = fctrl.telemetry()
    return FleetResult(
        rounds_run=version,
        participants_per_round=parts_hist,
        dropped_per_round=[0] * version,
        round_times=fold_times,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        final_update=mean,
        telemetry=_telemetry(channel, tier, cfg, extra=extra),
    )
