"""Client availability traces — who is reachable at simulated time t.

Real federated fleets are not always-on: phones charge at night, desktops
sleep, links drop. Both servers used to assume the full population was
reachable at every draw (uniform resampling); this module makes the
reachable set an explicit, deterministic function of simulated time so the
same seed always replays the same fleet churn.

Three trace models behind one tiny protocol:

  - ``AlwaysOn``      — the pre-scenario behavior: everyone, always. The
                        participant draw consumes the SAME rng stream as
                        before, so existing runs reproduce bit-exactly.
  - ``DiurnalChurn``  — sinusoidal timezone cohorts. Client k belongs to
                        cohort k mod n_cohorts; cohort c's availability
                        level at time t is
                            p_c(t) = floor + (1-floor)·(1+sin(2πt/T + φ_c))/2
                        and client k is online iff its fixed propensity
                        draw u_k ≤ p_c(t). Clients with low u_k are nearly
                        always on; high-u_k clients appear only near the
                        cohort's peak — smooth, deterministic diurnal churn
                        with no per-query randomness.
  - ``TraceReplay``   — explicit per-client (on, off) interval schedules,
                        either handed in directly (a recorded trace) or
                        generated once from seeded exponential on/off
                        durations. Membership is one vectorized pass over
                        the flattened boundary array, so replays are
                        deterministic and cheap even for 10⁶ clients.

``AvailabilityConfig`` is the serializable knob surface
(``FedConfig.availability``); ``make_availability`` builds the model for a
fleet. Servers query ``available_mask(t)`` for the participant draw and
``next_change(t)`` when nobody is reachable and simulated time must
advance to the next arrival/departure.
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class ClientAvailability(Protocol):
    """Deterministic map from simulated time to the reachable client set."""

    def available_mask(self, t: float) -> np.ndarray:
        """Boolean (n_clients,) mask: True = reachable at time ``t``."""
        ...

    def next_change(self, t: float) -> float:
        """Earliest time > ``t`` at which the mask may differ (inf = never).
        Used by the async server to advance time when nobody is online."""
        ...


@dataclasses.dataclass(frozen=True)
class AvailabilityConfig:
    """Serializable scenario knobs (``FedConfig.availability``).

    Attributes:
      kind: "always_on" | "diurnal" | "trace".
      period_s: diurnal cycle length in SIMULATED seconds (a "day").
      floor: minimum availability level of a cohort at its trough, in
        [0, 1] (0.1 → at least ~10% of each cohort stays reachable).
      n_cohorts: number of timezone cohorts spread evenly around the cycle.
      mean_on_s / mean_off_s: trace-replay exponential session/gap means.
      horizon_s: trace-replay schedule length; the schedule tiles
        periodically past it so long runs never fall off the trace.
      seed_offset: folded into the fleet seed so availability draws are
        decorrelated from link/participation draws.
    """

    kind: str = "always_on"
    period_s: float = 400.0
    floor: float = 0.1
    n_cohorts: int = 4
    mean_on_s: float = 120.0
    mean_off_s: float = 60.0
    horizon_s: float = 4000.0
    seed_offset: int = 7919


class AlwaysOn:
    """Everyone reachable at every instant (the pre-scenario fleet)."""

    def __init__(self, n_clients: int):
        self._mask = np.ones(n_clients, dtype=bool)

    def available_mask(self, t: float) -> np.ndarray:
        return self._mask

    def next_change(self, t: float) -> float:
        return float("inf")


class DiurnalChurn:
    """Sinusoidal timezone-cohort availability (see module docstring)."""

    def __init__(self, n_clients: int, *, period_s: float = 400.0,
                 floor: float = 0.1, n_cohorts: int = 4, seed: int = 0):
        if not 0.0 <= floor <= 1.0:
            raise ValueError(f"floor must be in [0, 1], got {floor}")
        if period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {period_s}")
        self.period_s = float(period_s)
        self.floor = float(floor)
        self.n_cohorts = max(1, int(n_cohorts))
        rng = np.random.default_rng(seed)
        # fixed per-client propensity: the one random draw, made once.
        self._u = rng.uniform(0.0, 1.0, size=n_clients)
        self._cohort = np.arange(n_clients) % self.n_cohorts
        self._phase = 2.0 * np.pi * self._cohort / self.n_cohorts
        # u=1 would never come online even at a full-amplitude peak; nudge
        # every propensity strictly below 1 so peaks reach the whole cohort.
        self._u = np.minimum(self._u, 1.0 - 1e-9)

    def _level(self, t: float) -> np.ndarray:
        s = np.sin(2.0 * np.pi * t / self.period_s + self._phase)
        return self.floor + (1.0 - self.floor) * 0.5 * (1.0 + s)

    def available_mask(self, t: float) -> np.ndarray:
        return self._u <= self._level(t)

    def next_change(self, t: float) -> float:
        # the mask changes continuously; a quarter-period step bounds the
        # wait without simulating the exact crossing times.
        return t + self.period_s / 4.0

    def expected_online(self, t: float) -> float:
        """Mean availability level across the fleet (telemetry)."""
        return float(self._level(t).mean())


class TraceReplay:
    """Deterministic per-client on/off interval schedules.

    ``schedules[k]`` is an ascending array of boundary times
    ``[on_0, off_0, on_1, off_1, ...]``: client k is online in
    [on_i, off_i). Schedules tile periodically past ``horizon_s`` so the
    trace never runs out.
    """

    def __init__(self, schedules: list[np.ndarray], horizon_s: float):
        if horizon_s <= 0:
            raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
        self.horizon_s = float(horizon_s)
        self.schedules = [np.asarray(s, dtype=np.float64) for s in schedules]
        for k, s in enumerate(self.schedules):
            if s.ndim != 1 or (s.size and np.any(np.diff(s) < 0)):
                raise ValueError(f"schedule {k} is not an ascending 1-D array")
        # flattened bounds + per-client segment offsets: mask queries are
        # ONE vectorized pass over all boundaries instead of a Python loop
        # of per-client searchsorteds (the fleet-scale requirement).
        lens = np.array([s.size for s in self.schedules], dtype=np.int64)
        self._seg_end = np.cumsum(lens)
        self._seg_start = self._seg_end - lens
        self._flat = (np.concatenate(self.schedules) if self.schedules
                      else np.empty(0, dtype=np.float64))

    @classmethod
    def generate(cls, n_clients: int, *, mean_on_s: float = 120.0,
                 mean_off_s: float = 60.0, horizon_s: float = 4000.0,
                 seed: int = 0) -> "TraceReplay":
        """Seeded exponential on/off sessions, drawn once at construction."""
        rng = np.random.default_rng(seed)
        schedules = []
        for _ in range(n_clients):
            # random initial phase: start mid-gap or mid-session.
            bounds = [-float(rng.exponential(mean_off_s))]
            on = True
            while bounds[-1] < horizon_s:
                dur = mean_on_s if on else mean_off_s
                bounds.append(bounds[-1] + float(rng.exponential(dur)))
                on = not on
            # boundary list starts with an ON edge (possibly before t=0)
            schedules.append(np.asarray(bounds, dtype=np.float64))
        return cls(schedules, horizon_s)

    def _fold(self, t: float) -> float:
        return float(t % self.horizon_s)

    def available_mask(self, t: float) -> np.ndarray:
        tf = self._fold(t)
        # schedules start with an ON edge, so an ODD number of passed
        # boundaries means the client is inside an ON span. Counting the
        # passed boundaries per client via a cumulative sum over the
        # flattened bounds is bit-identical to a per-client searchsorted
        # (side="right" counts elements ≤ tf, exactly what ``<=`` counts).
        passed = np.concatenate([[0], np.cumsum(self._flat <= tf)])
        counts = passed[self._seg_end] - passed[self._seg_start]
        return (counts % 2) == 1

    def next_change(self, t: float) -> float:
        tf = self._fold(t)
        # the schedule tiles at horizon_s, so the wrap itself is a change
        # point (folded time jumps back to 0 and the mask re-evaluates).
        best = self.horizon_s - tf
        # each client's candidate is its first boundary > tf (ascending),
        # so the global candidate is just the min boundary in (tf, horizon).
        m = (self._flat > tf) & (self._flat < self.horizon_s)
        if m.any():
            best = min(best, float(self._flat[m].min() - tf))
        return t + max(best, 1e-9)


def make_availability(cfg: AvailabilityConfig, n_clients: int,
                      seed: int = 0) -> ClientAvailability:
    """Build the availability model for one fleet (seeded, deterministic)."""
    if cfg.kind == "always_on":
        return AlwaysOn(n_clients)
    if cfg.kind == "diurnal":
        return DiurnalChurn(
            n_clients, period_s=cfg.period_s, floor=cfg.floor,
            n_cohorts=cfg.n_cohorts, seed=seed + cfg.seed_offset,
        )
    if cfg.kind == "trace":
        return TraceReplay.generate(
            n_clients, mean_on_s=cfg.mean_on_s, mean_off_s=cfg.mean_off_s,
            horizon_s=cfg.horizon_s, seed=seed + cfg.seed_offset,
        )
    raise ValueError(f"unknown availability kind {cfg.kind!r}")


def draw_participants(avail: ClientAvailability, t: float, n: int,
                      n_clients: int, rng: np.random.Generator) -> np.ndarray:
    """Sample ≤ ``n`` distinct ONLINE clients at time ``t``.

    With every client online this consumes the rng stream EXACTLY like the
    historical uniform draw (``rng.choice(n_clients, n, replace=False)``),
    so ``AlwaysOn`` scenarios reproduce pre-scenario runs bit-for-bit.
    Under churn, the draw is uniform over the online subset (and shrinks
    to its size when fewer than ``n`` are reachable).
    """
    mask = avail.available_mask(t)
    if mask.all():
        return rng.choice(n_clients, size=min(n, n_clients), replace=False)
    online = np.flatnonzero(mask)
    if online.size == 0:
        return online
    take = min(n, online.size)
    return online[rng.choice(online.size, size=take, replace=False)]


def draw_one(avail: ClientAvailability, t: float, n_clients: int,
             rng: np.random.Generator) -> int:
    """Sample one online client (the async refill draw); -1 if none.

    Bit-compatibility contract as ``draw_participants``: all-online
    consumes ``rng.integers(n_clients)`` exactly like the historical path.
    """
    mask = avail.available_mask(t)
    if mask.all():
        return int(rng.integers(n_clients))
    online = np.flatnonzero(mask)
    if online.size == 0:
        return -1
    return int(online[rng.integers(online.size)])
