"""Event-driven buffered-asynchronous federated server (FedBuf-style).

The synchronous server (Algorithm 2) pays a barrier per round: every
participant waits for the slowest survivor. At fleet scale that barrier is
the throughput ceiling, so this server removes it:

  - ``max_concurrency`` clients are always in flight. Each one downloads
    the current global model (serialized through ``repro.comm.wire``),
    trains locally, and uploads; its arrival time is download + compute +
    upload from the ``repro.comm.channel`` model.
  - Arrivals are processed from an event queue in simulated-time order.
    The server BUFFERS them and aggregates every ``buffer_k`` arrivals —
    never blocking on any individual client.
  - An arrival carries the version of the model it started from; its
    aggregation weight is discounted by staleness,
        w_i ∝ |D_i| · (1 + staleness_i)^(-α)          (α = staleness_exponent)
    and the buffer average is mixed into the global model with rate η:
        θ ← (1-η)·θ + η·Σ ŵ_i·θ_i .
    With fresh updates (staleness 0), η = 1 and K = concurrency this
    reduces exactly to the synchronous weighted average.

Bytes are measured from the serialized buffers on both directions; transfer
times are logged per client, so the async-vs-sync comparison reads out in
simulated seconds as well as bytes. Compression is per-direction
(``FedConfig.compression``): dispatch serializes through the DOWNSTREAM
codec spec and arrivals through the UPSTREAM one (via the shared
``broadcast_blob`` / ``train_client`` helpers). Arrivals stream straight
into ONE long-lived ``fed.aggregator.Aggregator`` — zero-copy record
ingest, the fused packed fan-in kernel for ternary records, codec-registry
dequant for everything else — whose staging buffers and leaf plans persist
ACROSS mixes (``finalize(reset=True)`` every ``buffer_k`` arrivals), so
asymmetric up/down codecs meter correctly, the buffer is never expanded to
per-client dense trees, and nothing is re-allocated per aggregation
(``cfg.fused_aggregation=False`` restores the reference dequant loop over
a buffered blob list).
"""

from __future__ import annotations

import heapq
from typing import Any, Callable

import jax
import numpy as np

from repro.comm import Channel
from repro.comm.wire import decode_update
from repro.data.federated import ClientDataset
from repro.fed.aggregator import Aggregator
from repro.fed.simulation import (
    FedConfig,
    FedResult,
    _make_local_steps,
    broadcast_blob,
    client_round_time,
    dequantize_tree,
    receive_broadcast,
    train_client,
)
from repro.optim import Optimizer

Pytree = Any


def _weighted_mix(global_params, buffered, eta, cfg: FedConfig | None = None,
                  agg: Aggregator | None = None):
    """θ ← (1-η)·θ + η·Σ ŵ_i·dequant(blob_i) over the buffered arrivals.

    ``buffered`` holds (staleness-discounted weight, wire blob) pairs; the
    weighted mean streams through the fused aggregator (Σ ŵ normalizes
    inside ``finalize``), then mixes into the global with rate η. Passing a
    long-lived ``agg`` reuses its staging buffers (``finalize(reset=True)``)
    instead of constructing a fresh one per mix.
    """
    if cfg is None or cfg.fused_aggregation:
        if agg is None:
            agg = Aggregator(chunk_c=cfg.agg_chunk_c if cfg is not None else 16)
        for w, blob in buffered:
            agg.add(blob, weight=w)
        mean = agg.finalize(reset=True)
    else:
        raw = np.array([w for w, _ in buffered], dtype=np.float64)
        wts = raw / raw.sum()
        models = [dequantize_tree(decode_update(b)) for _, b in buffered]

        def wsum(*leaves):
            acc = leaves[0] * wts[0]
            for w, l in zip(wts[1:], leaves[1:]):
                acc = acc + w * l
            return acc

        mean = jax.tree_util.tree_map(wsum, *models)

    return jax.tree_util.tree_map(
        lambda g, m: (1.0 - eta) * g + eta * m, global_params, mean
    )


def run_federated_async(
    apply_fn: Callable,
    global_params: Pytree,
    clients: list[ClientDataset],
    cfg: FedConfig,
    optimizer: Optimizer,
    eval_fn: Callable[[Pytree], tuple[float, float]],
    *,
    eval_every: int = 10,
) -> FedResult:
    """Run ``cfg.rounds`` buffered aggregations; see module docstring."""
    rng = np.random.default_rng(cfg.seed)
    fp_step, qat_step = _make_local_steps(apply_fn, optimizer, cfg)
    channel = Channel(cfg.channel, len(clients), seed=cfg.seed + 1)

    n_conc = cfg.max_concurrency or max(
        int(np.ceil(cfg.participation * len(clients))), 1
    )
    n_conc = min(n_conc, len(clients))
    buffer_k = max(1, min(cfg.buffer_k, n_conc))

    version = 0
    up_bytes = 0
    down_bytes = 0
    seq = 0                       # tie-breaker for the heap
    events: list = []             # (arrival_time, seq, client_id, blob, version)
    buffered: list = []           # (weight, wire blob) — reference path only
    # ONE long-lived aggregator for the whole run: arrivals stream into it
    # as they land and `finalize(reset=True)` every buffer_k keeps its
    # staging buffers + leaf plans alive across mixes (ROADMAP item).
    agg = Aggregator(chunk_c=cfg.agg_chunk_c) if cfg.fused_aggregation else None
    n_buffered = 0
    acc_hist, loss_hist = [], []
    agg_times, staleness_hist, parts_hist = [], [], []
    last_agg_t = 0.0

    # the broadcast only changes when an aggregation bumps `version`, so
    # serialize (requantize + encode) and decode once per version, not per
    # dispatch.
    blob_cache = {"version": -1, "blob": b"", "params": None}

    def current_broadcast() -> tuple[bytes, Any]:
        if blob_cache["version"] != version:
            blob_cache["blob"] = broadcast_blob(global_params, cfg)
            blob_cache["params"] = receive_broadcast(blob_cache["blob"])
            blob_cache["version"] = version
        return blob_cache["blob"], blob_cache["params"]

    def dispatch(k: int, t0: float) -> None:
        """Send the CURRENT global to client k; enqueue its arrival."""
        nonlocal seq, down_bytes
        blob, start_params = current_broadcast()
        down_bytes += len(blob)
        up_blob = train_client(
            clients[k], start_params, cfg, optimizer, fp_step, qat_step, rng
        )
        total = client_round_time(
            channel, k, len(blob), len(up_blob), len(clients[k]) * cfg.local_epochs
        )
        heapq.heappush(events, (t0 + total, seq, k, up_blob, version))
        seq += 1

    start = rng.choice(len(clients), size=n_conc, replace=False)
    for k in start:
        dispatch(int(k), 0.0)

    while version < cfg.rounds:
        if not events:  # pragma: no cover - dispatch() always refills
            raise RuntimeError("async server starved: no in-flight clients")
        now, _, k, up_blob, born = heapq.heappop(events)
        up_bytes += len(up_blob)
        staleness = version - born
        weight = len(clients[k]) * (1.0 + staleness) ** (-cfg.staleness_exponent)
        if agg is not None:
            agg.add(up_blob, weight=weight)  # streams into the live aggregator
        else:
            buffered.append((weight, up_blob))  # decoded in the reference mix
        n_buffered += 1
        staleness_hist.append(staleness)

        if n_buffered >= buffer_k:
            global_params = _weighted_mix(
                global_params, buffered, cfg.mixing_rate, cfg, agg=agg
            )
            buffered = []
            n_buffered = 0
            version += 1
            parts_hist.append(buffer_k)
            agg_times.append(now - last_agg_t)
            last_agg_t = now
            if version % eval_every == 0 or version == cfg.rounds:
                acc, ls = eval_fn(global_params)
                acc_hist.append(float(acc))
                loss_hist.append(float(ls))

        # keep the fleet saturated: replace the arrival with a fresh client
        # (sampled uniformly — fleet churn), carrying the newest global.
        if version < cfg.rounds:
            dispatch(int(rng.integers(len(clients))), now)

    return FedResult(
        accuracy=acc_hist,
        loss=loss_hist,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        rounds_run=version,
        participants_per_round=parts_hist,
        round_times=agg_times,
        dropped_per_round=[0] * version,
        transfer_summary=channel.summary(),
        staleness_per_agg=staleness_hist,
    )
