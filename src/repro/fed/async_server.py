"""Event-driven buffered-asynchronous federated server (FedBuf-style).

The synchronous server (Algorithm 2) pays a barrier per round: every
participant waits for the slowest survivor. At fleet scale that barrier is
the throughput ceiling, so this server removes it:

  - ``max_concurrency`` clients are always in flight. Each one downloads
    the current global model (serialized through ``repro.comm.wire``),
    trains locally, and uploads; its arrival time is download + compute +
    upload from the ``repro.comm.channel`` model. Uploads go through
    ``Channel.transfer_timed``, so simultaneous async arrivals contend for
    the server NIC instead of each enjoying the full pipe.
  - Refill draws sample from the clients ONLINE at dispatch time
    (``FedConfig.availability`` — diurnal churn, trace replay, or the
    always-on fleet, which reproduces pre-scenario runs bit-exactly). If
    nobody is reachable, simulated time advances to the next availability
    change before dispatching.
  - Arrivals are processed from an event queue in simulated-time order.
    The server BUFFERS them and aggregates every ``buffer_k`` arrivals —
    never blocking on any individual client.
  - An arrival carries the version of the model it started from; its
    aggregation weight is discounted by staleness,
        w_i ∝ |D_i| · (1 + staleness_i)^(-α)          (α = staleness_exponent)
    and the buffer average is mixed into the global model with rate η:
        θ ← (1-η)·θ + η·Σ ŵ_i·θ_i .
    With fresh updates (staleness 0), η = 1 and K = concurrency this
    reduces exactly to the synchronous weighted average.
  - A hard staleness cap (``max_staleness``, 0 = off) bounds how old an
    update may be: past the cap it is DROPPED (``staleness_policy="drop"``
    — its bytes were still paid for and are accounted as waste) or
    down-weighted by an extra ``(1+excess)^(-α)`` factor ("downweight").
  - ``adaptive_buffer`` turns the fixed ``buffer_k`` into a controller:
    an EWMA of inter-arrival gaps estimates the arrival rate and
    ``buffer_k ← clamp(round(target_mix_latency_s / gap), 1, concurrency)``
    retunes after every mix, holding the time-per-aggregation near the
    target as churn moves the arrival rate. ``target_mix_latency_s = 0``
    locks the target to the initial K's observed latency on first mix.

Bytes are measured from the serialized buffers on both directions; transfer
times are logged per client, so the async-vs-sync comparison reads out in
simulated seconds as well as bytes. Compression is per-direction
(``FedConfig.compression``): dispatch serializes through the DOWNSTREAM
codec spec and arrivals through the UPSTREAM one (via the shared
``broadcast_blob`` / ``train_client`` helpers). Arrivals stream straight
into ONE long-lived ``fed.aggregator.Aggregator`` — zero-copy record
ingest, the fused packed fan-in kernel for ternary records, codec-registry
dequant for everything else — whose staging buffers and leaf plans persist
ACROSS mixes (``finalize(reset=True)`` every ``buffer_k`` arrivals), so
asymmetric up/down codecs meter correctly, the buffer is never expanded to
per-client dense trees, and nothing is re-allocated per aggregation
(``cfg.fused_aggregation=False`` restores the reference dequant loop over
a buffered blob list). Per-mix telemetry — staleness histogram, dropped /
retransmitted bytes, the buffer_k trajectory — lands in
``FedResult.telemetry``.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np

from repro.comm import Channel
from repro.comm.wire import decode_update
from repro.data.federated import ClientDataset
from repro.fed.aggregator import Aggregator
from repro.fed.attackers import attacker_ids, poison_blob
from repro.fed.availability import draw_one, draw_participants, make_availability
from repro.fed.controller import make_controller
from repro.fed.defense import UpdateGate
from repro.fed.fleet import EventHeap
from repro.fed.hierarchy import EdgeTier
from repro.fed.simulation import (
    FedConfig,
    FedResult,
    _make_local_steps,
    broadcast_blob,
    dequantize_tree,
    receive_broadcast,
    resolve_rule,
    train_client,
)
from repro.optim import Optimizer

Pytree = Any


def _weighted_mix(global_params, buffered, eta, cfg: FedConfig | None = None,
                  agg: Aggregator | None = None):
    """θ ← (1-η)·θ + η·Σ ŵ_i·dequant(blob_i) over the buffered arrivals.

    ``buffered`` holds (staleness-discounted weight, wire blob) pairs; the
    weighted mean streams through the fused aggregator (Σ ŵ normalizes
    inside ``finalize``), then mixes into the global with rate η. Passing a
    long-lived ``agg`` reuses its staging buffers (``finalize(reset=True)``)
    instead of constructing a fresh one per mix.
    """
    if cfg is None or cfg.fused_aggregation:
        if agg is None:
            agg = Aggregator(chunk_c=cfg.agg_chunk_c if cfg is not None else 16)
        for w, blob in buffered:
            agg.add(blob, weight=w)
        mean = agg.finalize(reset=True)
    else:
        raw = np.array([w for w, _ in buffered], dtype=np.float64)
        wts = raw / raw.sum()
        models = [dequantize_tree(decode_update(b)) for _, b in buffered]

        def wsum(*leaves):
            acc = leaves[0] * wts[0]
            for w, l in zip(wts[1:], leaves[1:]):
                acc = acc + w * l
            return acc

        mean = jax.tree_util.tree_map(wsum, *models)

    return jax.tree_util.tree_map(
        lambda g, m: (1.0 - eta) * g + eta * m, global_params, mean
    )


def run_federated_async(
    apply_fn: Callable,
    global_params: Pytree,
    clients: list[ClientDataset],
    cfg: FedConfig,
    optimizer: Optimizer,
    eval_fn: Callable[[Pytree], tuple[float, float]],
    *,
    eval_every: int = 10,
) -> FedResult:
    """Run ``cfg.rounds`` buffered aggregations; see module docstring."""
    rng = np.random.default_rng(cfg.seed)
    fp_step, qat_step = _make_local_steps(apply_fn, optimizer, cfg)
    channel = Channel(cfg.channel, len(clients), seed=cfg.seed + 1)
    avail = make_availability(cfg.availability, len(clients), seed=cfg.seed)

    n_conc = cfg.max_concurrency or max(
        int(np.ceil(cfg.participation * len(clients))), 1
    )
    n_conc = min(n_conc, len(clients))
    buffer_k = max(1, min(cfg.buffer_k, n_conc))
    max_stale = cfg.max_staleness if cfg.max_staleness > 0 else float("inf")
    if cfg.staleness_policy not in ("drop", "downweight"):
        raise ValueError(
            f"unknown staleness_policy {cfg.staleness_policy!r} "
            "(expected 'drop' or 'downweight')"
        )

    version = 0
    up_bytes = 0
    down_bytes = 0
    # arrival events: array-backed min-heap keyed (arrival_time, seq) —
    # the internal seq is assigned in push order, so pops come out in the
    # EXACT order the old (time, seq, ...) tuple heapq produced.
    events = EventHeap(capacity=max(2 * n_conc, 16))
    buffered: list = []           # (weight, wire blob) — reference path only
    rule, trim_frac = resolve_rule(cfg)
    # hierarchical tier (when enabled): arrivals fan into regional edges,
    # each shipping one re-quantized record to the root per mix.
    tier = (EdgeTier(cfg.hierarchy, cfg.fttq, len(clients),
                     fused_encode=cfg.fused_encode,
                     rule=rule, trim_frac=trim_frac)
            if cfg.hierarchy.enabled else None)
    # ONE long-lived aggregator for the whole run: arrivals stream into it
    # as they land and `finalize(reset=True)` every buffer_k keeps its
    # staging buffers + leaf plans alive across mixes (ROADMAP item).
    agg = (Aggregator(chunk_c=cfg.agg_chunk_c, rule=rule, trim_frac=trim_frac)
           if cfg.fused_aggregation and tier is None else None)
    # Byzantine layer: seeded attacker cohort poisons at dispatch; the gate
    # vets every arrival's CONTENT before it can enter the buffer. The gate
    # is long-lived so its scale history warms across the whole run.
    attackers = (attacker_ids(cfg.attack, len(clients))
                 if cfg.attack is not None else frozenset())
    gate = (UpdateGate(cfg.defense, global_params)
            if cfg.defense is not None and cfg.defense.enabled else None)
    # adaptive compression controller (None → static codec path, bit-exact).
    # Encodes are tagged with the model version they trained from.
    ctrl = make_controller(cfg)
    if ctrl is not None and rule != "mean":
        raise ValueError(
            "adaptive compression requires aggregation rule 'mean': "
            "mixed-codec rounds have no robust-vote decomposition"
        )
    arrived_bytes = 0             # client-hop bytes presented to the gate
    n_buffered = 0
    acc_hist, loss_hist = [], []
    agg_times, staleness_hist, parts_hist = [], [], []
    # drop-path ledger for the reference (non-fused) path; the fused path
    # books waste on the long-lived Aggregator itself (note_dropped).
    dropped_updates = 0
    dropped_update_bytes = 0
    last_agg_t = 0.0
    # adaptive buffer_k controller state: EWMA of inter-arrival gaps.
    ewma_gap: float | None = None
    last_arrival = 0.0
    auto_target = 0.0             # resolved target when target_mix_latency_s=0

    # the broadcast only changes when an aggregation bumps `version`, so
    # serialize (requantize + encode) and decode once per version, not per
    # dispatch.
    blob_cache = {"version": -1, "blob": b"", "params": None}

    def current_broadcast() -> tuple[bytes, Any]:
        if blob_cache["version"] != version:
            blob_cache["blob"] = broadcast_blob(global_params, cfg)
            blob_cache["params"] = receive_broadcast(blob_cache["blob"])
            blob_cache["version"] = version
        return blob_cache["blob"], blob_cache["params"]

    def dispatch(k: int, t0: float, clock: float | None = None) -> None:
        """Send the CURRENT global to client k; enqueue its arrival.

        ``clock`` is the event-loop pop time (monotonic across dispatches)
        — the safe prune horizon for the NIC contention window. ``t0`` may
        run ahead of it when an empty fleet forced a wait.
        """
        nonlocal down_bytes
        blob, start_params = current_broadcast()
        down_bytes += len(blob)
        if ctrl is not None:
            ctrl.note_round(version)
        up_blob = train_client(
            clients[k], start_params, cfg, optimizer, fp_step, qat_step,
            rng, controller=ctrl, client_id=k,
        )
        if k in attackers:
            # poison at dispatch (wire-valid re-encode); colluding cohorts
            # key their rng on the model version they trained from.
            up_blob = poison_blob(up_blob, cfg.attack, k, round_idx=version)
        t_down = channel.transfer(k, len(blob), "down")
        t_comp = channel.compute_time(k, len(clients[k]) * cfg.local_epochs)
        # async uploads share the server NIC: the upload's absolute start
        # time lets in-flight arrivals degrade each other's rate.
        t_up = channel.transfer_timed(
            k, len(up_blob), t0 + t_down + t_comp, "up",
            now_s=t0 if clock is None else clock,
        )
        if ctrl is not None:
            ctrl.observe_upload(k, len(up_blob), t_up)
        total = t_down + t_comp + t_up
        events.push(t0 + total, (k, up_blob, version))

    def refill(now: float) -> None:
        """Dispatch one ONLINE client; advance time if nobody is reachable.
        The availability clock ``t`` may run ahead of ``now``, but pending
        heap events can still pop before it — so ``now`` (monotonic across
        refills) stays the channel's prune horizon."""
        t = now
        while True:
            k = draw_one(avail, t, len(clients), rng)
            if k >= 0:
                dispatch(k, t, clock=now)
                return
            t = avail.next_change(t)
            if not np.isfinite(t):
                raise RuntimeError("no client is ever available")

    t0 = 0.0
    start = draw_participants(avail, t0, n_conc, len(clients), rng)
    while start.size == 0:
        t0 = avail.next_change(t0)
        if not np.isfinite(t0):
            raise RuntimeError("no client is ever available")
        start = draw_participants(avail, t0, n_conc, len(clients), rng)
    for k in start:
        dispatch(int(k), t0, clock=0.0)

    while version < cfg.rounds:
        if len(events) == 0:  # pragma: no cover - dispatch() always refills
            raise RuntimeError("async server starved: no in-flight clients")
        now, _, (k, up_blob, born) = events.pop()
        up_bytes += len(up_blob)
        arrived_bytes += len(up_blob)
        staleness = version - born
        gap = now - last_arrival
        last_arrival = now
        ewma_gap = gap if ewma_gap is None else 0.8 * ewma_gap + 0.2 * gap

        if gate is not None and not gate.check(up_blob).ok:
            # content-poisoned: quarantined BEFORE staleness/weighting —
            # it never enters the buffer and never counts toward buffer_k.
            if agg is not None:
                agg.note_quarantined(len(up_blob))
            elif tier is not None:
                tier.note_quarantined(len(up_blob))
        elif staleness > max_stale and cfg.staleness_policy == "drop":
            staleness_hist.append(staleness)
            # the bytes were transferred and paid for; the update is waste.
            if agg is not None:
                agg.note_dropped(len(up_blob))
            else:
                dropped_updates += 1
                dropped_update_bytes += len(up_blob)
        else:
            staleness_hist.append(staleness)
            weight = len(clients[k]) * (
                (1.0 + staleness) ** (-cfg.staleness_exponent)
            )
            if staleness > max_stale:  # "downweight": extra excess discount
                weight *= (1.0 + staleness - max_stale) ** (
                    -cfg.staleness_exponent
                )
            if tier is not None:
                tier.add(k, up_blob, weight, staleness=float(staleness))
            elif agg is not None:
                agg.add(up_blob, weight=weight)  # streams into the aggregator
            else:
                buffered.append((weight, up_blob))
            n_buffered += 1

        if n_buffered >= buffer_k:
            if tier is not None:
                # edges flush ONE record each to the root; that hop is real
                # upstream wire traffic, booked alongside the client hop.
                mean, fold_info = tier.fold()
                up_bytes += fold_info["edge_to_root_bytes"]
                eta = cfg.mixing_rate
                global_params = jax.tree_util.tree_map(
                    lambda g, m: (1.0 - eta) * g + eta * m,
                    global_params, mean,
                )
            else:
                global_params = _weighted_mix(
                    global_params, buffered, cfg.mixing_rate, cfg, agg=agg
                )
            buffered = []
            n_buffered = 0
            version += 1
            parts_hist.append(buffer_k)
            agg_times.append(now - last_agg_t)
            last_agg_t = now
            if cfg.adaptive_buffer and ewma_gap and ewma_gap > 0:
                target = cfg.target_mix_latency_s
                if target <= 0:
                    if auto_target == 0.0:  # lock the initial K's latency
                        auto_target = ewma_gap * buffer_k
                    target = auto_target
                buffer_k = int(np.clip(round(target / ewma_gap), 1, n_conc))
            if version % eval_every == 0 or version == cfg.rounds:
                acc, ls = eval_fn(global_params)
                acc_hist.append(float(acc))
                loss_hist.append(float(ls))

        # keep the fleet saturated: replace the arrival with a fresh ONLINE
        # client, carrying the newest global.
        if version < cfg.rounds:
            refill(now)

    summary = channel.summary()
    if agg is not None:  # the fused path's waste ledger lives on the agg
        dropped_updates, dropped_update_bytes = (
            agg.dropped_updates, agg.dropped_bytes
        )
    telemetry = {
        "staleness_hist": np.bincount(
            np.asarray(staleness_hist, dtype=np.int64)
        ).tolist() if staleness_hist else [],
        "dropped_updates": dropped_updates,
        "dropped_update_bytes": dropped_update_bytes,
        # every mix fires at exactly buffer_k accepted arrivals, so the
        # participants history IS the adaptive-K trajectory.
        "buffer_k_per_agg": parts_hist,
        "retrans_bytes": summary.get("retrans_bytes", 0),
        "retries": summary.get("retries", 0),
        "goodput_fraction": summary.get("goodput_fraction", 1.0),
        "availability": cfg.availability.kind,
    }
    if ctrl is not None:
        telemetry["controller"] = ctrl.telemetry()
    if gate is not None:
        telemetry["defense"] = gate.telemetry()
        # extended ledger on the client hop: every arrived byte either
        # passed the gate (then ingested or staleness-dropped) or was
        # quarantined — the three buckets partition the hop exactly.
        telemetry["defense"]["ledger_balanced"] = (
            arrived_bytes == gate.passed_bytes + gate.quarantined_bytes
        )
    if tier is not None:
        telemetry["hierarchy"] = tier.telemetry()
    return FedResult(
        accuracy=acc_hist,
        loss=loss_hist,
        upload_bytes=up_bytes,
        download_bytes=down_bytes,
        rounds_run=version,
        participants_per_round=parts_hist,
        round_times=agg_times,
        dropped_per_round=[0] * version,
        transfer_summary=summary,
        staleness_per_agg=staleness_hist,
        telemetry=telemetry,
    )
