"""Streaming fused fan-in aggregation for the T-FedAvg server.

``Aggregator`` replaces the dequantize-every-client Python loop
(``core.tfedavg.server_aggregate`` stays as the list-based REFERENCE): wire
blobs stream in one at a time (``add``), their ternary records are decoded
ZERO-COPY (numpy views straight off the buffer, no per-client device
transfer) into reusable stacked ``(chunk, R, LANES)`` uint8 buffers, and
every full chunk is folded into the running dense sum by ONE launch of the
fused Pallas kernel (``kernels.aggregate.packed_weighted_sum``, C-shardable
over a mesh via ``parallel.fanin``). ``finalize`` flushes the remainder and
returns the |D_k|-weighted mean pytree.

Why this is the fan-in artery:
  - per-client fp32 trees are never materialized — the only dense state is
    ONE running fp32 partial per leaf plus one chunk-sized byte buffer, so
    server memory is O(chunk + model), independent of the client count C;
  - per-client scales fold into the kernel's coefficient vector
    (coeff = |D_k| · w_q); leaves with per-leading-dim scales (stacked scan
    layers, conv kernels) aggregate per SCALE SEGMENT — each segment is a
    contiguous byte range of the wire stream, so the split is a zero-copy
    slice;
  - client counts vary round to round, so chunks are padded up to a BUCKET
    (powers of two up to ``chunk_c``; padding rows carry coefficient 0) —
    the jit trace set is the bucket set × leaf shapes, and a new client
    count never triggers a retrace (``parallel.fanin.fanin_trace_count``);
  - non-ternary wire leaves (raw fp32 biases, downcast, top-k — whatever
    the upstream codec spec shipped) take a streaming dequant fallback with
    the same O(chunk) footprint.

Equivalence: Σ w_c·(s_c·codes_c) is computed as Σ (w_c·s_c)·codes_c in fp32
— bit-order differs from the reference's per-client dequant-then-sum, so
parity is within ~1e-6·C, not bit-exact (``tests/test_aggregate.py``).

Robust rules (``rule=`` ctor arg; "mean" is the default and bit-identical
to the pre-rule aggregator):
  - "majority": ternary leaves are decided coordinate-wise by weighted
    plurality over the 2-bit codes — ``kernels.vote`` counts ±1 vote
    masses straight off the same stacked byte buffers (scales NOT folded:
    a vote is scale-free), partial counts accumulate across chunk flushes,
    and ``finalize`` multiplies the winner codes by a per-segment robust
    scale (the weighted MEDIAN of the client scales, so a scale-poisoning
    minority cannot move it). Non-ternary leaves take the coordinate-wise
    weighted median.
  - "trimmed_mean" / "median": every leaf is decoded dense and kept
    per-client (O(C·model) memory — exact order statistics need the full
    sample; these rules are for moderate C), then reduced coordinate-wise.
A sign-flipping / noise-injecting minority with under half the total vote
weight cannot move any majority-voted coordinate (``tests/test_robust.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.comm.wire import decode_update_leaves, tree_from_records
from repro.core.compression import decode_wire_leaf
from repro.core.ternary import TernaryTensor
from repro.kernels.aggregate import BLOCK_ROWS, LANES, padded_rows
from repro.kernels.vote import majority_from_counts
from repro.parallel.fanin import fanin_vote_counts, fanin_weighted_sum

Pytree = Any

# Aggregation rules; "mean" is the legacy bit-exact weighted mean, the rest
# are the Byzantine-robust statistics (see module docstring).
AGG_RULES = ("mean", "majority", "trimmed_mean", "median")


def weighted_median(stack: np.ndarray, weights: np.ndarray) -> np.ndarray:
    """Coordinate-wise weighted median along axis 0 (lower median: the
    first sorted value whose cumulative weight reaches half the total)."""
    order = np.argsort(stack, axis=0, kind="stable")
    svals = np.take_along_axis(stack, order, axis=0)
    sw = np.take_along_axis(
        np.broadcast_to(
            weights.reshape((-1,) + (1,) * (stack.ndim - 1)), stack.shape
        ), order, axis=0,
    )
    cum = np.cumsum(sw, axis=0)
    idx = np.argmax(cum >= cum[-1] / 2.0, axis=0)
    return np.take_along_axis(svals, idx[None], axis=0)[0]


def trimmed_mean(stack: np.ndarray, weights: np.ndarray,
                 trim_frac: float) -> np.ndarray:
    """Coordinate-wise trimmed weighted mean along axis 0: sort values,
    drop ⌊trim_frac·C⌋ per side (clamped so at least one survives), then
    the weighted mean of the survivors — the classic defense against a
    tail-dwelling minority."""
    c = stack.shape[0]
    k = min(int(trim_frac * c), (c - 1) // 2)
    order = np.argsort(stack, axis=0, kind="stable")
    svals = np.take_along_axis(stack, order, axis=0)
    sw = np.take_along_axis(
        np.broadcast_to(
            weights.reshape((-1,) + (1,) * (stack.ndim - 1)), stack.shape
        ), order, axis=0,
    )
    if k:
        svals, sw = svals[k:c - k], sw[k:c - k]
    return (svals * sw).sum(axis=0) / sw.sum(axis=0)


def bucket_for(c: int, chunk_c: int) -> int:
    """Pad a partial chunk of c clients up to the trace bucket: the smallest
    power of two ≥ c, capped at ``chunk_c`` (full chunks hit chunk_c; the
    cap also holds for non-power-of-two chunk sizes)."""
    if c >= chunk_c:
        return chunk_c
    b = 1
    while b < c:
        b <<= 1
    return min(b, chunk_c)


@dataclasses.dataclass
class _Group:
    """Pending rows of one (leaf, scale-segment) stacked kernel input."""

    nbytes: int                  # real packed bytes per client segment
    n_elements: int              # logical elements per segment
    rows: int                    # padded byte-rows R (multiple of BLOCK_ROWS)
    views: list = dataclasses.field(default_factory=list)   # np byte views
    coeffs: list = dataclasses.field(default_factory=list)  # weight · scale
    partial: Any = None          # running fp32 flat sum (jax array)
    # majority-rule state: running (2, 4R·LANES) ±1 vote masses, plus every
    # client's (scale, weight) sample for the finalize-time robust scale
    # (persists across flushes — the median needs the full sample).
    counts: Any = None
    scale_samples: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _LeafPlan:
    """How one record path aggregates: fused kernel groups or dense fallback."""

    fused: bool
    shape: tuple = ()
    dtype: str = "float32"
    n_segments: int = 1
    scale_size: int = 1


class Aggregator:
    """Streaming |D_k|-weighted mean of wire-encoded client updates.

    Usage::

        agg = Aggregator(chunk_c=16)
        for blob, n_samples in arrivals:
            agg.add(blob, weight=n_samples)
        global_params = agg.finalize()

    One instance aggregates one round/buffer at a time, but is REUSABLE:
    ``finalize(reset=True)`` (or an explicit ``reset()``) clears the
    accumulated state while KEEPING the leaf plans and the stacked staging
    buffers, so a long-lived server instance — e.g. the buffered-async
    server, which aggregates every K arrivals — never rebuilds its
    buffers between mixes.
    """

    def __init__(self, chunk_c: int = 16, *, mesh=None,
                 block_rows: int = BLOCK_ROWS, interpret: bool | None = None,
                 rule: str = "mean", trim_frac: float = 0.2):
        if chunk_c < 1:
            raise ValueError(f"chunk_c must be ≥ 1, got {chunk_c}")
        if rule not in AGG_RULES:
            raise ValueError(f"rule must be one of {AGG_RULES}, got {rule!r}")
        if not 0.0 <= trim_frac < 0.5:
            raise ValueError(f"trim_frac must be in [0, 0.5), got {trim_frac}")
        self.chunk_c = chunk_c
        self.mesh = mesh
        self.block_rows = block_rows
        self.interpret = interpret
        self.rule = rule
        self.trim_frac = trim_frac
        # exact order statistics need every client's dense leaf — these two
        # rules bypass the fused plan entirely (O(C·model) memory).
        self._dense_rule = rule in ("trimmed_mean", "median")
        self._client_dense: dict[str, list] = {}  # path → [(weight, f32 leaf)]
        self._paths: list[str] | None = None   # record order of client 0
        self._plans: dict[str, _LeafPlan] = {}
        self._groups: dict[tuple[str, int], _Group] = {}
        self._fallback: dict[str, np.ndarray] = {}
        # paths whose fallback accumulator received adds SINCE THE LAST
        # reset — a long-lived aggregator keeps (zeroed) accumulators from
        # past mixed-codec mixes, and finalize must not fold those into
        # later pure-ternary mixes.
        self._fallback_touched: set[str] = set()
        self._fallback_dtype: dict[str, Any] = {}
        self._buffers: dict[tuple[int, int], np.ndarray] = {}  # reusable
        self._pending = 0
        self._n_clients = 0
        self._total_weight = 0.0
        self.peak_intermediate_bytes = 0
        # drop-path ledger: updates the server PAID wire bytes for but chose
        # not to fold in (staleness cap, policy drops). Cumulative across
        # resets — it is run-level waste accounting, not per-mix state.
        self.dropped_updates = 0
        self.dropped_bytes = 0
        # quarantine ledger: updates the defense gate refused — received and
        # paid for, but content-poisoned. Third ledger bucket; cumulative
        # across resets like the drop counters.
        self.quarantined_updates = 0
        self.quarantined_bytes = 0

    # -- ingest ------------------------------------------------------------

    def note_dropped(self, nbytes: int) -> None:
        """Record one received-but-discarded update (e.g. past the async
        staleness cap): its wire bytes were spent, its weights never enter
        the mean. Feeds the scenario telemetry's waste accounting."""
        self.dropped_updates += 1
        self.dropped_bytes += int(nbytes)

    def note_quarantined(self, nbytes: int) -> None:
        """Record one gate-refused update: wire bytes spent, content judged
        poisoned, weights never enter the aggregate. Extends the ledger
        invariant to shipped == ingested + dropped + quarantined."""
        self.quarantined_updates += 1
        self.quarantined_bytes += int(nbytes)

    def add(self, blob: bytes, weight: float) -> None:
        """Decode one client's wire buffer (zero-copy) and buffer/accumulate
        it; a full chunk triggers one fused kernel launch per leaf group."""
        if weight < 0:
            raise ValueError(f"client weight must be ≥ 0, got {weight}")
        # weight 0 (an empty data shard) is tolerated exactly like the
        # reference: the client rides along contributing nothing.
        pairs = decode_update_leaves(blob, zero_copy=True)
        paths = [p for p, _ in pairs]
        if len(set(paths)) != len(paths):
            # decode_update would last-wins this; an accumulator would
            # double-count it — refuse loudly (it is a malformed update).
            from repro.comm.wire import WireError

            raise WireError("duplicate record paths in client update")
        if self._paths is None:
            self._paths = paths
            for path, leaf in pairs:
                self._plan_leaf(path, leaf)
        elif paths != self._paths:
            raise ValueError(
                "client update structure changed mid-aggregation: "
                f"{len(paths)} records vs {len(self._paths)}"
            )
        for path, leaf in pairs:
            self._add_leaf(path, leaf, float(weight))
        self._total_weight += float(weight)
        self._n_clients += 1
        self._pending += 1
        if self._pending >= self.chunk_c:
            self._flush()

    def _plan_leaf(self, path: str, leaf) -> None:
        if self._dense_rule:
            # trimmed_mean / median: every leaf keeps per-client dense
            # copies; the fused plan never engages.
            self._plans[path] = _LeafPlan(fused=False)
            return
        if isinstance(leaf, TernaryTensor):
            shape = tuple(int(s) for s in leaf.shape)
            n = leaf.n_elements
            scale = np.asarray(leaf.w_q)
            trailing_ok = scale.ndim <= 1 or all(s == 1 for s in scale.shape[1:])
            if scale.size == 1:
                segs = 1
            elif (trailing_ok and shape and scale.size == shape[0]
                  and n % scale.size == 0 and (n // scale.size) % 4 == 0):
                segs = scale.size   # per-leading-dim scales, byte-aligned
            else:
                segs = 0            # odd scale layout → dense fallback
            if segs:
                self._plans[path] = _LeafPlan(
                    fused=True, shape=shape, dtype=leaf.dtype,
                    n_segments=segs, scale_size=scale.size,
                )
                seg_elems = n // segs
                seg_bytes = (seg_elems + 3) // 4 if segs == 1 else seg_elems // 4
                rows = padded_rows(seg_bytes, self.block_rows)
                for s in range(segs):
                    self._groups[(path, s)] = _Group(
                        nbytes=seg_bytes, n_elements=seg_elems, rows=rows
                    )
                return
        self._plans[path] = _LeafPlan(fused=False)

    def _add_leaf(self, path: str, leaf, weight: float) -> None:
        plan = self._plans[path]
        if plan.fused and not isinstance(leaf, TernaryTensor):
            # mixed-codec round: this client shipped a different wire kind
            # (top-k, downcast, raw) for a path planned fused off an earlier
            # ternary client. The weighted MEAN is additive, so the leaf
            # detours through the dense fallback accumulator and finalize
            # sums the fused partial with it; the order-statistic rules have
            # no such decomposition — refuse loudly rather than vote wrong.
            if self.rule != "mean":
                raise ValueError(
                    f"leaf {path!r}: mixed wire kinds under rule "
                    f"{self.rule!r} (only 'mean' aggregates mixed-codec "
                    "rounds; pin one codec per round for robust rules)"
                )
            self._add_fallback(path, leaf, weight)
            return
        if plan.fused:
            t: TernaryTensor = leaf
            if tuple(int(s) for s in t.shape) != plan.shape:
                raise ValueError(f"leaf {path!r} changed shape mid-aggregation")
            packed = np.asarray(t.packed).reshape(-1)
            scale = np.asarray(t.w_q, np.float64).reshape(-1)
            if scale.size != plan.scale_size:
                raise ValueError(f"leaf {path!r} changed scale layout")
            for s in range(plan.n_segments):
                g = self._groups[(path, s)]
                g.views.append(packed[s * g.nbytes:(s + 1) * g.nbytes])
                if self.rule == "majority":
                    # votes are scale-free: the kernel coefficient is the
                    # raw weight; the scale joins at finalize as a weighted
                    # median over these samples.
                    g.coeffs.append(weight)
                    g.scale_samples.append(
                        (float(scale[s if scale.size > 1 else 0]), weight)
                    )
                else:
                    g.coeffs.append(weight * float(scale[s if scale.size > 1 else 0]))
        else:
            self._add_fallback(path, leaf, weight)

    def _add_fallback(self, path: str, leaf, weight: float) -> None:
        dense = np.asarray(decode_wire_leaf(leaf))
        if path not in self._fallback_dtype:
            # reference promotion: float leaves keep their dtype under a
            # python-float weight, int leaves promote to float32.
            self._fallback_dtype[path] = (
                dense.dtype if jnp.issubdtype(dense.dtype, jnp.floating)
                else np.dtype(np.float32)
            )
        if self.rule == "mean":
            if path not in self._fallback:
                self._fallback[path] = np.zeros(dense.shape, np.float32)
            self._fallback[path] += weight * dense.astype(np.float32)
            self._fallback_touched.add(path)
        else:
            # robust order statistics need the whole per-client sample.
            self._client_dense.setdefault(path, []).append(
                (weight, dense.astype(np.float32))
            )

    # -- kernel launches ---------------------------------------------------

    def _buffer(self, c_pad: int, rows: int) -> np.ndarray:
        buf = self._buffers.get((c_pad, rows))
        if buf is None:
            buf = np.empty((c_pad, rows * LANES), np.uint8)
            self._buffers[(c_pad, rows)] = buf
            live = sum(b.nbytes for b in self._buffers.values())
            self.peak_intermediate_bytes = max(self.peak_intermediate_bytes, live)
        return buf

    def _flush(self) -> None:
        for g in self._groups.values():
            self._flush_group(g)
        self._pending = 0

    def _flush_group(self, g: _Group) -> None:
        c = len(g.views)
        if c == 0:
            return
        c_pad = bucket_for(c, self.chunk_c)
        buf = self._buffer(c_pad, g.rows)
        for i, v in enumerate(g.views):
            buf[i, :g.nbytes] = v
            buf[i, g.nbytes:] = 0
        buf[c:] = 0
        coeffs = np.zeros((c_pad,), np.float32)
        coeffs[:c] = g.coeffs
        stacked = buf.reshape(c_pad, g.rows, LANES)
        if self.rule == "majority":
            # a zero-padding BYTE is four code-0 slots (−1 votes); the
            # zeroed coefficient rows cancel them exactly as in the mean
            # path, and real clients' tail padding lands past n_elements.
            out = fanin_vote_counts(
                stacked, coeffs, mesh=self.mesh,
                block_rows=self.block_rows, interpret=self.interpret,
            )
            out.block_until_ready()
            g.counts = out if g.counts is None else g.counts + out
        else:
            out = fanin_weighted_sum(
                stacked, coeffs, mesh=self.mesh, block_rows=self.block_rows,
                interpret=self.interpret,
            )
            # the device_put of the staging buffer may be ZERO-COPY (CPU
            # backend aliases aligned numpy memory) and the launch is async
            # — block before the buffer is refilled for the next
            # group/chunk, or the in-flight kernel would read torn bytes.
            out.block_until_ready()
            g.partial = out if g.partial is None else g.partial + out
        g.views.clear()
        g.coeffs.clear()

    # -- result ------------------------------------------------------------

    @property
    def n_clients(self) -> int:
        """Client updates added since construction / the last reset."""
        return self._n_clients

    def reset(self) -> None:
        """Clear the accumulated state for the next aggregation while
        KEEPING the record plans and the reusable staging buffers — the
        long-lived-server path (async ``buffer_k`` mixes) pays the buffer
        allocation once, not every K arrivals."""
        for g in self._groups.values():
            g.views.clear()
            g.coeffs.clear()
            g.partial = None
            g.counts = None
            g.scale_samples.clear()
        for acc in self._fallback.values():
            acc.fill(0.0)
        self._fallback_touched.clear()
        for samples in self._client_dense.values():
            samples.clear()
        self._pending = 0
        self._n_clients = 0
        self._total_weight = 0.0

    def finalize(self, *, reset: bool = False) -> Pytree:
        """Flush pending rows and return the weighted-mean pytree
        (Algorithm 2's Σ |D_k|/Σ|D_k| · dequant(payload_k)). With
        ``reset=True`` the instance is immediately reusable for the next
        round (plans + staging buffers survive)."""
        if self._n_clients == 0:
            raise ValueError("Aggregator.finalize: no client updates were added")
        if self._total_weight <= 0:
            raise ValueError("Aggregator.finalize: total client weight is zero")
        self._flush()
        inv = 1.0 / self._total_weight
        pairs = []
        for path in self._paths:
            plan = self._plans[path]
            if plan.fused and self.rule == "majority":
                parts = []
                for s in range(plan.n_segments):
                    g = self._groups[(path, s)]
                    counts = np.asarray(g.counts)[:, : g.n_elements]
                    votes = majority_from_counts(counts, self._total_weight)
                    vals = np.array([v for v, _ in g.scale_samples], np.float32)
                    ws = np.array([w for _, w in g.scale_samples], np.float32)
                    robust_scale = weighted_median(vals, ws)
                    parts.append(votes.astype(np.float32) * np.float32(robust_scale))
                flat = parts[0] if len(parts) == 1 else np.concatenate(parts)
                leaf = jnp.asarray(flat.reshape(plan.shape)).astype(plan.dtype)
            elif plan.fused:
                parts = []
                for s in range(plan.n_segments):
                    g = self._groups[(path, s)]
                    # a mixed-codec round may leave a fused group empty
                    # (every client detoured to the fallback): zero partial.
                    parts.append(
                        g.partial[: g.n_elements] if g.partial is not None
                        else jnp.zeros((g.n_elements,), jnp.float32)
                    )
                flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
                if path in self._fallback_touched:
                    # mixed-codec detours accumulated Σ w·dense here; the
                    # weighted mean is additive across the two routes.
                    flat = flat + jnp.asarray(self._fallback[path].reshape(-1))
                leaf = (flat * inv).reshape(plan.shape).astype(plan.dtype)
            elif self.rule == "mean":
                acc = self._fallback[path] * np.float32(inv)
                leaf = jnp.asarray(acc).astype(self._fallback_dtype[path])
            else:
                samples = self._client_dense[path]
                stack = np.stack([d for _, d in samples])
                ws = np.array([w for w, _ in samples], np.float32)
                if self.rule == "trimmed_mean":
                    acc = trimmed_mean(stack, ws, self.trim_frac)
                else:  # "median", and the majority rule's dense fallback
                    acc = weighted_median(stack, ws)
                leaf = jnp.asarray(acc).astype(self._fallback_dtype[path])
            pairs.append((path, leaf))
        out = tree_from_records(pairs)
        if reset:
            self.reset()
        return out
