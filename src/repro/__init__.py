"""repro — production-grade JAX framework reproducing and extending
"Ternary Compression for Communication-Efficient Federated Learning"
(Xu, Du, Cheng, He, Jin — IEEE TNNLS 2020).

Public surface:
    repro.core      — FTTQ quantizer, ternary codec, T-FedAvg protocol
    repro.models    — architecture zoo (dense / MoE / SSM / hybrid / VLM / audio)
    repro.configs   — named architecture configs + input-shape suites
    repro.parallel  — sharding rules + ternary-compressed collectives
    repro.launch    — production mesh, dry-run, train/serve drivers
"""

__version__ = "1.0.0"
