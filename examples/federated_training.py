"""The paper's core experiment (Tables II/IV): FedAvg vs T-FedAvg on the
synthetic MNIST stand-in, with accuracy + communication measured from the
real serialized wire buffers, plus simulated transfer times from the
channel model. ``--mode async`` runs the buffered-asynchronous server.

    PYTHONPATH=src python examples/federated_training.py [--rounds 10]
    PYTHONPATH=src python examples/federated_training.py --noniid 2
    PYTHONPATH=src python examples/federated_training.py --mode async --buffer-k 3
    PYTHONPATH=src python examples/federated_training.py --deadline 0.3
    PYTHONPATH=src python examples/federated_training.py --mode async \\
        --availability diurnal --loss-rate 0.01 --max-staleness 4
"""

import argparse

import jax
import jax.numpy as jnp

from repro.comm import ChannelConfig
from repro.core import FTTQConfig
from repro.data import (
    partition_iid, partition_noniid, synthetic_classification,
)
from repro.fed import AvailabilityConfig, FedConfig, run_federated
from repro.models.paper_models import init_mlp_mnist, mlp_mnist
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--noniid", type=int, default=0,
                    help="classes per client (0 = IID)")
    ap.add_argument("--mode", choices=("sync", "async"), default="sync")
    ap.add_argument("--buffer-k", type=int, default=4,
                    help="async: aggregate every K arrivals")
    ap.add_argument("--bandwidth-mbps", type=float, default=8.0,
                    help="median link bandwidth, megabits/s")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="sync-only round deadline in seconds (0 = none); "
                         "slow clients become emergent stragglers. The async "
                         "server has no barrier, so no deadline applies.")
    # --- scenario layer ---------------------------------------------------
    ap.add_argument("--availability", choices=("always_on", "diurnal", "trace"),
                    default="always_on",
                    help="client availability trace (diurnal = sinusoidal "
                         "timezone cohorts, trace = seeded on/off sessions)")
    ap.add_argument("--loss-rate", type=float, default=0.0,
                    help="per-chunk packet loss probability; lost chunks "
                         "retransmit with timeout backoff and are metered")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async: drop updates staler than this (0 = no cap)")
    ap.add_argument("--adaptive-buffer", action="store_true",
                    help="async: auto-tune buffer_k from the arrival rate")
    args = ap.parse_args()
    if args.mode == "async" and args.deadline > 0:
        ap.error("--deadline applies to --mode sync only "
                 "(the async server never blocks on a round barrier)")

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 4000, 10, 784, noise=3.0, n_test=1000)
    if args.noniid:
        clients = partition_noniid(x, y, args.clients, args.noniid)
    else:
        clients = partition_iid(x, y, args.clients)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        logp = jax.nn.log_softmax(logits, -1)
        return float(acc), float(-jnp.mean(
            jnp.take_along_axis(logp, yt_j[:, None], -1)))

    chan = ChannelConfig(
        mean_bandwidth_bytes_s=args.bandwidth_mbps * 1e6 / 8,
        deadline_s=args.deadline if args.deadline > 0 else float("inf"),
        loss_rate=args.loss_rate,
    )
    avail = AvailabilityConfig(kind=args.availability)
    print(f"{'algo':10s} {'acc':>7s} {'upload':>10s} {'download':>10s} "
          f"{'sim-time':>9s} {'p95-xfer':>9s}")
    results = {}
    for algo in ("fedavg", "tfedavg"):
        cfg = FedConfig(algorithm=algo, mode=args.mode,
                        participation=args.participation,
                        local_epochs=2, batch_size=32, rounds=args.rounds,
                        fttq=FTTQConfig(), channel=chan,
                        buffer_k=args.buffer_k, availability=avail,
                        max_staleness=args.max_staleness,
                        adaptive_buffer=args.adaptive_buffer)
        res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                            eval_fn, eval_every=args.rounds)
        results[algo] = res
        print(f"{algo:10s} {res.accuracy[-1]:7.3f} "
              f"{res.upload_bytes / 1e6:9.2f}M {res.download_bytes / 1e6:9.2f}M "
              f"{res.total_time_s:8.2f}s "
              f"{res.transfer_summary['p95_seconds'] * 1e3:7.1f}ms")
        if res.dropped_per_round and sum(res.dropped_per_round):
            print(f"{'':10s} stragglers dropped per round: "
                  f"{res.dropped_per_round}")
        tel = res.telemetry
        if tel.get("retrans_bytes") or tel.get("dropped_updates"):
            # sync drops stragglers at the deadline; async drops over-stale
            # arrivals whose bytes were already paid for.
            what = "stale" if args.mode == "async" else "straggler"
            print(f"{'':10s} scenario: retrans "
                  f"{tel.get('retrans_bytes', 0) / 1e3:.1f}kB "
                  f"(goodput {tel.get('goodput_fraction', 1.0):.3f}), "
                  f"{what}-dropped {tel.get('dropped_updates', 0)} "
                  f"({tel.get('dropped_update_bytes', 0) / 1e3:.1f}kB wasted)")
        if args.adaptive_buffer and tel.get("buffer_k_per_agg"):
            print(f"{'':10s} buffer_k trajectory: {tel['buffer_k_per_agg']}")
    r = results["fedavg"].upload_bytes / results["tfedavg"].upload_bytes
    t = results["fedavg"].total_time_s / max(results["tfedavg"].total_time_s, 1e-9)
    print(f"\ncommunication compression: {r:.1f}×  wall-clock speedup: {t:.1f}×  "
          f"(paper Table IV reports ~16×; biases stay fp32, framing adds bytes)")


if __name__ == "__main__":
    main()
