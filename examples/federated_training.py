"""The paper's core experiment (Tables II/IV): FedAvg vs T-FedAvg on the
synthetic MNIST stand-in, with accuracy + measured communication.

    PYTHONPATH=src python examples/federated_training.py [--rounds 10]
    PYTHONPATH=src python examples/federated_training.py --noniid 2
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import FTTQConfig
from repro.data import (
    partition_iid, partition_noniid, synthetic_classification,
)
from repro.fed import FedConfig, run_federated
from repro.models.paper_models import init_mlp_mnist, mlp_mnist
from repro.optim import adam


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--noniid", type=int, default=0,
                    help="classes per client (0 = IID)")
    ap.add_argument("--straggler-drop", type=float, default=0.0)
    args = ap.parse_args()

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 4000, 10, 784, noise=3.0, n_test=1000)
    if args.noniid:
        clients = partition_noniid(x, y, args.clients, args.noniid)
    else:
        clients = partition_iid(x, y, args.clients)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        logp = jax.nn.log_softmax(logits, -1)
        return float(acc), float(-jnp.mean(
            jnp.take_along_axis(logp, yt_j[:, None], -1)))

    print(f"{'algo':10s} {'acc':>7s} {'upload':>10s} {'download':>10s}")
    results = {}
    for algo in ("fedavg", "tfedavg"):
        cfg = FedConfig(algorithm=algo, participation=args.participation,
                        local_epochs=2, batch_size=32, rounds=args.rounds,
                        fttq=FTTQConfig(),
                        straggler_drop_prob=args.straggler_drop)
        res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                            eval_fn, eval_every=args.rounds)
        results[algo] = res
        print(f"{algo:10s} {res.accuracy[-1]:7.3f} "
              f"{res.upload_bytes / 1e6:9.2f}M {res.download_bytes / 1e6:9.2f}M")
    r = results["fedavg"].upload_bytes / results["tfedavg"].upload_bytes
    print(f"\ncommunication compression: {r:.1f}×  "
          f"(paper Table IV reports ~16×; biases stay fp32)")


if __name__ == "__main__":
    main()
