"""End-to-end driver (deliverable b): FTTQ-QAT pretraining of a ~100M LM for
a few hundred steps — the paper's technique as a first-class feature of a
modern LM training stack (checkpointing included).

Default runs a fast 10M-param config so CPU finishes in minutes; pass
--full for the true ~100M × 300-step run.

    PYTHONPATH=src python examples/ternary_lm_pretrain.py [--full]
"""

import argparse
import subprocess
import sys
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params × 300 steps (hours on CPU; minutes on TPU)")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

preset = "100m" if args.full else "10m"
steps = args.steps or (300 if args.full else 60)
ckpt = os.path.join(REPO, "artifacts", "ckpt_lm")

cmd = [sys.executable, "-m", "repro.launch.train",
       "--preset", preset, "--steps", str(steps),
       "--batch", "8", "--seq", "128",
       "--ckpt-dir", ckpt, "--ckpt-every", "50", "--resume"]
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(REPO, "src")
print("running:", " ".join(cmd))
sys.exit(subprocess.call(cmd, env=env))
