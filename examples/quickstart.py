"""Quickstart: the paper's pipeline end-to-end in ~30 seconds on CPU.

1. FTTQ-quantize a weight matrix (eqs. 6-12) and inspect the wire format.
2. Pack to 2 bits, run the ternary-weight matmul kernel, check vs fp32.
3. One T-FedAvg round (3 clients) with measured communication bytes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FTTQConfig, encode_ternary, fttq_quantize,
)
from repro.core import fttq as F
from repro.core.tfedavg import (
    TernaryUpdate, client_update_payload, server_aggregate, server_requantize,
)
from repro.kernels import ops, ref

cfg = FTTQConfig()

# --- 1. quantize one layer ---------------------------------------------
key = jax.random.PRNGKey(0)
theta = jax.random.normal(key, (512, 256)) * 0.05
wq = F.init_wq(theta, cfg)
theta_t = fttq_quantize(theta, wq, cfg.t_k)
ts = F.scale_layer(theta)
i_t = F.ternarize(ts, F.fttq_threshold(ts, cfg.t_k))
wire = encode_ternary(i_t, wq)
print(f"layer: {theta.size} weights  fp32={theta.size * 4} B  "
      f"ternary wire={wire.nbytes_wire()} B  "
      f"({theta.size * 4 / wire.nbytes_wire():.1f}× smaller)")
print(f"w_q = {float(wq):.4f}  sparsity = "
      f"{float(jnp.mean(i_t == 0)):.2%}  "
      f"L2 err = {float(jnp.linalg.norm(theta - theta_t) / jnp.linalg.norm(theta)):.3f}")

# --- 2. ternary matmul kernel ------------------------------------------
x = jax.random.normal(jax.random.PRNGKey(1), (32, 512))
packed = ops.pack2bit(i_t.astype(jnp.int8))
y_kernel = ops.ternary_matmul(x, packed, wq)
y_ref = x @ theta_t
rel = float(jnp.linalg.norm(y_kernel - y_ref) / jnp.linalg.norm(y_ref))
print(f"ternary matmul kernel vs dequantized fp32: rel err {rel:.2e}")

# --- 3. one T-FedAvg round ----------------------------------------------
params = {"fc": {"w": theta, "bias": jnp.zeros((256,))}}
wq_tree = F.init_wq_tree(params, cfg)
updates = []
for cid in range(3):
    local = jax.tree_util.tree_map(
        lambda t: t + 0.01 * jax.random.normal(jax.random.PRNGKey(cid), t.shape),
        params)
    payload = client_update_payload(local, wq_tree, cfg)
    u = TernaryUpdate(payload=payload, n_samples=100 * (cid + 1), client_id=cid)
    updates.append(u)
    print(f"client {cid}: upstream {u.nbytes_upstream()} B")
global_params = server_aggregate(updates)
wire_down = server_requantize(global_params, cfg)
print("server aggregated; downstream re-quantized (Algorithm 2 complete)")
