"""Serve a ternary-deployed LM with batched requests (prefill + decode) —
the paper's edge-inference story (2-bit weights, §III.B) as a serving stack.

    PYTHONPATH=src python examples/serve_ternary.py [--arch yi-9b]
"""

import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="olmo-1b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.serve",
       "--arch", args.arch, "--reduced", "--ternary",
       "--batch", str(args.batch), "--gen", str(args.gen)]
env = dict(os.environ)
env["PYTHONPATH"] = os.path.join(REPO, "src")
print("running:", " ".join(cmd))
sys.exit(subprocess.call(cmd, env=env))
