"""CI bench regression gate.

Compares the smoke-mode bench records the CI job just produced
(``BENCH_aggregate.json`` / ``BENCH_encode.json`` /
``BENCH_hierarchy.json`` / ``BENCH_serve.json`` / ``BENCH_chaos.json`` /
``BENCH_robust.json`` in
the repo root) against the committed baselines in ``benchmarks/baselines/``
and fails on a >THRESHOLD× slowdown of any timing metric (keys ending in
``_s``), or on a metric that silently disappeared from the record.

    PYTHONPATH=src python -m benchmarks.run \
        --only aggregate,encode,hierarchy,serve,chaos --smoke
    python benchmarks/check_regression.py              # gate (exit 1 = fail)
    python benchmarks/check_regression.py --update     # re-baseline

CI-runner noise swamps microsecond effects, so the gate is deliberately
coarse: 2× on wall-clock smoke timings catches real structural regressions
(a kernel falling back to the reference path, an accidental O(C) retrace)
while shrugging off runner jitter. ``BENCH_*.json`` records in the repo
root remain the human-readable perf trajectory; the ``baselines/`` copies
exist only for this gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_DIR = pathlib.Path(__file__).resolve().parent / "baselines"
RECORDS = ("BENCH_aggregate.json", "BENCH_encode.json",
           "BENCH_hierarchy.json", "BENCH_serve.json", "BENCH_chaos.json",
           "BENCH_robust.json", "BENCH_adaptive.json")
THRESHOLD = 2.0
# Sub-5ms timings are runner-speed lottery (a dev-machine baseline vs a CI
# runner can legitimately differ >2x at the 100µs scale); the structural
# regressions this gate exists for — a kernel falling back to the
# reference path, an accidental retrace — all show up in the 10ms–10s
# metrics, so only those are gated.
MIN_SECONDS = 5e-3


def _is_seconds_key(k: str) -> bool:
    # '..._s' names a wall-clock duration; '..._per_s' / '..._gb_s' are
    # throughputs (higher = better) and must NOT be gated as slowdowns.
    return k.endswith("_s") and not (k.endswith("per_s") or k.endswith("gb_s"))


def _timing_leaves(obj, prefix=""):
    """Flatten {path: seconds} for every numeric leaf whose key ends '_s'."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                out.update(_timing_leaves(v, p))
            elif isinstance(v, (int, float)) and _is_seconds_key(str(k)):
                out[p] = float(v)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(_timing_leaves(v, f"{prefix}[{i}]"))
    return out


def _adaptive_gate(record: dict) -> list[str]:
    """ISSUE acceptance gate on ``BENCH_adaptive.json``: the adaptive
    controller must reach the target accuracy at equal or fewer upstream
    bytes than static ternary. Checked from the record (not just inside
    the bench) so a silently-edited JSON cannot pass."""
    try:
        a = record["adaptive"]["bytes_to_target"]
        s = record["static"]["bytes_to_target"]
    except (KeyError, TypeError):
        return ["BENCH_adaptive.json: bytes_to_target fields missing"]
    print(f"[gate] BENCH_adaptive.json: adaptive {a} B <= static {s} B "
          f"to target acc {record.get('target_accuracy')} "
          f"({'ok' if a <= s else 'REGRESSION'})")
    if a > s:
        return [f"BENCH_adaptive.json: adaptive needed MORE upstream bytes "
                f"to target accuracy ({a} > {s})"]
    return []


def check(threshold: float = THRESHOLD) -> int:
    failures = []
    compared = 0
    for name in RECORDS:
        cur_path = ROOT / name
        base_path = BASELINE_DIR / name
        if not base_path.exists():
            print(f"[gate] no baseline for {name} — run with --update first")
            return 1
        if not cur_path.exists():
            failures.append(f"{name}: record missing (bench did not run?)")
            continue
        cur_record = json.loads(cur_path.read_text())
        if name == "BENCH_adaptive.json":
            failures.extend(_adaptive_gate(cur_record))
        base = _timing_leaves(json.loads(base_path.read_text()))
        cur = _timing_leaves(cur_record)
        for key, b in sorted(base.items()):
            if b < MIN_SECONDS:
                continue
            if key not in cur:
                failures.append(f"{name}:{key}: metric vanished from record")
                continue
            ratio = cur[key] / b
            compared += 1
            marker = "REGRESSION" if ratio > threshold else "ok"
            print(f"[gate] {name}:{key}: {b:.4g}s -> {cur[key]:.4g}s "
                  f"({ratio:.2f}x) {marker}")
            if ratio > threshold:
                failures.append(
                    f"{name}:{key}: {ratio:.2f}x slower "
                    f"({b:.4g}s -> {cur[key]:.4g}s, threshold {threshold}x)"
                )
    if failures:
        print(f"\n[gate] FAIL — {len(failures)} regression(s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\n[gate] OK — {compared} timing metrics within {threshold}x "
          "of baseline")
    return 0


def update() -> int:
    BASELINE_DIR.mkdir(exist_ok=True)
    for name in RECORDS:
        cur = ROOT / name
        if not cur.exists():
            print(f"[gate] cannot re-baseline: {cur} missing")
            return 1
        shutil.copyfile(cur, BASELINE_DIR / name)
        print(f"[gate] baselined {name}")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="copy the current records over the baselines")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args()
    sys.exit(update() if args.update else check(args.threshold))


if __name__ == "__main__":
    main()
