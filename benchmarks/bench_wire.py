"""Wire-codec benchmarks: encode/decode throughput and bytes-per-parameter
vs the fp32 baseline, on real model payloads — plus the SCENARIO section,
which runs the same T-FedAvg config under realistic fleet conditions.

Rows (name, us_per_call, derived):
  wire_encode_<model>   derived = encode throughput, MB/s
  wire_decode_<model>   derived = decode throughput, MB/s
  wire_bpp_<model>      derived = serialized ternary bytes per parameter
  wire_ratio_<model>    derived = fp32 serialized bytes / ternary bytes
  codec_encode_<name>   per-registry-codec serialize throughput, MB/s
  codec_decode_<name>   per-registry-codec decode+decompress throughput, MB/s
  codec_bpp_<name>      per-registry-codec serialized bytes per parameter
  scenario_<s>_acc      final accuracy under scenario s (async T-FedAvg)
  scenario_<s>_upMB     measured upload megabytes under scenario s
  scenario_<s>_time     simulated seconds under scenario s
  scenario_<s>_goodput  goodput / (goodput + retransmitted) wire fraction
"""

from __future__ import annotations

import time

import jax

from repro.comm.wire import decode_update, encode_update
from repro.core import CodecSpec, FTTQConfig, compress_pytree, decompress_pytree
from repro.core.tfedavg import server_requantize
from repro.models.paper_models import (
    init_mlp_mnist, init_resnet_cifar,
)

FTTQ = FTTQConfig()

# one CodecSpec per registry codec, applied tree-wide (weights AND residual
# leaves) so codec_bpp_* is the intrinsic cost of each wire format
CODEC_SPECS = {
    "none": CodecSpec(kind="none", residual="none"),
    "ternary": CodecSpec(kind="ternary", residual="none", fttq=FTTQ),
    "fp16": CodecSpec(kind="fp16", residual="fp16"),
    "bf16": CodecSpec(kind="bf16", residual="bf16"),
    "topk10": CodecSpec(kind="topk", residual="topk", topk_fraction=0.1),
}


def _models():
    out = [
        ("mlp", init_mlp_mnist(jax.random.PRNGKey(0))),
        ("resnet", init_resnet_cifar(jax.random.PRNGKey(1))),
    ]
    try:
        from repro.configs import get_reduced
        from repro.models.transformer import init_params

        cfg = get_reduced("olmo-1b")
        out.append(("olmo_reduced", init_params(cfg, jax.random.PRNGKey(2))))
    except Exception:
        pass  # transformer stack unavailable: bench the paper models only
    return out


def _timed(fn, *args, repeats: int = 5, warmup: int = 2):
    """Warmed + synchronized: decode returns jax arrays whose computation is
    async-dispatched — ``block_until_ready`` inside the timed region makes
    the MB/s figures measure compute, not dispatch."""
    from benchmarks.common import SMOKE

    if SMOKE:
        repeats, warmup = 1, 1
    for _ in range(max(warmup, 1)):  # traces/compiles + device transfers
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeats


def wire_codec():
    rows = []
    for name, params in _models():
        n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
        wire_tree = server_requantize(params, FTTQ)

        blob, dt_e = _timed(encode_update, wire_tree)
        rows.append((f"wire_encode_{name}", round(dt_e * 1e6, 1),
                     round(len(blob) / dt_e / 1e6, 1)))

        _, dt_d = _timed(decode_update, blob)
        rows.append((f"wire_decode_{name}", round(dt_d * 1e6, 1),
                     round(len(blob) / dt_d / 1e6, 1)))

        fp_blob = encode_update(params)
        rows.append((f"wire_bpp_{name}", 0.0, round(len(blob) / n_params, 4)))
        rows.append((f"wire_ratio_{name}", 0.0,
                     round(len(fp_blob) / len(blob), 2)))
    return rows


def codec_table():
    """Per-registry-codec throughput and bytes-per-param on the paper MLP."""
    params = init_mlp_mnist(jax.random.PRNGKey(3))
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    rows = []
    for name, spec in CODEC_SPECS.items():
        wire_tree, _ = compress_pytree(params, spec)

        def enc(tree=wire_tree):
            return encode_update(tree)

        blob, dt_e = _timed(enc)
        rows.append((f"codec_encode_{name}", round(dt_e * 1e6, 1),
                     round(len(blob) / dt_e / 1e6, 1)))

        def dec(b=blob):
            return decompress_pytree(decode_update(b))

        _, dt_d = _timed(dec)
        rows.append((f"codec_decode_{name}", round(dt_d * 1e6, 1),
                     round(len(blob) / dt_d / 1e6, 1)))
        rows.append((f"codec_bpp_{name}", 0.0, round(len(blob) / n_params, 4)))
    return rows


def scenario_table():
    """Async T-FedAvg on the paper MLP under realistic fleet scenarios:
    always-on vs diurnal churn vs 1% packet loss vs both (README table)."""
    from benchmarks.common import SMOKE, mlp_task
    from repro.comm import ChannelConfig
    from repro.data import partition_iid
    from repro.fed import AvailabilityConfig, FedConfig, run_federated
    from repro.models.paper_models import mlp_mnist
    from repro.optim import adam

    x, y, params, eval_fn = mlp_task(seed=0, n_train=1500, n_test=400)
    clients = partition_iid(x, y, 10)
    rounds = 3 if SMOKE else 20
    diurnal = AvailabilityConfig(kind="diurnal", period_s=120.0, floor=0.2,
                                 n_cohorts=4)
    lossy = ChannelConfig(loss_rate=0.01, chunk_bytes=4096)
    scenarios = {
        "alwayson": dict(),
        "diurnal": dict(availability=diurnal),
        "loss1pct": dict(channel=lossy),
        "churn_loss": dict(availability=diurnal, channel=lossy,
                           max_staleness=4),
    }
    rows = []
    for name, kw in scenarios.items():
        cfg = FedConfig(algorithm="tfedavg", mode="async", participation=0.5,
                        local_epochs=1 if SMOKE else 2, batch_size=32,
                        rounds=rounds, buffer_k=3, seed=0, **kw)
        res = run_federated(mlp_mnist, params, clients, cfg, adam(2e-3),
                            eval_fn, eval_every=rounds)
        rows.append((f"scenario_{name}_acc", 0.0, round(res.accuracy[-1], 4)))
        rows.append((f"scenario_{name}_upMB", 0.0,
                     round(res.upload_bytes / 1e6, 3)))
        rows.append((f"scenario_{name}_time", 0.0,
                     round(res.total_time_s, 2)))
        rows.append((f"scenario_{name}_goodput", 0.0,
                     round(res.telemetry["goodput_fraction"], 4)))
    return rows
