"""Wire-codec benchmarks: encode/decode throughput and bytes-per-parameter
vs the fp32 baseline, on real model payloads.

Rows (name, us_per_call, derived):
  wire_encode_<model>   derived = encode throughput, MB/s
  wire_decode_<model>   derived = decode throughput, MB/s
  wire_bpp_<model>      derived = serialized ternary bytes per parameter
  wire_ratio_<model>    derived = fp32 serialized bytes / ternary bytes
"""

from __future__ import annotations

import time

import jax

from repro.comm.wire import decode_update, encode_update
from repro.core import FTTQConfig
from repro.core.tfedavg import server_requantize
from repro.models.paper_models import (
    init_mlp_mnist, init_resnet_cifar,
)

FTTQ = FTTQConfig()


def _models():
    out = [
        ("mlp", init_mlp_mnist(jax.random.PRNGKey(0))),
        ("resnet", init_resnet_cifar(jax.random.PRNGKey(1))),
    ]
    try:
        from repro.configs import get_reduced
        from repro.models.transformer import init_params

        cfg = get_reduced("olmo-1b")
        out.append(("olmo_reduced", init_params(cfg, jax.random.PRNGKey(2))))
    except Exception:
        pass  # transformer stack unavailable: bench the paper models only
    return out


def _timed(fn, *args, repeats: int = 5):
    fn(*args)  # warm (traces/compiles + device transfers)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    return out, (time.perf_counter() - t0) / repeats


def wire_codec():
    rows = []
    for name, params in _models():
        n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
        wire_tree = server_requantize(params, FTTQ)

        blob, dt_e = _timed(encode_update, wire_tree)
        rows.append((f"wire_encode_{name}", round(dt_e * 1e6, 1),
                     round(len(blob) / dt_e / 1e6, 1)))

        _, dt_d = _timed(decode_update, blob)
        rows.append((f"wire_decode_{name}", round(dt_d * 1e6, 1),
                     round(len(blob) / dt_d / 1e6, 1)))

        fp_blob = encode_update(params)
        rows.append((f"wire_bpp_{name}", 0.0, round(len(blob) / n_params, 4)))
        rows.append((f"wire_ratio_{name}", 0.0,
                     round(len(fp_blob) / len(blob), 2)))
    return rows
