"""Fleet-scale hierarchy bench: flat vs 2-tier aggregation topology.

One ``run_fleet`` round per (clients, topology) cell under DiurnalChurn —
the vectorized cohort simulator (``fed/fleet.py``) moving real wire blobs
through the real channel/availability/aggregation stack with local SGD
stubbed by a pre-encoded payload pool. The claim under test is the tier's
whole point: ROOT ingress bytes scale with the EDGE count in the 2-tier
topology and with the PARTICIPANT count in the flat one, while memory
stays flat (chunk-bounded aggregator staging + O(n_clients) float arrays,
no per-client Python objects).

Rows (name, us_per_call, derived):
  fleet_flat_n<N>   wall µs for one flat round, derived = participants
  fleet_tier_n<N>   wall µs for one 2-tier round (E edges), derived =
                    root upstream bytes (the edge→root hop)
  fleet_root_ratio_n<N>   derived = flat root ingress / tier root ingress

``BENCH_hierarchy.json`` (repo root) records wall-clock, current/peak RSS,
and the per-tier byte ledger per cell; the byte-ledger balance invariant
is asserted on every tier run (CI smoke runs the 10k-client 2-tier cell).
"""

from __future__ import annotations

import json
import os
import resource
import time

import jax
import numpy as np

from repro.fed import FedConfig, HierarchyConfig, run_fleet
from repro.fed.availability import AvailabilityConfig

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_hierarchy.json")
N_EDGES = 64
PARTICIPATION = 0.1


def _rss_mib() -> float:
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return int(line.split()[1]) / 1024.0
    return 0.0  # pragma: no cover - /proc always has VmRSS on linux


def _peak_rss_mib() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _params(seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "dense1": {"w": rng.standard_normal((784, 128)).astype(np.float32),
                   "b": np.zeros(128, np.float32)},
        "dense2": {"w": rng.standard_normal((128, 10)).astype(np.float32),
                   "b": np.zeros(10, np.float32)},
    }


def _run(n_clients: int, n_edges: int):
    cfg = FedConfig(
        n_clients=n_clients, rounds=1, participation=PARTICIPATION,
        availability=AvailabilityConfig(kind="diurnal"),
        hierarchy=HierarchyConfig(n_edges=n_edges),
    )
    t0 = time.perf_counter()
    res = run_fleet(_params(), cfg)
    jax.block_until_ready(res.final_update)
    wall = time.perf_counter() - t0
    return res, wall


def fleet_scaling():
    from benchmarks.common import SMOKE

    # CI smoke keeps the 10k 2-tier cell (the byte-ledger gate) and skips
    # the 100k/1M fan-ins; smoke sizes are a SUBSET of the full ladder so
    # the committed full record always carries every gated baseline key.
    sizes = (1_000, 10_000) if SMOKE else (1_000, 10_000, 100_000, 1_000_000)
    rows, record = [], {
        "n_edges": N_EDGES, "participation": PARTICIPATION,
        "availability": "diurnal", "smoke": SMOKE, "results": {},
    }
    for n in sizes:
        cell: dict = {}
        flat_res, flat_wall = _run(n, 0)
        cell["flat"] = {
            "wall_s": flat_wall,
            "rss_mib": round(_rss_mib(), 1),
            "peak_rss_mib": round(_peak_rss_mib(), 1),
            "participants": flat_res.participants_per_round[0],
            "upload_bytes": flat_res.upload_bytes,
            # flat topology: every client blob lands on the root.
            "root_ingress_bytes": flat_res.upload_bytes,
        }
        tier_res, tier_wall = _run(n, N_EDGES)
        hier = tier_res.telemetry["hierarchy"]
        assert hier["ledger_balanced"], (
            f"byte ledger out of balance at n={n}: {hier}"
        )
        cell["tier2"] = {
            "wall_s": tier_wall,
            "rss_mib": round(_rss_mib(), 1),
            "peak_rss_mib": round(_peak_rss_mib(), 1),
            "participants": tier_res.participants_per_round[0],
            "upload_bytes": tier_res.upload_bytes,
            "client_to_edge_bytes": hier["client_to_edge_bytes"],
            "root_ingress_bytes": hier["edge_to_root_bytes"],
            "edges_active": sum(1 for c in hier["clients_per_edge"] if c),
        }
        ratio = (cell["flat"]["root_ingress_bytes"]
                 / max(cell["tier2"]["root_ingress_bytes"], 1))
        cell["root_ingress_ratio"] = round(ratio, 2)
        record["results"][str(n)] = cell
        rows.append((f"fleet_flat_n{n}", round(flat_wall * 1e6, 1),
                     cell["flat"]["participants"]))
        rows.append((f"fleet_tier_n{n}", round(tier_wall * 1e6, 1),
                     cell["tier2"]["root_ingress_bytes"]))
        rows.append((f"fleet_root_ratio_n{n}", 0.0, round(ratio, 2)))
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows
