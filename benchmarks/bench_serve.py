"""Serving-under-load bench: p50/p99 latency vs offered QPS, batch sweep.

Drives ``launch.serve_loop``'s closed loop against the packed-ternary
engine: a deterministic Poisson arrival schedule with REAL measured forward
wall times, swept over offered load × ``max_batch``. Offered-QPS points are
calibrated from a measured batch-1 forward (0.5× / 2× / 8× the engine's
single-stream capacity), so "past saturation" means past THIS runner's
saturation — the shape of the surface, not absolute QPS, is the artifact.

Rows (name, us_per_call, derived):
  serve_b<B>_<load>   p50 latency µs at that (batch, load) cell,
                      derived = achieved QPS
  serve_batch_speedup derived = saturated throughput max_batch vs batch=1
                      (the batching claim: > 1 or the record asserts)

``BENCH_serve.json`` (repo root) records the full latency surface, the
engine byte footprint, and the LRU dequant-cache counters; the ``wall_s``
keys are gated by ``benchmarks/check_regression.py`` against the committed
smoke baseline.
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.launch.serve_loop import ServeEngine, demo_model, run_closed_loop

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_serve.json")

PROMPT_LEN = 8
# offered load as multiples of the measured batch-1 capacity: under load,
# around saturation, and far past it (where batching has to carry it).
LOAD_POINTS = (("lo", 0.5), ("mid", 2.0), ("hi", 8.0))


def _calibrate(engine: ServeEngine, vocab: int) -> float:
    """Measured batch-1 forward seconds (after warmup)."""
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, vocab, size=(1, PROMPT_LEN)))
    engine.forward(toks)                     # warmup / trace
    reps = 3
    t0 = time.perf_counter()
    for _ in range(reps):
        engine.forward(toks)
    return (time.perf_counter() - t0) / reps


def serve_under_load():
    from benchmarks.common import SMOKE

    batches = (1, 8) if SMOKE else (1, 4, 8)
    n_requests = 12 if SMOKE else 40
    cfg, params = demo_model(d_model=32, n_layers=2)

    t0 = time.perf_counter()
    probe = ServeEngine(cfg, params, max_batch=1)
    build_s = time.perf_counter() - t0
    t_fwd = _calibrate(probe, cfg.vocab_size)
    base_qps = 1.0 / max(t_fwd, 1e-9)

    rows = []
    record = {
        "model": {"d_model": cfg.d_model, "n_layers": cfg.n_layers,
                  "vocab_size": cfg.vocab_size, "prompt_len": PROMPT_LEN},
        "smoke": SMOKE,
        "n_requests": n_requests,
        "build_s": build_s,
        "batch1_forward_s": t_fwd,
        "base_qps": base_qps,
        "engine": None,
        "sweep": {},
    }
    saturated = {}   # max_batch -> achieved qps at the "hi" point
    for b in batches:
        engine = ServeEngine(cfg, params, max_batch=b)
        for tag, mult in LOAD_POINTS:
            rep = run_closed_loop(
                engine, n_requests=n_requests, offered_qps=mult * base_qps,
                prompt_len=PROMPT_LEN, seed=17,
            )
            cell = rep.row()
            record["sweep"][f"b{b}_{tag}"] = cell
            rows.append((f"serve_b{b}_{tag}", round(rep.p50_ms * 1e3, 1),
                         round(rep.achieved_qps, 2)))
            if tag == "hi":
                saturated[b] = rep.achieved_qps
        record["engine"] = engine.stats()

    speedup = saturated[max(batches)] / max(saturated[1], 1e-9)
    record["batch_speedup_at_saturation"] = round(speedup, 3)
    # the batching claim this bench exists to measure: coalescing must buy
    # throughput over batch=1 under saturating load.
    assert speedup > 1.0, (
        f"batching gained nothing: {saturated} (speedup {speedup:.3f})"
    )
    rows.append(("serve_batch_speedup", 0.0, round(speedup, 2)))
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows
