"""Fused packed fan-in aggregation vs the per-client dequant loop.

Both paths consume the SAME serialized client wire blobs (the ResNet-CIFAR
payload — the paper's larger model) and produce the |D_k|-weighted mean:

  old  — decode each blob to jax arrays, dequantize every leaf to a dense
         fp32 tree, fold in a Python loop (``core.tfedavg.server_aggregate``)
  fused — stream blobs through ``fed.aggregator.Aggregator``: zero-copy
         record decode into stacked packed buffers + one Pallas launch per
         chunk (``kernels.aggregate.packed_weighted_sum``)

Rows (name, us_per_call, derived):
  agg_old_c<C> / agg_fused_c<C>   derived = aggregation throughput, client
                                  updates/s at fan-in C
  agg_speedup_c<C>                derived = old_time / fused_time
  agg_gbs_c<C>                    derived = effective dense GB/s of the fused
                                  path (C · n_params · 4 B / second)
  agg_peak_mib_c<C>               derived = peak stacked-buffer MiB of the
                                  fused path (chunked ⇒ independent of C)

``BENCH_aggregate.json`` (repo root) captures the same numbers for the CI
perf trajectory. Pallas runs interpret-mode off-TPU; the STRUCTURAL wins
(no per-client dense trees, O(chunk) memory, bounded trace set) transfer.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.comm.wire import decode_update, encode_update
from repro.core import FTTQConfig
from repro.core import fttq as F
from repro.core.tfedavg import TernaryUpdate, client_update_payload, server_aggregate
from repro.fed.aggregator import Aggregator
from repro.models.paper_models import init_resnet_cifar

FTTQ = FTTQConfig()
JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_aggregate.json")
CHUNK_C = 16
N_DISTINCT = 4   # distinct client payloads; cycled to build larger fan-ins


def _client_blobs():
    blobs = []
    n_params = 0
    for c in range(N_DISTINCT):
        params = init_resnet_cifar(jax.random.PRNGKey(c))
        wq = F.init_wq_tree(params, FTTQ)
        payload = client_update_payload(params, wq, FTTQ)
        blobs.append(encode_update(payload))
        if not n_params:
            n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    return blobs, n_params


def _old_loop(blobs, weights):
    updates = [
        TernaryUpdate(payload=decode_update(b), n_samples=w)
        for b, w in zip(blobs, weights)
    ]
    return server_aggregate(updates)


def _fused(blobs, weights):
    agg = Aggregator(chunk_c=CHUNK_C)
    for b, w in zip(blobs, weights):
        agg.add(b, weight=w)
    return agg.finalize(), agg.peak_intermediate_bytes


def _time(fn, repeats, warmup):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def fused_aggregation():
    from benchmarks.common import SMOKE

    fan_ins = (4, 16) if SMOKE else (4, 16, 64)
    repeats, warmup = (1, 1) if SMOKE else (3, 1)
    base, n_params = _client_blobs()
    rows, record = [], {
        "payload": "resnet_cifar", "n_params": n_params,
        "chunk_c": CHUNK_C, "interpret": jax.default_backend() != "tpu",
        "smoke": SMOKE, "results": {},
    }
    for c in fan_ins:
        blobs = [base[i % N_DISTINCT] for i in range(c)]
        weights = [100 + 13 * i for i in range(c)]

        dt_old = _time(lambda: _old_loop(blobs, weights), repeats, warmup)
        dt_fused = _time(lambda: _fused(blobs, weights)[0], repeats, warmup)
        _, peak = _fused(blobs, weights)

        # parity receipt: the two paths must agree before their times do.
        ref = _old_loop(blobs, weights)
        got, _ = _fused(blobs, weights)
        err = max(
            float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32))))
            for a, b in zip(jax.tree_util.tree_leaves(ref),
                            jax.tree_util.tree_leaves(got))
        )
        assert err < 1e-5, f"fused aggregation diverged at C={c}: {err}"

        speedup = dt_old / dt_fused
        gbs = c * n_params * 4 / dt_fused / 1e9
        rows.append((f"agg_old_c{c}", round(dt_old * 1e6, 1), round(c / dt_old, 1)))
        rows.append((f"agg_fused_c{c}", round(dt_fused * 1e6, 1), round(c / dt_fused, 1)))
        rows.append((f"agg_speedup_c{c}", 0.0, round(speedup, 2)))
        rows.append((f"agg_gbs_c{c}", 0.0, round(gbs, 3)))
        rows.append((f"agg_peak_mib_c{c}", 0.0, round(peak / 2**20, 3)))
        record["results"][str(c)] = {
            "old_s": dt_old, "fused_s": dt_fused, "speedup": round(speedup, 2),
            "old_updates_per_s": round(c / dt_old, 1),
            "fused_updates_per_s": round(c / dt_fused, 1),
            "fused_effective_gb_s": round(gbs, 3),
            "peak_intermediate_bytes": int(peak),
            "max_abs_err_vs_reference": err,
        }
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows
