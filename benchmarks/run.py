"""Benchmark harness — one section per paper table/figure. Prints
``name,us_per_call,derived`` CSV (derived = accuracy / ratio / bytes as
appropriate per row; see each bench's docstring).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table4,codec
    PYTHONPATH=src python -m benchmarks.run --only aggregate --smoke   # CI
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from repro.launch.env import pin_runtime

# pinned fast runtime (tcmalloc preload when present, quiet XLA logs) —
# must run before the section modules import jax.
pin_runtime()

from benchmarks import (  # noqa: E402
    bench_adaptive, bench_aggregate, bench_chaos, bench_encode,
    bench_hierarchy, bench_kernels, bench_robust, bench_serve, bench_tables,
    bench_wire, roofline,
)

SECTIONS = {
    "wire": bench_wire.wire_codec,
    "codecs": bench_wire.codec_table,
    "scenario": bench_wire.scenario_table,
    "aggregate": bench_aggregate.fused_aggregation,
    "encode": bench_encode.fused_encode,
    "hierarchy": bench_hierarchy.fleet_scaling,
    "serve": bench_serve.serve_under_load,
    "chaos": bench_chaos.chaos_sweep,
    "robust": bench_robust.robust_grid,
    "adaptive": bench_adaptive.adaptive_bytes_to_target,
    "kernel_peak": roofline.kernel_peak_table,
    "table2": bench_tables.table2_iid_accuracy,
    "table3": bench_tables.table3_noniid,
    "table4": bench_tables.table4_comm_costs,
    "fig7": bench_tables.fig7_batch_sizes,
    "fig10": bench_tables.fig10_participation,
    "fig11": bench_tables.fig11_unbalanced,
    "sparsity": bench_tables.sparsity_report,
    "codec": bench_kernels.codec_roundtrip,
    "quantizer": bench_kernels.quantizer_cost,
    "gemm_model": bench_kernels.ternary_matmul_hbm_model,
    "xpod_model": bench_kernels.collective_wire_model,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sanity mode: same code paths, minimal repeats")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    if args.smoke:
        import benchmarks.common as common

        common.SMOKE = True

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in SECTIONS.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            for row in fn():
                print(",".join(str(v) for v in row), flush=True)
        except Exception as e:
            failures += 1
            print(f"{name}_ERROR,0,{type(e).__name__}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# section {name} took {time.perf_counter() - t0:.1f}s",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
