"""Docs drift gate (stdlib-only — runs in the lint CI job, no jax).

Three checks, exit 1 on any failure:

1. **Markdown links**: every relative link target in the repo-root
   ``*.md`` files and ``docs/*.md`` must exist on disk (anchors stripped;
   external ``scheme://`` links are not fetched).
2. **README tables**: the codec and adaptive-compression tables in
   ``README.md`` (between ``<!-- codec-table -->`` /
   ``<!-- adaptive-table -->`` marker comments) must byte-match the
   tables rendered from the committed
   ``benchmarks/baselines/BENCH_adaptive.json`` — edit the bench, rerun
   it, re-baseline, and regenerate (``python benchmarks/check_docs.py
   --render``) rather than hand-editing numbers.
   ``benchmarks/bench_tables.readme_tables()`` delegates to the same
   renderers, so "regenerate the README tables" and "what the gate
   expects" cannot diverge.
3. **Wire spec**: ``docs/WIRE_FORMAT.md`` must quote the live format
   constants (magic, header struct, version set), and the frozen
   ``tests/data/wire_v1_update.bin`` capture must still parse as the v1
   header the spec describes (magic/version/CRC/body length).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import struct
import sys
import zlib

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE = ROOT / "benchmarks" / "baselines" / "BENCH_adaptive.json"
README = ROOT / "README.md"
WIRE_SPEC = ROOT / "docs" / "WIRE_FORMAT.md"
FIXTURE = ROOT / "tests" / "data" / "wire_v1_update.bin"

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

# --- table renderers (pure functions of the committed bench record) -------

_CODEC_ORDER = ("none", "ternary", "fp16", "bf16", "topk", "topk16")
_CODEC_META = {
    "none": ("raw array", "FedAvg baseline (fp32 on the wire)"),
    "ternary": ("`TernaryTensor`", "FTTQ 2-bit codes + trained scale"),
    "fp16": ("`DowncastTensor`", "half downcast, upcasts on decode"),
    "bf16": ("`DowncastTensor`", "bfloat16 downcast"),
    "topk": ("`TopKTensor`", "top-5% by magnitude, varint-delta indices"),
    "topk16": ("`TopKTensor`", "top-5% composed with fp16 values"),
}


def render_codec_table(record: dict) -> str:
    """Codec bytes/param table from ``codec_bytes_per_param``."""
    rows = record["codec_bytes_per_param"]
    lines = [
        "| codec | wire leaf | bytes/param | vs fp32 | notes |",
        "|-------|-----------|------------:|--------:|-------|",
    ]
    for kind in _CODEC_ORDER:
        if kind not in rows:
            continue
        leaf, note = _CODEC_META[kind]
        r = rows[kind]
        lines.append(
            f"| `{kind}` | {leaf} | {r['bytes_per_param']:.4f} "
            f"| {r['ratio_vs_fp32']:.2f}× | {note} |"
        )
    return "\n".join(lines)


def render_adaptive_table(record: dict) -> str:
    """Bytes-to-target table from the static/adaptive run summaries."""
    lines = [
        "| upstream policy | bytes to target | rounds | total upload B "
        "| best accuracy |",
        "|-----------------|----------------:|-------:|---------------:"
        "|--------------:|",
    ]
    for label, key in (("static ternary", "static"),
                       ("adaptive + error feedback", "adaptive")):
        r = record[key]
        lines.append(
            f"| {label} | {r['bytes_to_target']:,} "
            f"| {r['rounds_to_target'] + 1} | {r['total_upload_bytes']:,} "
            f"| {r['best_accuracy']:.3f} |"
        )
    lines.append(
        f"\nTarget accuracy {record['target_accuracy']} "
        f"(0.95× the static run's best); adaptive reached it with "
        f"**{record['bytes_ratio']:.2f}×** the static upstream bytes."
    )
    return "\n".join(lines)


_TABLES = {
    "codec-table": render_codec_table,
    "adaptive-table": render_adaptive_table,
}


def _marked_span(text: str, name: str) -> tuple[int, int] | None:
    begin, end = f"<!-- {name}:begin -->", f"<!-- {name}:end -->"
    i = text.find(begin)
    j = text.find(end)
    if i < 0 or j < 0:
        return None
    return i + len(begin), j


def check_tables(errors: list[str]) -> None:
    record = json.loads(BASELINE.read_text())
    text = README.read_text()
    for name, render in _TABLES.items():
        span = _marked_span(text, name)
        if span is None:
            errors.append(f"README.md: missing <!-- {name}:begin/end --> markers")
            continue
        got = text[span[0]:span[1]].strip()
        want = render(record).strip()
        if got != want:
            errors.append(
                f"README.md: {name} drifted from "
                f"benchmarks/baselines/BENCH_adaptive.json — regenerate with "
                f"`python benchmarks/check_docs.py --render`"
            )


def check_links(errors: list[str]) -> None:
    md_files = sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("*.md"))
    for md in md_files:
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if "://" in target or target.startswith(("#", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists() and not (ROOT / rel).exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> {target}")


# constants the spec must quote verbatim (mirrors comm/wire + transport +
# kernels/pack2bit — change the code, change the doc, or this fails).
_SPEC_LITERALS = (
    'b"TFW1"',          # wire magic
    "<4sHHIIQ",         # 24-byte wire header struct
    'b"TFT1"',          # transport frame magic
    "<4sBBHQ",          # 16-byte transport frame header struct
    "TOPK_DELTA",
    "LEB128",
)
_WIRE_HEADER = struct.Struct("<4sHHIIQ")


def check_wire_spec(errors: list[str]) -> None:
    if not WIRE_SPEC.exists():
        errors.append("docs/WIRE_FORMAT.md missing")
        return
    spec = WIRE_SPEC.read_text()
    for lit in _SPEC_LITERALS:
        if lit not in spec:
            errors.append(f"docs/WIRE_FORMAT.md: does not mention {lit!r}")
    blob = FIXTURE.read_bytes()
    magic, version, flags, n_records, crc, body_len = _WIRE_HEADER.unpack_from(blob)
    body = blob[_WIRE_HEADER.size:]
    if magic != b"TFW1" or version != 1 or flags != 0:
        errors.append(f"wire_v1_update.bin: header {magic!r} v{version} "
                      f"flags={flags} does not match the spec'd v1 layout")
    if len(body) != body_len or zlib.crc32(body) != crc:
        errors.append("wire_v1_update.bin: body length / CRC32 do not match "
                      "the header — frozen capture corrupted")
    if f"{n_records} records" not in spec:
        errors.append(
            f"docs/WIRE_FORMAT.md: frozen-capture walkthrough does not state "
            f"'{n_records} records' (fixture header says {n_records})"
        )


def render() -> None:
    """Rewrite the marked README spans from the committed baseline."""
    record = json.loads(BASELINE.read_text())
    text = README.read_text()
    for name, render_fn in _TABLES.items():
        span = _marked_span(text, name)
        if span is None:
            raise SystemExit(f"README.md: missing {name} markers")
        text = text[:span[0]] + "\n" + render_fn(record) + "\n" + text[span[1]:]
    README.write_text(text)
    print("README.md tables regenerated")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--render", action="store_true",
                    help="rewrite the marked README tables, then exit")
    args = ap.parse_args()
    if args.render:
        render()
        return
    errors: list[str] = []
    check_links(errors)
    check_tables(errors)
    check_wire_spec(errors)
    if errors:
        print(f"[docs] FAIL — {len(errors)} problem(s):")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    print("[docs] OK — links, README tables, wire spec all in sync")


if __name__ == "__main__":
    main()
