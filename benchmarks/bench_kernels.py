"""Kernel/microbenchmarks: codec throughput, compressed-collective wire
bytes, and quantizer cost — CPU wall times are NOT TPU projections (the
Pallas kernels run interpret=True here); the `derived` column carries the
structural quantities (bytes/ratios) that DO transfer."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.core.ternary import pack2bit, unpack2bit, packed_nbytes
from repro.kernels import ops
from repro.parallel.collectives import compressed_bytes_per_element


def codec_roundtrip():
    rows = []
    for n in (1 << 16, 1 << 20):
        it = jnp.asarray(
            np.random.default_rng(0).integers(-1, 2, size=(n,)), jnp.int8
        )
        pack = jax.jit(pack2bit)
        us = timed(pack, it)
        rows.append((f"codec_pack_n{n}", round(us, 1),
                     round(n / packed_nbytes(n), 2)))  # logical compression ×
        packed = pack(it)
        unpack = jax.jit(lambda p: unpack2bit(p, n))
        us = timed(unpack, packed)
        rows.append((f"codec_unpack_n{n}", round(us, 1), packed_nbytes(n)))
    return rows


def quantizer_cost():
    rows = []
    theta = jax.random.normal(jax.random.PRNGKey(0), (1024, 1024))
    us = timed(lambda t: ops.fttq_apply(t, 0.7, interpret=True)[0], theta)
    rows.append(("fttq_apply_1Mparam_interpret", round(us, 1), 1024 * 1024))
    return rows


def ternary_matmul_hbm_model():
    """Structural HBM-traffic advantage of the packed ternary GEMM on TPU:
    weight bytes read per (K×N) tile at bf16 vs 2-bit packed."""
    rows = []
    for (k, n) in ((4096, 4096), (2048, 11008)):
        bf16 = k * n * 2
        packed = packed_nbytes(k * n)
        rows.append((f"ternary_gemm_weight_bytes_k{k}_n{n}", 0.0,
                     round(bf16 / packed, 2)))
    return rows


def collective_wire_model():
    """Cross-pod gradient sync: bytes/element, bf16 ring vs ternary gather."""
    rows = []
    for pods in (2, 4, 8):
        ring = 2 * 2 * (pods - 1) / pods          # bf16 all-reduce
        tern = compressed_bytes_per_element(pods)  # packed all-gather
        rows.append((f"xpod_sync_bytes_per_elem_P{pods}", 0.0,
                     round(ring / tern, 2)))       # compression ×
    return rows
