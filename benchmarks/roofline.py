"""Roofline report (deliverable g): reads artifacts/dryrun/*.json and emits
the per-(arch × shape × mesh) three-term table + bottleneck + useful-flops
ratio, in markdown (for EXPERIMENTS.md) or CSV.

    PYTHONPATH=src python -m benchmarks.roofline            # markdown table
    PYTHONPATH=src python -m benchmarks.roofline --csv
    PYTHONPATH=src python -m benchmarks.roofline --compare baseline pod_compressed
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(variant_filter=None):
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(p))
        if d.get("status") != "ok":
            rows.append(d)
            continue
        if variant_filter and d.get("variant", "baseline") not in variant_filter:
            continue
        rows.append(d)
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def markdown(rows):
    out = [
        "| arch | shape | mesh | variant | compute | memory | collective "
        "| bottleneck | peakGB | useful | MFU≤ |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        if d.get("status") != "ok":
            out.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                f"{d.get('variant','-')} | ERROR: {d.get('error','')[:40]} "
                "| | | | | | |"
            )
            continue
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['variant']} "
            f"| {fmt_s(r['compute_term_s'])} | {fmt_s(r['memory_term_s'])} "
            f"| {fmt_s(r['collective_term_s'])} | **{r['bottleneck']}** "
            f"| {d['memory']['peak_estimate_gb']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['mfu_upper_bound']:.4f} |"
        )
    return "\n".join(out)


def csv(rows):
    out = ["arch,shape,mesh,variant,compute_s,memory_s,collective_s,"
           "bottleneck,peak_gb,useful_ratio,mfu_upper_bound"]
    for d in rows:
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        out.append(
            f"{d['arch']},{d['shape']},{d['mesh']},{d['variant']},"
            f"{r['compute_term_s']:.6g},{r['memory_term_s']:.6g},"
            f"{r['collective_term_s']:.6g},{r['bottleneck']},"
            f"{d['memory']['peak_estimate_gb']},{r['useful_flops_ratio']:.4f},"
            f"{r['mfu_upper_bound']:.5f}"
        )
    return "\n".join(out)


def compare(variants):
    """Side-by-side of the same cells across variants (§Perf evidence)."""
    by_cell = {}
    for d in load():
        if d.get("status") != "ok":
            continue
        key = (d["arch"], d["shape"], d["mesh"])
        by_cell.setdefault(key, {})[d["variant"]] = d
    lines = ["| cell | variant | compute | memory | collective | bound | Δbound |",
             "|---|---|---|---|---|---|---|"]
    for key, vs in sorted(by_cell.items()):
        if not all(v in vs for v in variants):
            continue
        base = vs[variants[0]]["roofline"]["step_time_lower_bound_s"]
        for v in variants:
            r = vs[v]["roofline"]
            delta = (r["step_time_lower_bound_s"] - base) / base * 100
            lines.append(
                f"| {'×'.join(key)} | {v} | {fmt_s(r['compute_term_s'])} "
                f"| {fmt_s(r['memory_term_s'])} | {fmt_s(r['collective_term_s'])} "
                f"| {fmt_s(r['step_time_lower_bound_s'])} | {delta:+.1f}% |"
            )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--compare", nargs="+")
    args = ap.parse_args()
    if args.compare:
        print(compare(args.compare))
    elif args.csv:
        print(csv(load(("baseline",))))
    else:
        print(markdown(load(("baseline",))))


if __name__ == "__main__":
    main()
