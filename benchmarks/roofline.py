"""Roofline report (deliverable g): reads artifacts/dryrun/*.json and emits
the per-(arch × shape × mesh) three-term table + bottleneck + useful-flops
ratio, in markdown (for EXPERIMENTS.md) or CSV.

    PYTHONPATH=src python -m benchmarks.roofline            # markdown table
    PYTHONPATH=src python -m benchmarks.roofline --csv
    PYTHONPATH=src python -m benchmarks.roofline --compare baseline pod_compressed
    PYTHONPATH=src python -m benchmarks.roofline --kernels  # % of peak per kernel

``--kernels`` is the per-Pallas-kernel %-of-peak table (first slice of the
real-hardware-validation roadmap item): each kernel's measured effective
bandwidth — derived from the BENCH_*.json records the bench suite emits —
against a MEASURED host memcpy peak. On this CPU/interpret-mode runner the
honest "theoretical peak" is host memory bandwidth; the small percentages
quantify the interpret-mode debt the roadmap names. The same rows ship in
the bench tables as the ``kernel_peak`` section of ``benchmarks.run``.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(variant_filter=None):
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(p))
        if d.get("status") != "ok":
            rows.append(d)
            continue
        if variant_filter and d.get("variant", "baseline") not in variant_filter:
            continue
        rows.append(d)
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


def markdown(rows):
    out = [
        "| arch | shape | mesh | variant | compute | memory | collective "
        "| bottleneck | peakGB | useful | MFU≤ |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        if d.get("status") != "ok":
            out.append(
                f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                f"{d.get('variant','-')} | ERROR: {d.get('error','')[:40]} "
                "| | | | | | |"
            )
            continue
        r = d["roofline"]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | {d['variant']} "
            f"| {fmt_s(r['compute_term_s'])} | {fmt_s(r['memory_term_s'])} "
            f"| {fmt_s(r['collective_term_s'])} | **{r['bottleneck']}** "
            f"| {d['memory']['peak_estimate_gb']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['mfu_upper_bound']:.4f} |"
        )
    return "\n".join(out)


def csv(rows):
    out = ["arch,shape,mesh,variant,compute_s,memory_s,collective_s,"
           "bottleneck,peak_gb,useful_ratio,mfu_upper_bound"]
    for d in rows:
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        out.append(
            f"{d['arch']},{d['shape']},{d['mesh']},{d['variant']},"
            f"{r['compute_term_s']:.6g},{r['memory_term_s']:.6g},"
            f"{r['collective_term_s']:.6g},{r['bottleneck']},"
            f"{d['memory']['peak_estimate_gb']},{r['useful_flops_ratio']:.4f},"
            f"{r['mfu_upper_bound']:.5f}"
        )
    return "\n".join(out)


def compare(variants):
    """Side-by-side of the same cells across variants (§Perf evidence)."""
    by_cell = {}
    for d in load():
        if d.get("status") != "ok":
            continue
        key = (d["arch"], d["shape"], d["mesh"])
        by_cell.setdefault(key, {})[d["variant"]] = d
    lines = ["| cell | variant | compute | memory | collective | bound | Δbound |",
             "|---|---|---|---|---|---|---|"]
    for key, vs in sorted(by_cell.items()):
        if not all(v in vs for v in variants):
            continue
        base = vs[variants[0]]["roofline"]["step_time_lower_bound_s"]
        for v in variants:
            r = vs[v]["roofline"]
            delta = (r["step_time_lower_bound_s"] - base) / base * 100
            lines.append(
                f"| {'×'.join(key)} | {v} | {fmt_s(r['compute_term_s'])} "
                f"| {fmt_s(r['memory_term_s'])} | {fmt_s(r['collective_term_s'])} "
                f"| {fmt_s(r['step_time_lower_bound_s'])} | {delta:+.1f}% |"
            )
    return "\n".join(lines)


# --------------------------------------------------------------------------
# Per-Pallas-kernel % of peak (from the measured BENCH_*.json records).
# --------------------------------------------------------------------------


def measure_host_peak_gb_s(n_mib: int = 64, repeats: int = 3) -> float:
    """Measured host memcpy bandwidth (GB/s): the honest bandwidth roof for
    CPU/interpret-mode kernels. Counts read+write bytes; best of N so a
    scheduler hiccup cannot deflate the roof."""
    import numpy as np

    src = np.ones(n_mib << 20, np.uint8)
    dst = np.empty_like(src)
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        dt = time.perf_counter() - t0
        best = max(best, 2 * src.nbytes / dt / 1e9)
    return best


def _record(name: str):
    path = os.path.join(ROOT, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def kernel_effective_rows() -> list[tuple[str, str, float, str]]:
    """(kernel, cell, effective_gb_s, source) per measured kernel run.

    Bytes-moved models per kernel:
      - aggregate fan-in: taken from the record's own fused_effective_gb_s
        (C packed client rows in + fp32 partial out).
      - quantize_pack: fp32 leaf read (4 B/param) + packed write
        (0.25 B/param) over the measured fused payload-encode time.
      - ternary_matmul (serve): packed weights + dense residual leaves
        streamed once per forward, over the measured batch-1 forward time.
    """
    rows: list[tuple[str, str, float, str]] = []
    agg = _record("BENCH_aggregate.json")
    if agg:
        for c, cell in sorted(agg.get("results", {}).items(),
                              key=lambda kv: int(kv[0])):
            rows.append(("aggregate_fanin", f"C{c}",
                         float(cell["fused_effective_gb_s"]),
                         "BENCH_aggregate.json"))
    enc = _record("BENCH_encode.json")
    if enc:
        for payload, cell in sorted(enc.get("results", {}).items()):
            moved = cell["n_params"] * 4 + cell["n_params"] // 4
            rows.append(("quantize_pack", payload,
                         moved / cell["payload_fused_s"] / 1e9,
                         "BENCH_encode.json"))
    srv = _record("BENCH_serve.json")
    if srv and srv.get("engine"):
        moved = (srv["engine"]["packed_weight_bytes"]
                 + srv["engine"]["lazy_wire_bytes_dense"])
        rows.append(("ternary_matmul", "serve_b1",
                     moved / srv["batch1_forward_s"] / 1e9,
                     "BENCH_serve.json"))
    return rows


def kernels_markdown() -> str:
    peak = measure_host_peak_gb_s()
    out = [
        f"host memcpy peak (measured): {peak:.2f} GB/s",
        "",
        "| kernel | cell | effective GB/s | % of peak | source |",
        "|---|---|---|---|---|",
    ]
    rows = kernel_effective_rows()
    if not rows:
        out.append("| (no BENCH_*.json records found — run benchmarks.run "
                   "first) | | | | |")
    for kernel, cell, eff, src in rows:
        out.append(f"| {kernel} | {cell} | {eff:.4g} | "
                   f"{100 * eff / peak:.4g}% | {src} |")
    return "\n".join(out)


def kernel_peak_table():
    """Bench-table section (benchmarks.run --only kernel_peak): derived
    column is GB/s for the roof row, % of that roof per kernel cell."""
    peak = measure_host_peak_gb_s()
    yield ("host_memcpy_peak_gb_s", 0.0, round(peak, 2))
    for kernel, cell, eff, _src in kernel_effective_rows():
        yield (f"peak_pct_{kernel}_{cell}", 0.0,
               float(f"{100 * eff / peak:.4g}"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--compare", nargs="+")
    ap.add_argument("--kernels", action="store_true",
                    help="measured %%-of-peak table per Pallas kernel")
    args = ap.parse_args()
    if args.kernels:
        print(kernels_markdown())
    elif args.compare:
        print(compare(args.compare))
    elif args.csv:
        print(csv(load(("baseline",))))
    else:
        print(markdown(load(("baseline",))))


if __name__ == "__main__":
    main()
