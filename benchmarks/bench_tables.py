"""Paper-table benchmarks (Tables II, III, IV; Figs 7, 10, 11) on the
synthetic MNIST stand-in. Each function returns CSV rows
(name, us_per_call, derived)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import centralized_train, mlp_task
from repro.core import FTTQConfig
from repro.core.fttq import ternary_stats
from repro.core.tfedavg import fedavg_round_bytes, tfedavg_round_bytes
from repro.data import (
    partition_iid, partition_noniid, partition_unbalanced,
)
from repro.fed import FedConfig, run_federated
from repro.models.paper_models import init_mlp_mnist, init_resnet_cifar, mlp_mnist
from repro.optim import adam


FTTQ = FTTQConfig()


def _run(algo, clients, params, eval_fn, *, rounds=14, participation=1.0,
         local_epochs=3, batch=32, seed=0, lr=2e-3, mode="sync"):
    """Protocol constants follow the regime validated in tests/examples:
    T-FedAvg re-quantizes the global model every round, so it needs enough
    local steps per round to recover from the downstream quantization — with
    too few rounds × epochs it sits at the re-quantization floor (the paper
    runs 100+ rounds; we use 14 × 3 epochs to stay in CPU budget)."""
    cfg = FedConfig(algorithm=algo, mode=mode, participation=participation,
                    local_epochs=local_epochs, batch_size=batch,
                    rounds=rounds, fttq=FTTQ, seed=seed)
    t0 = time.perf_counter()
    res = run_federated(mlp_mnist, params, clients, cfg, adam(lr),
                        eval_fn, eval_every=rounds)
    dt = (time.perf_counter() - t0) * 1e6 / rounds
    return res, dt


def table2_iid_accuracy():
    """Table II: Baseline / TTQ (centralized) vs FedAvg / T-FedAvg, IID."""
    x, y, params, eval_fn = mlp_task()
    rows = []

    t0 = time.perf_counter()
    p_base = centralized_train(mlp_mnist, params, x, y, adam(1e-3), steps=200)
    rows.append(("table2_baseline_acc", (time.perf_counter() - t0) * 1e6,
                 eval_fn(p_base)[0]))

    t0 = time.perf_counter()
    p_ttq = centralized_train(mlp_mnist, params, x, y, adam(1e-3), steps=200,
                              qat=True, fttq_cfg=FTTQ)
    rows.append(("table2_ttq_2bit_acc", (time.perf_counter() - t0) * 1e6,
                 eval_fn(p_ttq)[0]))

    clients = partition_iid(x, y, 10)
    res, dt = _run("fedavg", clients, params, eval_fn)
    rows.append(("table2_fedavg_acc", dt, res.accuracy[-1]))
    res, dt = _run("tfedavg", clients, params, eval_fn)
    rows.append(("table2_tfedavg_2bit_acc", dt, res.accuracy[-1]))
    return rows


def table3_noniid():
    """Table III: accuracy under non-IID label splits (N_c = 2, 5)."""
    x, y, params, eval_fn = mlp_task()
    rows = []
    for nc in (2, 5):
        clients = partition_noniid(x, y, 10, nc)
        for algo in ("fedavg", "tfedavg"):
            res, dt = _run(algo, clients, params, eval_fn, rounds=10)
            rows.append((f"table3_{algo}_Nc{nc}_acc", dt, res.accuracy[-1]))
    return rows


def table4_comm_costs():
    """Table IV: measured + analytic per-100-round communication (MB).

    Protocol constants follow the paper: N=100 clients, λ=0.1 ⇒ 10
    participants/round, MLP (24,330 params) and ResNet18* (≈600k params)."""
    rows = []
    mlp = init_mlp_mnist(jax.random.PRNGKey(0))
    resnet = init_resnet_cifar(jax.random.PRNGKey(1))
    for name, params in (("mlp", mlp), ("resnet", resnet)):
        fed = fedavg_round_bytes(params, 10)
        tfed = tfedavg_round_bytes(params, 10, FTTQ)
        rows.append((f"table4_{name}_fedavg_upload_mb_100r", 0.0,
                     round(fed["upload"] * 100 / 1e6, 2)))
        rows.append((f"table4_{name}_tfedavg_upload_mb_100r", 0.0,
                     round(tfed["upload"] * 100 / 1e6, 2)))
        rows.append((f"table4_{name}_compression_ratio", 0.0,
                     round(fed["upload"] / tfed["upload"], 2)))

    # measured end-to-end (MLP, 3 rounds): wire bytes actually produced.
    x, y, params, eval_fn = mlp_task()
    clients = partition_iid(x, y, 10)
    res_f, _ = _run("fedavg", clients, params, eval_fn, rounds=3)
    res_t, _ = _run("tfedavg", clients, params, eval_fn, rounds=3)
    rows.append(("table4_measured_ratio_upload", 0.0,
                 round(res_f.upload_bytes / res_t.upload_bytes, 2)))
    rows.append(("table4_measured_ratio_download", 0.0,
                 round(res_f.download_bytes / res_t.download_bytes, 2)))
    return rows


def fig7_batch_sizes():
    """Fig. 7: accuracy vs local batch size."""
    x, y, params, eval_fn = mlp_task()
    clients = partition_iid(x, y, 10)
    rows = []
    for b in (16, 64, 256):
        for algo in ("fedavg", "tfedavg"):
            res, dt = _run(algo, clients, params, eval_fn, rounds=6, batch=b)
            rows.append((f"fig7_{algo}_B{b}_acc", dt, res.accuracy[-1]))
    return rows


def fig10_participation():
    """Fig. 10: T-FedAvg accuracy vs participation ratio λ (N=20 scaled)."""
    x, y, params, eval_fn = mlp_task()
    clients = partition_iid(x, y, 20)
    rows = []
    for lam in (0.1, 0.3, 0.5):
        res, dt = _run("tfedavg", clients, params, eval_fn,
                       rounds=8, participation=lam)
        rows.append((f"fig10_tfedavg_lam{lam}_acc", dt, res.accuracy[-1]))
    return rows


def fig11_unbalanced():
    """Fig. 11: accuracy vs unbalancedness β (eq. 29)."""
    x, y, params, eval_fn = mlp_task()
    rows = []
    for beta in (0.1, 0.5, 1.0):
        clients = partition_unbalanced(x, y, 10, beta)
        for algo in ("fedavg", "tfedavg"):
            res, dt = _run(algo, clients, params, eval_fn, rounds=6,
                           participation=0.3, seed=1)
            rows.append((f"fig11_{algo}_beta{beta}_acc", dt, res.accuracy[-1]))
    return rows


def sparsity_report():
    """FTTQ ternary sparsity at the default T_k (sanity vs Prop. 4.1)."""
    params = init_mlp_mnist(jax.random.PRNGKey(2))
    st = ternary_stats(params, FTTQ)
    return [("fttq_ternary_sparsity", 0.0, round(st["ternary_sparsity"], 4)),
            ("fttq_quantized_fraction", 0.0, round(st["quantized_fraction"], 4))]


def readme_tables() -> str:
    """The README's generated tables, rendered from the committed
    ``benchmarks/baselines/BENCH_adaptive.json``. Delegates to the
    ``check_docs`` renderers so regeneration and the lint-job drift gate
    can never disagree; write them back into the marked README spans
    with ``python benchmarks/check_docs.py --render``."""
    import json

    from benchmarks.check_docs import (
        BASELINE, render_adaptive_table, render_codec_table,
    )

    record = json.loads(BASELINE.read_text())
    return (render_codec_table(record) + "\n\n"
            + render_adaptive_table(record))


if __name__ == "__main__":
    print(readme_tables())
