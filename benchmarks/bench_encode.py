"""Fused upstream encode vs the per-leaf jnp reference chain.

Both paths build the SAME wire payloads (byte-identical — asserted before
any timing is reported):

  ref   — the pinned per-leaf jnp pipeline: scale → threshold → ternarize →
          pack per tensor (``client_update_payload(fused=False)`` /
          ``server_requantize(fused=False)``)
  fused — the one-pass quantize→pack kernel driven tree-wide by
          ``core.encode``: lane-aligned staging, one launch per dtype (+ a
          vmapped launch per stacked leaf), w_q moments from the same pass

Rows (name, us_per_call, derived):
  enc_ref_<m> / enc_fused_<m>     client-payload encode; derived = encode
                                  throughput, Mparam/s
  enc_speedup_<m>                 derived = ref_time / fused_time
  req_ref_<m> / req_fused_<m>     server re-quantize (downstream broadcast)
  req_speedup_<m>                 derived = ref_time / fused_time
  ser_join_<m> / ser_stream_<m>   encode_update on the ternary broadcast
                                  tree: legacy join-based builder vs the
                                  preallocated streaming writer; derived =
                                  MB/s
  ser_stream_ratio_<m>            derived = join_time / stream_time
  ser_fp32_ratio_<m>              same ratio on the RAW fp32 payload (the
                                  FedAvg direction, where the saved
                                  whole-buffer copy is ~16× larger)

Timing uses the trajectory-comparable harness (warmup + per-iteration
``jax.block_until_ready``). ``BENCH_encode.json`` (repo root) captures the
numbers for the CI perf trajectory next to ``BENCH_aggregate.json``.
Pallas runs interpret-mode off-TPU; the structural wins (one HBM read per
leaf, one byte-sized write, one serialization allocation) transfer.
"""

from __future__ import annotations

import json
import os
import struct
import zlib

import jax

from repro.core import FTTQConfig
from repro.core import fttq as F
from repro.core.tfedavg import client_update_payload, server_requantize
from repro.comm.wire import (
    _HEADER,
    _PATH_SEP,
    _leaf_types,
    _path_entries,
    _record_for_leaf,
    encode_update,
)
from repro.models.paper_models import init_resnet_cifar

FTTQ = FTTQConfig()
JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_encode.json",
)


def _models():
    out = [("resnet", init_resnet_cifar(jax.random.PRNGKey(0)))]
    try:
        from repro.configs import get_reduced
        from repro.models.transformer import init_params

        cfg = get_reduced("olmo-1b")
        out.append(("olmo_reduced", init_params(cfg, jax.random.PRNGKey(1))))
    except Exception:
        pass  # transformer stack unavailable: bench the paper model only
    return out


def _timed(fn, repeats, warmup):
    """Seconds per call via the shared harness (``benchmarks.common.timed``:
    warmup + block_until_ready inside the timed region, SMOKE-aware) — one
    timing contract for the whole bench suite."""
    from benchmarks.common import timed

    return timed(fn, repeats=repeats, warmup=warmup) / 1e6


def _join_encode_update(tree) -> bytes:
    """The pre-streaming encoder: per-record bytes + one big join — kept
    here as the serialization baseline the micro-bench compares against."""
    lt = _leaf_types()
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, lt)
    )[0]
    records, version = [], 1
    for path, leaf in leaves:
        p = _PATH_SEP.join(_path_entries(path)).encode("utf-8")
        rec = _record_for_leaf(leaf)
        version = max(version, rec.min_version)
        records.append(b"".join([
            struct.pack("<H", len(p)), p,
            struct.pack("<B", rec.kind), rec.pack(leaf),
        ]))
    body = b"".join(records)
    return _HEADER.pack(
        b"TFW1", version, 0, len(records), zlib.crc32(body), len(body)
    ) + body


def fused_encode():
    from benchmarks.common import SMOKE

    repeats, warmup = 5, 2   # common.timed clamps to (1, 1) in SMOKE mode
    rows, record = [], {
        "interpret": jax.default_backend() != "tpu",
        "smoke": SMOKE,
        "results": {},
    }
    for name, params in _models():
        n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
        wq = F.init_wq_tree(params, FTTQ)

        # parity receipt FIRST: both paths must serialize byte-identically.
        ref_blob = encode_update(client_update_payload(params, wq, FTTQ,
                                                       fused=False))
        fus_blob = encode_update(client_update_payload(params, wq, FTTQ,
                                                       fused=True))
        assert ref_blob == fus_blob, f"fused encode diverged on {name}"

        dt_ref = _timed(
            lambda: client_update_payload(params, wq, FTTQ, fused=False),
            repeats, warmup,
        )
        dt_fus = _timed(
            lambda: client_update_payload(params, wq, FTTQ, fused=True),
            repeats, warmup,
        )
        dt_rref = _timed(
            lambda: server_requantize(params, FTTQ, fused=False),
            repeats, warmup,
        )
        dt_rfus = _timed(
            lambda: server_requantize(params, FTTQ, fused=True),
            repeats, warmup,
        )

        wire_tree = server_requantize(params, FTTQ)
        assert encode_update(wire_tree) == _join_encode_update(wire_tree)
        blob_len = len(encode_update(wire_tree))
        dt_join = _timed(lambda: _join_encode_update(wire_tree), repeats, warmup)
        dt_stream = _timed(lambda: encode_update(wire_tree), repeats, warmup)
        # the raw fp32 direction (FedAvg payloads): the intermediate copy
        # the streaming writer removes is full-size here
        dt_join32 = _timed(lambda: _join_encode_update(params), repeats, warmup)
        dt_stream32 = _timed(lambda: encode_update(params), repeats, warmup)

        mps = n_params / 1e6
        rows += [
            (f"enc_ref_{name}", round(dt_ref * 1e6, 1), round(mps / dt_ref, 2)),
            (f"enc_fused_{name}", round(dt_fus * 1e6, 1), round(mps / dt_fus, 2)),
            (f"enc_speedup_{name}", 0.0, round(dt_ref / dt_fus, 2)),
            (f"req_ref_{name}", round(dt_rref * 1e6, 1), round(mps / dt_rref, 2)),
            (f"req_fused_{name}", round(dt_rfus * 1e6, 1), round(mps / dt_rfus, 2)),
            (f"req_speedup_{name}", 0.0, round(dt_rref / dt_rfus, 2)),
            (f"ser_join_{name}", round(dt_join * 1e6, 1),
             round(blob_len / dt_join / 1e6, 1)),
            (f"ser_stream_{name}", round(dt_stream * 1e6, 1),
             round(blob_len / dt_stream / 1e6, 1)),
            (f"ser_stream_ratio_{name}", 0.0, round(dt_join / dt_stream, 2)),
            (f"ser_fp32_ratio_{name}", 0.0, round(dt_join32 / dt_stream32, 2)),
        ]
        record["results"][name] = {
            "n_params": n_params,
            "payload_ref_s": dt_ref, "payload_fused_s": dt_fus,
            "payload_speedup": round(dt_ref / dt_fus, 2),
            "requantize_ref_s": dt_rref, "requantize_fused_s": dt_rfus,
            "requantize_speedup": round(dt_rref / dt_rfus, 2),
            "wire_bytes": blob_len,
            "serialize_join_s": dt_join, "serialize_stream_s": dt_stream,
            "serialize_stream_ratio": round(dt_join / dt_stream, 2),
            "serialize_fp32_join_s": dt_join32,
            "serialize_fp32_stream_s": dt_stream32,
            "serialize_fp32_stream_ratio": round(dt_join32 / dt_stream32, 2),
            "byte_identical": True,
        }
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows
