"""Round-completion-vs-fault-rate sweep over the real socket tier.

Runs full ``fed.mp_server`` quorum rounds — real client OS processes, real
TCP, a real in-path ``ChaosProxy`` — at increasing Gilbert–Elliott fault
intensity, and measures what the fault machinery buys: which clients still
land (completion fraction), how many reconnects/resumes it took, and what
fraction of shipped update bytes became aggregate (goodput) vs drops.

Fault schedules are seeded, so each level's survivor set, retry count, and
byte ledger are reproducible run to run; only wall times move.

Rows (name, us_per_call, derived):
  chaos_<level>       round wall µs, derived = survivor fraction
  chaos_goodput       derived = heaviest level's ingested/shipped fraction

``BENCH_chaos.json`` (repo root) records the full sweep: per-level wall
times (the ``*_s`` keys are gated by ``benchmarks/check_regression.py``),
ledgers, and outcome histograms. The README robustness table is generated
from this record.
"""

from __future__ import annotations

import json
import os
import time
from collections import Counter

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_chaos.json")

CHAOS_SEED = 19       # same seed the CLI smoke and chaos tests pin
ROUND_SEED = 7

# fault intensity ladder: per-chunk fault probability while the GE chain is
# in the bad state, and the kill share of those faults
LEVELS = (
    ("none", 0.0, 0.0),
    ("light", 0.2, 0.3),
    ("heavy", 0.6, 0.6),
)


def _level_cfg(name: str, fault_bad: float, p_kill: float):
    from repro.comm.faults import FaultConfig

    return FaultConfig(
        seed=CHAOS_SEED,
        chunk_bytes=512,
        ge_p_good_bad=0.15,
        ge_p_bad_good=0.4,
        fault_good=0.0,
        fault_bad=fault_bad,
        p_kill=p_kill,
        p_refuse=0.5 if fault_bad > 0 else 0.0,
        delay_s=0.01,
    )


def chaos_sweep():
    from benchmarks.common import SMOKE
    from repro.fed.mp_server import demo_params, run_socket_round

    n_clients = 4
    levels = [lv for lv in LEVELS if not SMOKE or lv[0] in ("none", "heavy")]
    params = demo_params(seed=ROUND_SEED)

    rows = []
    record = {
        "smoke": SMOKE,
        "n_clients": n_clients,
        "chaos_seed": CHAOS_SEED,
        "quorum_frac": 0.5,
        "levels": {},
    }
    goodput_heaviest = 1.0
    for name, fault_bad, p_kill in levels:
        cfg = _level_cfg(name, fault_bad, p_kill)
        t0 = time.perf_counter()
        res = run_socket_round(
            params, n_clients, seed=ROUND_SEED, mode="sync",
            quorum_frac=0.5, fault_cfg=cfg,
        )
        wall = time.perf_counter() - t0
        led = res.ledger()
        assert led["balance_ok"], f"ledger imbalance at level {name}"
        shipped = max(res.shipped_update_bytes, 1)
        goodput = res.ingested_update_bytes / shipped
        frac = res.n_survivors / n_clients
        record["levels"][name] = {
            f"round_{name}_s": wall,
            "fault_bad": fault_bad,
            "p_kill": p_kill,
            "survivor_frac": frac,
            "committed": res.committed,
            "retries": res.retries,
            "resumed_bytes": res.resumed_bytes,
            "shipped_update_bytes": res.shipped_update_bytes,
            "ingested_update_bytes": res.ingested_update_bytes,
            "dropped_update_bytes": res.dropped_update_bytes,
            "goodput_frac": round(goodput, 4),
            "outcomes": dict(Counter(res.outcomes.values())),
            "chaos": res.chaos,
        }
        rows.append((f"chaos_{name}", round(wall * 1e6, 1), round(frac, 3)))
        goodput_heaviest = goodput
        # the robustness claim: faults may cost bytes, never correctness —
        # every level must commit at (or above) quorum with a balanced
        # ledger, and the no-fault level must lose nothing
        if name == "none":
            assert frac == 1.0 and res.retries == 0, (
                f"no-fault level degraded: {led}"
            )
    rows.append(("chaos_goodput", 0.0, round(goodput_heaviest, 3)))
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows
