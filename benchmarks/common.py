"""Shared helpers for the benchmark suite.

The container is offline and CPU-only: MNIST/CIFAR10 are replaced by
learnable synthetic stand-ins with matching shapes/class counts (see
data/synthetic.py). Absolute accuracies are dataset-specific; the
FedAvg-vs-T-FedAvg comparisons and the measured communication volumes are
the reproduction targets. Scale knobs keep each benchmark in CPU budget;
EXPERIMENTS.md records them."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data import synthetic_classification, partition_iid
from repro.models.paper_models import init_mlp_mnist, mlp_mnist

# smoke mode (benchmarks.run --smoke): shrink every section to a CI-budget
# sanity pass — same code paths, fewer repeats / smaller sweeps.
SMOKE = False


def timed(fn, *args, repeats: int = 3, warmup: int = 2):
    """Mean wall-clock µs per call, async-dispatch-proof: every warmup AND
    every timed iteration is ``jax.block_until_ready``-synchronized, so the
    number measures compute, not how fast XLA enqueues work."""
    if SMOKE:
        repeats, warmup = 1, 1
    for _ in range(max(warmup, 1)):            # compile/trace + device warm
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6  # µs


def mlp_task(seed: int = 0, n_train: int = 2000, n_test: int = 500):
    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(seed), n_train, 10, 784, noise=3.0, n_test=n_test
    )
    params = init_mlp_mnist(jax.random.PRNGKey(seed + 1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        logp = jax.nn.log_softmax(logits, -1)
        loss = -jnp.mean(jnp.take_along_axis(logp, yt_j[:, None], -1))
        return float(acc), float(loss)

    return x, y, params, eval_fn


def centralized_train(apply_fn, params, x, y, optimizer, steps=150, batch=64,
                      qat=False, fttq_cfg=None):
    """Baseline / TTQ rows of Table II (centralized)."""
    from repro.core import fttq as F
    from repro.optim import apply_updates

    x = jnp.asarray(x); y = jnp.asarray(y)
    opt_state = optimizer.init(params)
    wq = F.init_wq_tree(params, fttq_cfg) if qat else None

    def ce(p, xb, yb):
        logits = apply_fn(p, xb)
        logp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], -1))

    @jax.jit
    def step(p, w, s, xb, yb):
        if qat:
            def lf(p_, w_):
                return ce(F.quantize_tree(p_, w_, fttq_cfg), xb, yb)
            loss, (gp, gw) = jax.value_and_grad(lf, (0, 1))(p, w)
            w = jax.tree_util.tree_map(
                lambda a, g, pp: None if a is None else a - 0.05 * g / float(pp.size),
                w, gw, p, is_leaf=lambda z: z is None)
        else:
            loss, gp = jax.value_and_grad(lambda p_: ce(p_, xb, yb))(p)
        upd, s = optimizer.update(gp, s, p)
        p = apply_updates(p, upd)
        return p, w, s, loss

    n = len(y)
    for i in range(steps):
        lo = (i * batch) % max(n - batch, 1)
        params, wq, opt_state, _ = step(params, wq, opt_state,
                                        x[lo:lo + batch], y[lo:lo + batch])
    if qat:
        params = F.quantize_tree(params, wq, fttq_cfg)
    return params
