"""Bytes-to-target-accuracy: adaptive controller + error feedback vs
static ternary, under the churn + loss scenario.

Two runs of the SAME federated task (synthetic-MNIST MLP, diurnal churn,
lossy chunked channel), differing only in ``FedConfig.controller``:

  - **static**: ``controller=None`` — the frozen T-FedAvg upstream path
    (pure ternary every client, every round);
  - **adaptive**: ``fed.controller.CompressionController`` with error
    feedback on — per-client rung selection over the fp16 → bf16 →
    ternary → topk → topk16 ladder from measured goodput + update
    divergence, residuals folded back before each encode.

Both runs eval every round; the headline metric is the cumulative
upstream bytes at the FIRST round whose accuracy reaches the target
(``TARGET_FRAC`` × the static run's best accuracy). The gate — asserted
here AND re-checked by ``benchmarks/check_regression.py`` from the JSON
record — is the ISSUE acceptance criterion: **adaptive must reach the
target at equal or fewer upstream bytes than static ternary**.

The record also carries a deterministic ``codec_bytes_per_param`` table
(every registered upstream codec encoding one fixed seeded tree) which
``benchmarks/check_docs.py`` uses to verify the README codec table never
drifts from the code.

Rows (name, us_per_call, derived):
  adaptive_static_bytes    round wall µs (static),   derived = bytes-to-target
  adaptive_ctrl_bytes      round wall µs (adaptive), derived = bytes-to-target
  adaptive_bytes_ratio     0,                        derived = adaptive/static
  codec_bpp_<kind>         encode µs/leaf-tree,      derived = bytes/param

Timing keys in ``BENCH_adaptive.json`` deliberately end in ``_us`` (not
``_s``): CPU federated rounds at smoke scale are seconds-long but vary
with runner load, and the meaningful gate here is the byte comparison,
which ``check_regression.py`` applies explicitly.
"""

from __future__ import annotations

import json
import os
import time

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_adaptive.json")

SEED = 11
TARGET_FRAC = 0.95        # target = TARGET_FRAC x static run's best accuracy


def _scenario_cfg(controller, *, rounds: int, n_clients: int):
    """Churn + loss FedConfig, identical apart from the controller."""
    from repro.comm.channel import ChannelConfig
    from repro.fed import AvailabilityConfig, FedConfig

    return FedConfig(
        algorithm="tfedavg",
        mode="sync",
        n_clients=n_clients,
        participation=1.0,
        local_epochs=3,
        batch_size=32,
        rounds=rounds,
        seed=SEED,
        controller=controller,
        availability=AvailabilityConfig(kind="diurnal", period_s=200.0,
                                        floor=0.5, n_cohorts=2),
        channel=ChannelConfig(loss_rate=0.05, chunk_bytes=4096,
                              bandwidth_sigma=0.5),
    )


def _bytes_to_target(result, target: float):
    """(cumulative upstream bytes, round index) at first acc >= target."""
    per_round = result.telemetry["upload_bytes_per_round"]
    cum = 0
    for r, (nbytes, acc) in enumerate(zip(per_round, result.accuracy)):
        cum += nbytes
        if acc >= target:
            return cum, r
    return None, None


def _run(controller, task, *, rounds: int, n_clients: int):
    from repro.data import partition_iid
    from repro.fed import run_federated
    from repro.models.paper_models import mlp_mnist
    from repro.optim import adam

    x, y, params, eval_fn = task
    clients = partition_iid(x, y, n_clients)
    cfg = _scenario_cfg(controller, rounds=rounds, n_clients=n_clients)
    t0 = time.perf_counter()
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=1)
    wall = time.perf_counter() - t0
    return res, wall


def _codec_bytes_per_param():
    """Deterministic bytes/param for every upstream codec on a fixed tree.

    Seeded once, encoded once per codec — pure function of the codec
    implementations, so the README codec table can be checked against it
    byte-for-byte (``benchmarks/check_docs.py``).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.compression import (
        CodecSpec, available_codecs, compress_pytree,
    )
    from repro.comm.wire import encode_update

    keys = jax.random.split(jax.random.PRNGKey(SEED), 3)
    tree = {
        "dense": {"kernel": jax.random.normal(keys[0], (256, 128)),
                  "bias": jax.random.normal(keys[1], (128,))},
        "out": {"kernel": jax.random.normal(keys[2], (128, 10))},
    }
    n_params = sum(leaf.size for leaf in jax.tree_util.tree_leaves(tree))
    out = {}
    rows = []
    for kind in available_codecs():
        spec = CodecSpec(kind=kind, topk_fraction=0.05)
        t0 = time.perf_counter()
        wire, _ = compress_pytree(tree, spec)
        nbytes = len(encode_update(wire))
        us = (time.perf_counter() - t0) * 1e6
        bpp = round(nbytes / n_params, 4)
        out[kind] = {"nbytes": nbytes, "bytes_per_param": bpp}
        rows.append((f"codec_bpp_{kind}", round(us, 1), bpp))
    dense = float(jnp.dtype(jnp.float32).itemsize)
    for kind, rec in out.items():
        rec["ratio_vs_fp32"] = round(dense / rec["bytes_per_param"], 2)
    return out, n_params, rows


def adaptive_bytes_to_target():
    from benchmarks.common import SMOKE, mlp_task

    from repro.fed import ControllerConfig

    # smoke shrinks ROUNDS only: fewer clients or less data makes the
    # sparse aggressive rung (topk16 over 4 clients) too lossy to recover
    # within the horizon, and the whole point is exercising the SAME
    # adaptive trajectory the full bench gates.
    rounds = 8 if SMOKE else 10
    n_clients = 8
    task = mlp_task(seed=SEED, n_train=2400, n_test=400)

    static_res, static_wall = _run(None, task, rounds=rounds,
                                   n_clients=n_clients)
    target = round(TARGET_FRAC * max(static_res.accuracy), 6)

    ctrl = ControllerConfig(error_feedback=True, warmup_encodes=1,
                            divergence_high=0.5, slow_factor=0.5,
                            aggressive_rung="topk16")
    adapt_res, adapt_wall = _run(ctrl, task, rounds=rounds,
                                 n_clients=n_clients)

    s_bytes, s_round = _bytes_to_target(static_res, target)
    a_bytes, a_round = _bytes_to_target(adapt_res, target)
    assert s_bytes is not None, (
        f"static run never reached its own target {target}")
    assert a_bytes is not None, (
        f"adaptive run never reached target {target} "
        f"(best {max(adapt_res.accuracy):.4f})")
    # the acceptance criterion — also re-checked from the JSON by
    # check_regression.py, so the committed record can't rot.
    assert a_bytes <= s_bytes, (
        f"adaptive used MORE bytes to target: {a_bytes} > {s_bytes}")

    codec_table, n_params, codec_rows = _codec_bytes_per_param()
    record = {
        "smoke": SMOKE,
        "seed": SEED,
        "rounds": rounds,
        "n_clients": n_clients,
        "target_accuracy": target,
        "scenario": {"availability": "diurnal", "loss_rate": 0.05,
                     "bandwidth_sigma": 0.5},
        "static": {
            "bytes_to_target": s_bytes,
            "rounds_to_target": s_round,
            "total_upload_bytes": static_res.upload_bytes,
            "best_accuracy": round(max(static_res.accuracy), 6),
            "wall_us": round(static_wall * 1e6, 1),
        },
        "adaptive": {
            "bytes_to_target": a_bytes,
            "rounds_to_target": a_round,
            "total_upload_bytes": adapt_res.upload_bytes,
            "best_accuracy": round(max(adapt_res.accuracy), 6),
            "wall_us": round(adapt_wall * 1e6, 1),
            "controller": adapt_res.telemetry["controller"],
        },
        "bytes_ratio": round(a_bytes / s_bytes, 4),
        "codec_bytes_per_param": codec_table,
        "codec_table_n_params": n_params,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")

    rows = [
        ("adaptive_static_bytes", round(static_wall * 1e6 / rounds, 1),
         s_bytes),
        ("adaptive_ctrl_bytes", round(adapt_wall * 1e6 / rounds, 1),
         a_bytes),
        ("adaptive_bytes_ratio", 0, round(a_bytes / s_bytes, 4)),
    ]
    rows.extend(codec_rows)
    return rows
