"""Attack × defense grid over the Byzantine-robust ingest layer.

Each cell runs one in-process round: C seeded client updates (f of them
from ``fed.attackers``), the ``fed.defense`` quarantine gate, then the
configured ``fed.aggregator`` rule — and measures what the defense buys:

  - divergence of the defended aggregate from the HONEST-ONLY weighted
    mean (relative L2 over the flattened tree; -1 when the aggregate
    went non-finite, which is what an undefended nan_poison produces);
  - quarantine precision/recall against the known attacker set;
  - aggregation wall time (gate + rule).

The "none" attack row doubles as the bit-exactness witness: gate+mean
over an all-honest cohort must reproduce the honest mean with divergence
exactly 0.0. A nan_poison row must quarantine every attacker (recall 1.0)
under every gate defense — both are asserted, not just recorded.

The vote-kernel section races ``kernels.vote.packed_vote_counts`` against
``kernels.aggregate.packed_weighted_sum`` on identical stacked packed
buffers at C ∈ {16, 64} — the cost of counting two vote planes instead of
one weighted sum, straight off the same bytes.

Rows (name, us_per_call, derived):
  robust_<attack>_<defense>   agg wall µs, derived = divergence
  vote_kernel_c<C>            vote µs/call, derived = vote_time/mean_time

``BENCH_robust.json`` (repo root) records the full grid; its ``*_s`` keys
are gated by ``benchmarks/check_regression.py``.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_robust.json")

SEED = 23
N_CLIENTS = 16
N_ATTACKERS = 5          # f < C/2: the majority rule's operating regime

ATTACKS = ("none", "sign_flip", "scale_blowup", "gaussian", "nan_poison",
           "collude")
DEFENSES = ("off", "gate_mean", "gate_majority", "gate_trimmed")
SMOKE_ATTACKS = ("none", "sign_flip", "nan_poison")


def _defense_cfg(name: str):
    from repro.fed.defense import DefenseConfig

    if name == "off":
        return None
    rule = {"gate_mean": "mean", "gate_majority": "majority",
            "gate_trimmed": "trimmed_mean"}[name]
    # min_history=2: the scale-bound check goes live inside a 16-client
    # round instead of staying observe-only for most of it.
    return DefenseConfig(enabled=True, rule=rule, min_history=2)


def _tree_l2(tree) -> float:
    import jax

    sq = 0.0
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf, dtype=np.float64)
        sq += float(np.sum(arr * arr))
    return float(np.sqrt(sq))


def _tree_div(a, b) -> float:
    """Relative L2 divergence ‖a−b‖/‖b‖; -1 when non-finite."""
    import jax

    diff = jax.tree_util.tree_map(
        lambda x, y: np.asarray(x, np.float64) - np.asarray(y, np.float64),
        a, b,
    )
    d = _tree_l2(diff) / max(_tree_l2(b), 1e-30)
    return float(d) if np.isfinite(d) else -1.0


def _round_blobs(params, attack_kind: str):
    """C client blobs + the attacker id set + per-client weights."""
    from repro.fed.attackers import AttackConfig, attacker_ids, poison_blob
    from repro.fed.mp_server import client_update_blob

    weights = [1.0 + (cid % 3) for cid in range(N_CLIENTS)]
    blobs = [client_update_blob(params, cid, SEED) for cid in range(N_CLIENTS)]
    if attack_kind == "none":
        return blobs, frozenset(), weights
    atk = AttackConfig(kind=attack_kind, n_attackers=N_ATTACKERS, seed=SEED)
    ids = attacker_ids(atk, N_CLIENTS)
    blobs = [poison_blob(b, atk, cid) if cid in ids else b
             for cid, b in enumerate(blobs)]
    return blobs, ids, weights


def _cell(params, blobs, attackers, weights, defense):
    """One grid cell: gate + rule aggregation; returns (record, wall_s)."""
    from repro.fed.aggregator import Aggregator
    from repro.fed.defense import UpdateGate

    rule = defense.rule if defense is not None else "mean"
    trim = defense.trim_frac if defense is not None else 0.2
    t0 = time.perf_counter()
    gate = UpdateGate(defense, params) if defense is not None else None
    agg = Aggregator(chunk_c=16, rule=rule, trim_frac=trim)
    quarantined: set[int] = set()
    for cid, blob in enumerate(blobs):
        if gate is not None and not gate.check(blob).ok:
            quarantined.add(cid)
            agg.note_quarantined(len(blob))
            continue
        agg.add(blob, weight=weights[cid])
    out = agg.finalize() if agg.n_clients else None
    wall = time.perf_counter() - t0

    tp = len(quarantined & attackers)
    precision = tp / len(quarantined) if quarantined else 1.0
    recall = tp / len(attackers) if attackers else 1.0
    rec = {
        "agg_wall_s": wall,
        "quarantined": sorted(quarantined),
        "precision": round(precision, 4),
        "recall": round(recall, 4),
        "reasons": dict(gate.reasons) if gate is not None else {},
    }
    return out, rec


def robust_grid():
    from benchmarks.common import SMOKE
    from repro.fed.aggregator import Aggregator
    from repro.fed.mp_server import demo_params

    params = demo_params(seed=SEED)
    attacks = SMOKE_ATTACKS if SMOKE else ATTACKS
    rows = []
    record = {
        "smoke": SMOKE,
        "n_clients": N_CLIENTS,
        "n_attackers": N_ATTACKERS,
        "seed": SEED,
        "grid": {},
    }
    for attack in attacks:
        blobs, attackers, weights = _round_blobs(params, attack)
        # honest-only reference: the weighted mean over the clients that
        # SHOULD survive — what a perfect defense would compute with "mean".
        ref_agg = Aggregator(chunk_c=16)
        for cid, blob in enumerate(blobs):
            if cid not in attackers:
                ref_agg.add(blob, weight=weights[cid])
        honest_ref = ref_agg.finalize()

        record["grid"][attack] = {}
        for dname in DEFENSES:
            out, rec = _cell(params, blobs, attackers, weights,
                             _defense_cfg(dname))
            div = _tree_div(out, honest_ref) if out is not None else -1.0
            rec["divergence"] = round(div, 6) if div >= 0 else -1.0
            record["grid"][attack][dname] = rec
            rows.append((f"robust_{attack}_{dname}",
                         round(rec["agg_wall_s"] * 1e6, 1),
                         rec["divergence"]))
            if attack == "none" and dname == "gate_mean":
                # defense-on-honest is BIT-EXACT vs the plain mean
                assert div == 0.0, f"honest gate_mean diverged: {div}"
            if attack == "nan_poison" and dname != "off":
                assert rec["recall"] == 1.0, (
                    f"nan_poison leaked past the gate: {rec}"
                )

    rows.extend(_vote_kernel_rows(record))
    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    return rows


def _vote_kernel_rows(record: dict):
    from benchmarks.common import SMOKE
    from repro.kernels.aggregate import BLOCK_ROWS, LANES
    from repro.parallel.fanin import fanin_vote_counts, fanin_weighted_sum

    reps = 3 if SMOKE else 30
    r = 32 * BLOCK_ROWS
    rng = np.random.default_rng(SEED)
    rows = []
    record["vote_kernel"] = {}
    for c in (16, 64):
        # valid 2-bit code planes only (codes 0..2, never the reserved 3)
        codes = rng.integers(0, 3, size=(c, r * LANES, 4), dtype=np.uint8)
        stacked = (codes[..., 0] | (codes[..., 1] << 2) | (codes[..., 2] << 4)
                   | (codes[..., 3] << 6)).reshape(c, r, LANES)
        coeffs = rng.uniform(1.0, 3.0, size=c).astype(np.float32)

        def timed(fn):
            fn(stacked, coeffs).block_until_ready()     # compile + warm
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(stacked, coeffs).block_until_ready()
            return (time.perf_counter() - t0) / reps

        t_vote = timed(fanin_vote_counts)
        t_mean = timed(fanin_weighted_sum)
        gb = stacked.nbytes / 1e9
        record["vote_kernel"][f"c{c}"] = {
            "bytes_in": int(stacked.nbytes),
            "vote_us": round(t_vote * 1e6, 1),
            "mean_us": round(t_mean * 1e6, 1),
            "vote_gb_per_s": round(gb / t_vote, 3),
            "mean_gb_per_s": round(gb / t_mean, 3),
            "vote_vs_mean": round(t_vote / t_mean, 3),
        }
        rows.append((f"vote_kernel_c{c}", round(t_vote * 1e6, 1),
                     round(t_vote / t_mean, 3)))
    return rows
