"""Wire codec tests: bit-exact round trips, integrity checking, and the
measured-size contract (serialized size == content + bounded framing)."""

import struct
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    WireError, decode_update, encode_update, update_nbytes,
)
from repro.comm.wire import _HEADER, WIRE_MAGIC
from repro.core import FTTQConfig
from repro.core import fttq as F
from repro.core.compression import wire_nbytes
from repro.core.tfedavg import client_update_payload, server_requantize
from repro.core.ternary import TernaryTensor, encode_ternary
from repro.kernels.pack2bit import pack2bit as pallas_pack2bit
from repro.kernels.pack2bit import pad_to_packable, unpack_padded
from repro.models.paper_models import init_mlp_mnist

CFG = FTTQConfig()


def _leaves(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, TernaryTensor)
    )[0]


def assert_trees_bitexact(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for (pa, xa), (pb, xb) in zip(la, lb):
        assert pa == pb
        if isinstance(xa, TernaryTensor):
            assert isinstance(xb, TernaryTensor)
            assert xa.shape == xb.shape and xa.dtype == xb.dtype
            np.testing.assert_array_equal(np.asarray(xa.packed), np.asarray(xb.packed))
            np.testing.assert_array_equal(np.asarray(xa.w_q), np.asarray(xb.w_q))
        else:
            assert xa.dtype == xb.dtype
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# --------------------------------------------------------------------------
# Round trips.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "float16", "int32"])
def test_raw_roundtrip_dtypes(dtype):
    x = jnp.arange(30).reshape(5, 6).astype(jnp.dtype(dtype))
    tree = {"layer": {"w": x, "b": jnp.zeros((3,), jnp.dtype(dtype))}}
    assert_trees_bitexact(tree, decode_update(encode_update(tree)))


@pytest.mark.parametrize("shape", [(), (1,), (7,), (5, 3), (3, 5, 7), (2, 3, 4, 5)])
def test_raw_roundtrip_shapes(shape):
    rng = np.random.default_rng(0)
    tree = {"x": jnp.asarray(rng.normal(size=shape).astype(np.float32))}
    assert_trees_bitexact(tree, decode_update(encode_update(tree)))


@pytest.mark.parametrize("n", [1, 3, 5, 17, 4096, 999])
def test_ternary_roundtrip_non_multiple_of_4(n):
    rng = np.random.default_rng(n)
    i_t = jnp.asarray(rng.integers(-1, 2, size=(n,)).astype(np.int8))
    t = encode_ternary(i_t, jnp.float32(0.37))
    tree = {"w": t}
    back = decode_update(encode_update(tree))["w"]
    np.testing.assert_array_equal(np.asarray(back.ternary()), np.asarray(i_t))
    assert float(back.w_q) == pytest.approx(0.37)


def test_model_payload_roundtrip_bitexact():
    """A full client payload (TernaryTensor weights + fp32 biases)."""
    params = init_mlp_mnist(jax.random.PRNGKey(0))
    wq = F.init_wq_tree(params, CFG)
    payload = client_update_payload(params, wq, CFG)
    assert_trees_bitexact(payload, decode_update(encode_update(payload)))


def test_stacked_scan_leaf_roundtrip():
    """≥3-D stacked scan weights with per-layer w_q scales."""
    params = {"scan": {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 9, 13))}}
    wq = F.init_wq_tree(params, CFG)
    assert wq["scan"]["w"].shape == (4, 1, 1)
    payload = client_update_payload(params, wq, CFG)
    t = payload["scan"]["w"]
    assert isinstance(t, TernaryTensor) and t.shape == (4, 9, 13)
    back = decode_update(encode_update(payload))["scan"]["w"]
    np.testing.assert_array_equal(np.asarray(t.ternary()), np.asarray(back.ternary()))
    np.testing.assert_array_equal(np.asarray(t.w_q), np.asarray(back.w_q))
    assert np.asarray(back.w_q).shape == (4, 1, 1)


def test_list_and_bare_leaf_roundtrip():
    tree = [jnp.arange(4), {"a": jnp.ones((2, 2))}, [jnp.zeros(3), jnp.ones(1)]]
    back = decode_update(encode_update(tree))
    assert isinstance(back, list) and isinstance(back[2], list)
    np.testing.assert_array_equal(np.asarray(back[2][1]), np.ones(1))
    bare = decode_update(encode_update(jnp.arange(9.0)))
    np.testing.assert_array_equal(np.asarray(bare), np.arange(9.0))


def test_int_dict_keys_roundtrip():
    """Int-keyed dicts (e.g. per-layer dicts keyed by index) keep their key
    type and are NOT confused with list indices."""
    tree = {0: jnp.arange(3.0), 1: {"w": jnp.ones((2, 2))}}
    back = decode_update(encode_update(tree))
    assert isinstance(back, dict)
    assert set(back.keys()) == {0, 1}
    np.testing.assert_array_equal(np.asarray(back[1]["w"]), np.ones((2, 2)))
    # a pure int-keyed dict stays a dict, while a list stays a list
    d = decode_update(encode_update({0: jnp.ones(1), 1: jnp.zeros(1)}))
    assert isinstance(d, dict) and set(d.keys()) == {0, 1}
    l = decode_update(encode_update([jnp.ones(1), jnp.zeros(1)]))
    assert isinstance(l, list) and len(l) == 2


def test_tensor_to_bytes_from_bytes():
    i_t = jnp.asarray(np.random.default_rng(3).integers(-1, 2, (11, 5)), jnp.int8)
    t = encode_ternary(i_t, jnp.float32(1.5))
    t2 = TernaryTensor.from_bytes(t.to_bytes())
    np.testing.assert_array_equal(np.asarray(t.ternary()), np.asarray(t2.ternary()))
    assert t2.shape == (11, 5) and t2.dtype == "float32"


# --------------------------------------------------------------------------
# Integrity.
# --------------------------------------------------------------------------


def test_crc_detects_corruption():
    blob = encode_update({"w": jnp.arange(64.0)})
    for offset in (_HEADER.size + 1, len(blob) // 2, len(blob) - 1):
        bad = bytearray(blob)
        bad[offset] ^= 0xFF
        with pytest.raises(WireError, match="CRC32"):
            decode_update(bytes(bad))


def test_truncation_and_magic_and_version_rejected():
    blob = encode_update({"w": jnp.arange(16.0)})
    with pytest.raises(WireError):
        decode_update(blob[: len(blob) - 3])
    with pytest.raises(WireError, match="magic"):
        decode_update(b"XXXX" + blob[4:])
    # an unsupported version (keep everything else): header-level reject
    magic, ver, flags, n, crc, blen = _HEADER.unpack_from(blob)
    bad = _HEADER.pack(WIRE_MAGIC, 99, flags, n, crc, blen) + blob[_HEADER.size:]
    with pytest.raises(WireError, match="version"):
        decode_update(bad)
    with pytest.raises(WireError):
        decode_update(b"")


# --------------------------------------------------------------------------
# The measured-size contract.
# --------------------------------------------------------------------------


def test_serialized_size_matches_content_within_framing():
    """len(encode_update) == raw content bytes + bounded per-record framing."""
    params = init_mlp_mnist(jax.random.PRNGKey(2))
    wire_tree = server_requantize(params, CFG)
    blob = encode_update(wire_tree)
    assert wire_nbytes(wire_tree) == len(blob) == update_nbytes(wire_tree)

    content = 0
    leaves = _leaves(wire_tree)
    for _, leaf in leaves:
        if isinstance(leaf, TernaryTensor):
            content += int(np.asarray(leaf.packed).nbytes)
            content += int(np.asarray(leaf.w_q).nbytes)
        else:
            content += int(np.asarray(leaf).nbytes)
    overhead = len(blob) - content
    assert 0 < overhead <= _HEADER.size + 96 * len(leaves)


def test_compression_ratio_on_wire():
    """fp32 vs ternary serialized buffers reproduce the ~16× of Table IV
    (slightly under: biases ship fp32 and framing adds bytes)."""
    params = init_mlp_mnist(jax.random.PRNGKey(4))
    fp = update_nbytes(params)
    tern = update_nbytes(server_requantize(params, CFG))
    assert 10 < fp / tern < 16.5


# --------------------------------------------------------------------------
# Pallas codec padding helper.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 5, 129, 1000])
def test_pallas_pad_pack_roundtrip(n):
    rng = np.random.default_rng(n)
    it = jnp.asarray(rng.integers(-1, 2, size=(n,)), jnp.int8)
    tiled, count = pad_to_packable(it, lanes=128)
    assert count == n and tiled.shape[0] % 4 == 0 and tiled.shape[1] == 128
    packed = pallas_pack2bit(tiled, interpret=True)
    out = unpack_padded(packed, count, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(it))
