"""Deterministic chaos layer: FaultSchedule reproducibility, the
ChaosProxy's transparent / refuse / kill / throttle behaviors over real
loopback sockets, the server→client pump in isolation, the Byzantine
corrupt mode (re-CRC'd poisoned frames), and the RST abort discipline."""

import socket
import threading

import numpy as np
import pytest

from repro.comm import (
    FT_HELLO,
    FT_UPDATE,
    ChaosProxy,
    FaultConfig,
    FaultSchedule,
    FrameDecoder,
    TransportError,
    pack_frame,
    recv_frame,
    send_frame,
)
from repro.comm.faults import DELAY, KILL, OK, REFUSE, abort_socket
from repro.comm.wire import decode_update_leaves, encode_update

BURSTY = dict(ge_p_good_bad=0.3, ge_p_bad_good=0.3, fault_good=0.05,
              fault_bad=0.8, p_kill=0.5, p_refuse=0.5, delay_s=0.001)


# --------------------------------------------------------------------------
# The schedule: pure, keyed, prefix-stable.
# --------------------------------------------------------------------------


def test_schedule_is_deterministic_per_key():
    cfg = FaultConfig(seed=3, chunk_bytes=64, **BURSTY)
    a = FaultSchedule(cfg, client_id=2, attempt=1)
    b = FaultSchedule(cfg, client_id=2, attempt=1)
    assert a.connect_action() == b.connect_action()
    assert [a.action_at(i) for i in range(32)] \
        == [b.action_at(i) for i in range(32)]


def test_schedule_lazy_fill_is_prefix_stable():
    """Consulting chunk 20 first must produce the SAME stream as consulting
    0..20 in order — a partially-consumed schedule is a prefix of the full
    one, so how far a connection got before dying cannot change history."""
    cfg = FaultConfig(seed=5, chunk_bytes=64, **BURSTY)
    eager = FaultSchedule(cfg, 1, 0)
    in_order = [eager.action_at(i) for i in range(21)]
    lazy = FaultSchedule(cfg, 1, 0)
    assert lazy.action_at(20) == in_order[20]
    assert [lazy.action_at(i) for i in range(21)] == in_order


def test_schedule_keys_decorrelate():
    """Different (client, attempt) keys must draw different weather — with
    bursty rates and 64 chunks, identical streams would mean the key is
    being ignored."""
    cfg = FaultConfig(seed=0, chunk_bytes=64, **BURSTY)
    streams = {
        (cid, att): tuple(FaultSchedule(cfg, cid, att).action_at(i)
                          for i in range(64))
        for cid in range(4) for att in range(2)
    }
    assert len(set(streams.values())) > 1


def test_disabled_schedule_draws_nothing():
    cfg = FaultConfig(seed=9, fault_good=0.0, fault_bad=0.0)
    assert cfg.disabled
    s = FaultSchedule(cfg, 0, 0)
    assert s.connect_action() == OK
    assert all(s.action_at(i) == (OK, 0.0) for i in range(16))
    assert s.first_kill_offset(1 << 20) is None


def test_first_kill_offset_matches_action_stream():
    cfg = FaultConfig(seed=1, chunk_bytes=128, ge_p_good_bad=0.9,
                      ge_p_bad_good=0.1, fault_bad=0.9, p_kill=0.9,
                      p_refuse=0.0)
    found = 0
    for cid in range(8):
        s = FaultSchedule(cfg, cid, 0)
        off = s.first_kill_offset(4096)
        if off is None:
            continue
        found += 1
        idx = off // cfg.chunk_bytes
        assert off == idx * cfg.chunk_bytes
        assert s.action_at(idx)[0] == KILL
        assert all(s.action_at(i)[0] != KILL for i in range(idx))
    assert found > 0       # these rates make kills near-certain somewhere


def test_config_validation():
    with pytest.raises(ValueError, match="fault_bad"):
        FaultConfig(fault_bad=1.5)
    with pytest.raises(ValueError, match="chunk_bytes"):
        FaultConfig(chunk_bytes=0)
    assert 0.0 < FaultConfig(**BURSTY).stationary_p_bad < 1.0


# --------------------------------------------------------------------------
# The proxy over real sockets.
# --------------------------------------------------------------------------


def _upstream_sink():
    """A server that answers any HELLO with FT_UPDATE echoing byte counts;
    records per-connection received byte totals."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(8)
    srv.settimeout(0.1)
    stop = threading.Event()
    received: list[int] = []

    def run():
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            threading.Thread(target=handle, args=(conn,), daemon=True).start()

    def handle(conn):
        n = 0
        conn.settimeout(10)
        try:
            dec = FrameDecoder()
            hello = recv_frame(conn, dec, timeout_s=10)
            n += dec.bytes_in
            send_frame(conn, FT_UPDATE, b"r" * 64,
                       {"echo": hello.meta.get("client_id", -1)})
            while True:
                chunk = conn.recv(1 << 16)
                if not chunk:
                    break
                n += len(chunk)
        except (TransportError, OSError):
            pass
        finally:
            received.append(n)
            conn.close()

    t = threading.Thread(target=run, daemon=True)
    t.start()

    def close():
        stop.set()
        srv.close()
        t.join(timeout=5)

    return srv.getsockname(), received, close


def _hello(cid, attempt=0):
    return pack_frame(FT_HELLO, meta={"client_id": cid, "attempt": attempt,
                                      "proto": 2, "nonce": "ab"})


def test_proxy_is_transparent_when_disabled():
    addr, received, close = _upstream_sink()
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0)
    try:
        with ChaosProxy(addr, cfg) as proxy:
            with socket.create_connection(("127.0.0.1", proxy.port),
                                          timeout=10) as s:
                sent = s.sendall(_hello(1)) or len(_hello(1))
                reply = recv_frame(s, timeout_s=10)
                assert reply.ftype == FT_UPDATE
                assert reply.meta["echo"] == 1
                body = b"x" * 3000
                s.sendall(body)
                s.shutdown(socket.SHUT_WR)
                # wait for the sink to book the connection total
                for _ in range(100):
                    if received:
                        break
                    threading.Event().wait(0.05)
                assert received and received[0] == sent + len(body)
            assert proxy.stats["refused"] == 0
            assert proxy.stats["killed"] == 0
            assert proxy.stats["bytes_up"] == sent + len(body)
    finally:
        close()


def test_proxy_throttle_paces_but_delivers_everything():
    addr, received, close = _upstream_sink()
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0,
                      throttle_bytes=64, throttle_delay_s=0.0005)
    try:
        with ChaosProxy(addr, cfg) as proxy:
            with socket.create_connection(("127.0.0.1", proxy.port),
                                          timeout=10) as s:
                h = _hello(2)
                s.sendall(h)
                assert recv_frame(s, timeout_s=10).ftype == FT_UPDATE
                body = b"y" * 2048
                s.sendall(body)
                s.shutdown(socket.SHUT_WR)
                for _ in range(200):
                    if received:
                        break
                    threading.Event().wait(0.05)
                assert received and received[0] == len(h) + len(body)
    finally:
        close()


def _find_key(cfg, want, nbytes=4096, max_cid=64):
    """First (cid, attempt=0) whose schedule has the wanted behavior."""
    for cid in range(max_cid):
        s = FaultSchedule(cfg, cid, 0)
        if want == REFUSE and s.connect_action() == REFUSE:
            return cid, None
        if want == KILL and s.connect_action() == OK:
            off = s.first_kill_offset(nbytes)
            if off is not None and off > 0:
                return cid, off
    raise AssertionError(f"no {want} key in range — pick other rates")


def test_proxy_refuses_deterministically():
    addr, received, close = _upstream_sink()
    cfg = FaultConfig(seed=2, chunk_bytes=256, **BURSTY)
    cid, _ = _find_key(cfg, REFUSE)
    try:
        with ChaosProxy(addr, cfg) as proxy:
            for _ in range(2):       # same key → refused every time
                with pytest.raises((TransportError, OSError)):
                    with socket.create_connection(
                        ("127.0.0.1", proxy.port), timeout=10
                    ) as s:
                        s.sendall(_hello(cid))
                        recv_frame(s, timeout_s=10)
            assert proxy.stats["refused"] == 2
        assert not received          # nothing ever reached the upstream
    finally:
        close()


def test_proxy_kill_truncates_upload_mid_stream():
    """A KILL chunk resets both directions: the client sees a torn
    connection, the upstream receives at most the bytes before the kill
    offset — a mid-frame truncation, never a clean EOF with a short body."""
    addr, received, close = _upstream_sink()
    cfg = FaultConfig(seed=4, chunk_bytes=256, ge_p_good_bad=0.9,
                      ge_p_bad_good=0.1, fault_bad=0.9, p_kill=0.9,
                      p_refuse=0.0, delay_s=0.0)
    # the kill must land within the first 4096 bytes; the upload is larger
    cid, off = _find_key(cfg, KILL, nbytes=4096)
    try:
        with ChaosProxy(addr, cfg) as proxy:
            h = _hello(cid)
            # the body is a REAL frame: loopback can coalesce it with the
            # HELLO into one recv, and _peek_hello feeds whole chunks to its
            # decoder — raw garbage there would reset the connection before
            # the schedule ever fires (a different, wrong failure).
            body = pack_frame(FT_UPDATE, b"k" * 8000, {"client_id": cid})
            sent = len(h) + len(body)
            assert sent > off
            with pytest.raises((TransportError, OSError)):
                with socket.create_connection(
                    ("127.0.0.1", proxy.port), timeout=10
                ) as s:
                    s.sendall(h)
                    s.sendall(body)
                    s.shutdown(socket.SHUT_WR)
                    # drain until the RST surfaces client-side
                    while True:
                        if not s.recv(1 << 16):
                            raise TransportError("clean EOF (no reply sent)")
            for _ in range(100):
                if received:
                    break
                threading.Event().wait(0.05)
            assert proxy.stats["killed"] >= 1
            assert received and received[0] <= off
            assert received[0] < sent
    finally:
        close()


def test_proxy_resets_on_garbage_first_bytes():
    """Bytes that never parse into a frame cannot be attributed to a
    schedule key — the proxy resets instead of forwarding them."""
    addr, received, close = _upstream_sink()
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0)
    try:
        with ChaosProxy(addr, cfg) as proxy:
            with pytest.raises((TransportError, OSError)):
                with socket.create_connection(
                    ("127.0.0.1", proxy.port), timeout=10
                ) as s:
                    s.sendall(b"GET / HTTP/1.1\r\n\r\n" + b"\x00" * 32)
                    recv_frame(s, timeout_s=10)
        assert not received
    finally:
        close()


def test_abort_socket_sends_rst_not_fin():
    """abort_socket must surface at the peer as a reset (torn), never as a
    clean EOF a decoder could mistake for a frame boundary."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    client = socket.create_connection(("127.0.0.1", port), timeout=10)
    conn, _ = srv.accept()
    try:
        conn.sendall(b"half a frame")
        abort_socket(conn)
        client.settimeout(10)
        with pytest.raises(OSError):
            # drain: the buffered bytes may arrive, then the RST must raise
            while True:
                data = client.recv(1 << 16)
                assert data, "peer saw clean EOF — abort sent FIN, not RST"
    finally:
        client.close()
        srv.close()


def test_delay_action_is_counted_and_harmless():
    """DELAY chunks slow delivery but change no bytes: a key whose stream
    has delays (and no kill) must still deliver everything."""
    cfg = FaultConfig(seed=6, chunk_bytes=128, ge_p_good_bad=0.9,
                      ge_p_bad_good=0.1, fault_bad=0.9, p_kill=0.0,
                      p_refuse=0.0, delay_s=0.001)
    cid = None
    for c in range(32):
        s = FaultSchedule(cfg, c, 0)
        if s.connect_action() == OK and any(
            s.action_at(i)[0] == DELAY for i in range(8)
        ):
            cid = c
            break
    assert cid is not None
    addr, received, close = _upstream_sink()
    try:
        with ChaosProxy(addr, cfg) as proxy:
            h = _hello(cid)
            with socket.create_connection(("127.0.0.1", proxy.port),
                                          timeout=10) as s:
                s.sendall(h)
                assert recv_frame(s, timeout_s=10).ftype == FT_UPDATE
                s.sendall(b"d" * 900)
                s.shutdown(socket.SHUT_WR)
                for _ in range(200):
                    if received:
                        break
                    threading.Event().wait(0.05)
            assert received and received[0] == len(h) + 900
            assert proxy.stats["delayed_chunks"] >= 1
    finally:
        close()


# --------------------------------------------------------------------------
# _pump_down in isolation: the server→client direction over socketpairs.
# --------------------------------------------------------------------------


def _idle_proxy():
    """A proxy whose acceptor never fires — just a stats/_stop carrier for
    driving the pumps directly over socketpairs."""
    return ChaosProxy(("127.0.0.1", 1), FaultConfig(fault_good=0.0,
                                                    fault_bad=0.0))


def test_pump_down_forwards_bytes_and_half_close():
    """Server bytes flow to the client verbatim (booked in bytes_down) and
    the upstream's clean EOF is forwarded as a SHUT_WR half-close, not a
    hard reset — the client can still finish reading buffered frames."""
    up_pump, up_srv = socket.socketpair()
    cn_pump, cn_cli = socket.socketpair()
    with _idle_proxy() as proxy:
        killed = threading.Event()
        t = threading.Thread(target=proxy._pump_down,
                             args=(up_pump, cn_pump, killed), daemon=True)
        t.start()
        body = b"s" * 5000
        up_srv.sendall(body)
        up_srv.shutdown(socket.SHUT_WR)
        got = bytearray()
        cn_cli.settimeout(10)
        while True:
            chunk = cn_cli.recv(1 << 16)
            if not chunk:          # the forwarded half-close, a clean EOF
                break
            got += chunk
        t.join(timeout=10)
        assert not t.is_alive()
        assert bytes(got) == body
        assert proxy.stats["bytes_down"] == len(body)
    for s in (up_pump, up_srv, cn_pump, cn_cli):
        s.close()


def test_pump_down_stops_on_killed_without_forwarding():
    """A KILL elsewhere sets the event; the pump must exit at its next poll
    and forward nothing more — the reset owns both directions."""
    up_pump, up_srv = socket.socketpair()
    cn_pump, cn_cli = socket.socketpair()
    with _idle_proxy() as proxy:
        killed = threading.Event()
        killed.set()               # the kill landed before the pump started
        up_srv.sendall(b"too late" * 64)
        t = threading.Thread(target=proxy._pump_down,
                             args=(up_pump, cn_pump, killed), daemon=True)
        t.start()
        t.join(timeout=10)
        assert not t.is_alive()
        assert proxy.stats["bytes_down"] == 0
        cn_cli.setblocking(False)
        with pytest.raises(BlockingIOError):
            cn_cli.recv(1)         # nothing was forwarded client-side
    for s in (up_pump, up_srv, cn_pump, cn_cli):
        s.close()


def test_pump_down_survives_already_dead_upstream():
    """An upstream socket a KILL already closed raises on the very first
    settimeout — the pump must return, never propagate."""
    up_pump, up_srv = socket.socketpair()
    cn_pump, cn_cli = socket.socketpair()
    up_pump.close()                # simulates abort_socket racing the pump
    with _idle_proxy() as proxy:
        proxy._pump_down(up_pump, cn_pump, threading.Event())   # no raise
        assert proxy.stats["bytes_down"] == 0
    for s in (up_srv, cn_pump, cn_cli):
        s.close()


# --------------------------------------------------------------------------
# Byzantine corrupt mode: poisoned but wire-valid frames.
# --------------------------------------------------------------------------


def _frame_sink():
    """An upstream that decodes every frame off one connection and records
    (ftype, payload, meta) — the server-eye view of proxied traffic."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(8)
    srv.settimeout(0.1)
    stop = threading.Event()
    frames: list = []

    def run():
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            conn.settimeout(10)
            dec = FrameDecoder()
            try:
                while True:
                    chunk = conn.recv(1 << 16)
                    if not chunk:
                        break
                    for f in dec.feed(chunk):
                        frames.append((f.ftype, f.payload, dict(f.meta)))
            except (TransportError, OSError):
                pass
            finally:
                conn.close()

    t = threading.Thread(target=run, daemon=True)
    t.start()

    def close():
        stop.set()
        srv.close()
        t.join(timeout=5)

    return srv.getsockname(), frames, close


def _send_update_via_proxy(proxy_port, cid, payload):
    with socket.create_connection(("127.0.0.1", proxy_port), timeout=10) as s:
        s.sendall(_hello(cid))
        s.sendall(pack_frame(FT_UPDATE, payload,
                             {"client_id": cid, "weight": 3.0}))
        s.shutdown(socket.SHUT_WR)


def _wait(frames, n, tries=200):
    for _ in range(tries):
        if len(frames) >= n:
            return
        threading.Event().wait(0.05)
    raise AssertionError(f"sink saw {len(frames)} frames, wanted {n}")


def test_corrupt_mode_poisons_update_but_stays_wire_valid():
    """A corrupt_clients member's UPDATE is decoded in-path, sign-flipped,
    and re-packed with a fresh CRC: the upstream parses a perfectly valid
    frame whose CONTENT is the negation of what the client sent. The HELLO
    and the frame meta ride through untouched."""
    addr, frames, close = _frame_sink()
    honest = np.arange(8, dtype=np.float32) + 1.0
    payload = encode_update({"w": honest})
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0,
                      corrupt_clients=(3,), corrupt_kind="sign_flip",
                      corrupt_seed=5)
    try:
        with ChaosProxy(addr, cfg) as proxy:
            _send_update_via_proxy(proxy.port, 3, payload)
            _wait(frames, 2)
            assert proxy.stats["corrupted_frames"] == 1
        hello_f, update_f = frames[0], frames[1]
        assert hello_f[0] == FT_HELLO
        assert hello_f[2]["client_id"] == 3      # attribution untouched
        assert update_f[0] == FT_UPDATE
        assert update_f[2]["weight"] == 3.0
        assert update_f[1] != payload            # content was poisoned...
        pairs = decode_update_leaves(update_f[1])   # ...but decodes cleanly
        (path, leaf), = pairs
        assert path.endswith("w")
        np.testing.assert_array_equal(np.asarray(leaf), -honest)
    finally:
        close()


def test_corrupt_mode_leaves_other_clients_byte_identical():
    addr, frames, close = _frame_sink()
    payload = encode_update({"w": np.ones(16, np.float32)})
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0,
                      corrupt_clients=(3,), corrupt_kind="sign_flip")
    try:
        with ChaosProxy(addr, cfg) as proxy:
            _send_update_via_proxy(proxy.port, 7, payload)   # not in the set
            _wait(frames, 2)
            assert proxy.stats["corrupted_frames"] == 0
        assert frames[1][0] == FT_UPDATE
        assert frames[1][1] == payload           # byte-for-byte untouched
    finally:
        close()


def test_corrupt_kind_validated_at_config_time():
    with pytest.raises(ValueError, match="corrupt_kind"):
        FaultConfig(corrupt_clients=(1,), corrupt_kind="frobnicate")
    # no corrupt clients ⇒ the kind is never consulted
    FaultConfig(corrupt_clients=(), corrupt_kind="frobnicate")
