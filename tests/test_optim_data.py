"""Optimizer + data-substrate unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    emd_to_global, partition_iid, partition_noniid, partition_unbalanced,
    synthetic_classification, synthetic_tokens, token_batches,
)
from repro.optim import (
    adam, adamw, apply_updates, clip_by_global_norm, cosine_schedule,
    global_norm, momentum, sgd, warmup_cosine_schedule,
)


def _quad_losses(opt, steps=60):
    """Minimize ||x||² from x0=1; returns the loss trace."""
    params = {"x": jnp.ones((8,))}
    state = opt.init(params)
    trace = []
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        trace.append(float(jnp.sum(params["x"] ** 2)))
    return trace


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adam(0.1), adamw(0.1)])
def test_optimizers_converge_quadratic(opt):
    trace = _quad_losses(opt)
    assert trace[-1] < 0.05 * trace[0]


def test_adam_bias_correction_first_step():
    opt = adam(1e-1)
    params = {"x": jnp.ones((2,))}
    st = opt.init(params)
    g = {"x": jnp.full((2,), 0.5)}
    upd, st = opt.update(g, st, params)
    # first Adam step ≈ -lr·sign(g)
    np.testing.assert_allclose(np.asarray(upd["x"]), -0.1, rtol=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_schedules():
    lr = warmup_cosine_schedule(1.0, warmup=10, total_steps=110)
    assert float(lr(jnp.asarray(0))) < 0.2
    assert float(lr(jnp.asarray(9))) == pytest.approx(1.0, rel=1e-6)
    assert float(lr(jnp.asarray(109))) < 0.2
    c = cosine_schedule(2.0, 100, final_frac=0.5)
    assert float(c(jnp.asarray(0))) == pytest.approx(2.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(1.0)


def test_partition_sizes_and_emd():
    x, y = synthetic_classification(jax.random.PRNGKey(0), 1000, 10, 32)
    iid = partition_iid(x, y, 10)
    assert sum(len(c) for c in iid) == 1000
    noniid = partition_noniid(x, y, 10, 2)
    assert emd_to_global(noniid, 10) > emd_to_global(iid, 10)


@pytest.mark.parametrize("beta", [0.1, 0.5, 1.0])
def test_unbalanced_beta(beta):
    x, y = synthetic_classification(jax.random.PRNGKey(1), 2000, 10, 16)
    parts = partition_unbalanced(x, y, 10, beta)
    sizes = sorted(len(c) for c in parts)
    assert sum(sizes) == 2000
    med = float(np.median(sizes)); mx = float(max(sizes))
    assert med / mx == pytest.approx(beta, abs=0.12)


def test_token_stream_and_batches():
    toks = synthetic_tokens(jax.random.PRNGKey(2), 5000, vocab=50)
    assert toks.min() >= 0 and toks.max() < 50
    it = token_batches(toks, batch=4, seq=16)
    b1, cur1 = next(it)
    assert b1["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"][:, 1:]), np.asarray(b1["labels"][:, :-1])
    )
    # cursor resume: restart iterator at cur1 → same second batch
    b2, _ = next(it)
    it2 = token_batches(toks, batch=4, seq=16, start=cur1)
    b2r, _ = next(it2)
    np.testing.assert_array_equal(np.asarray(b2["tokens"]), np.asarray(b2r["tokens"]))
