"""Fleet-scale simulation: EventHeap vs heapq total-order parity, batched
channel draws vs the scalar stream, vectorized TraceReplay vs a per-client
reference, and end-to-end ``run_fleet`` rounds (flat / 2-tier / async /
compat) with the byte ledger balanced."""

import heapq

import numpy as np
import pytest

from repro.comm import Channel, ChannelConfig
from repro.fed import FedConfig, FleetConfig, HierarchyConfig, run_fleet
from repro.fed.availability import AvailabilityConfig, TraceReplay
from repro.fed.fleet import EventHeap


# --------------------------------------------------------------------------
# EventHeap.
# --------------------------------------------------------------------------


def test_event_heap_matches_heapq_order():
    """Random interleaving of push / push_many / pop: pop order is the
    exact (time, seq) total order heapq produces — ties included."""
    rng = np.random.default_rng(0)
    heap = EventHeap(capacity=2)
    ref: list = []
    seq = 0
    popped, popped_ref = [], []
    for _ in range(300):
        op = rng.integers(3)
        if op == 0:
            t = float(rng.integers(10))        # coarse times force seq ties
            heap.push(t, ("p", seq))
            heapq.heappush(ref, (t, seq, ("p", seq)))
            seq += 1
        elif op == 1:
            k = int(rng.integers(1, 6))
            ts = rng.integers(10, size=k).astype(np.float64)
            heap.push_many(ts, [("m", seq + i) for i in range(k)])
            for i, t in enumerate(ts):
                heapq.heappush(ref, (float(t), seq + i, ("m", seq + i)))
            seq += k
        elif ref:
            popped.append(heap.pop())
            popped_ref.append(heapq.heappop(ref))
    while ref:
        popped.append(heap.pop())
        popped_ref.append(heapq.heappop(ref))
    assert popped == popped_ref
    assert len(heap) == 0


def test_event_heap_guards_and_growth():
    heap = EventHeap(capacity=1)
    with pytest.raises(IndexError):
        heap.pop()
    with pytest.raises(IndexError):
        heap.peek_time()
    with pytest.raises(ValueError, match="payloads"):
        heap.push_many(np.array([1.0, 2.0]), ["only-one"])
    heap.push_many(np.empty(0), [])        # no-op
    for i in range(40):                    # grows far past capacity=1
        heap.push(float(40 - i), i)
    assert len(heap) == 40
    assert heap.peek_time() == 1.0
    assert [heap.pop()[2] for _ in range(40)] == list(range(40))[::-1]


# --------------------------------------------------------------------------
# Batched channel draws.
# --------------------------------------------------------------------------


def _chan(seed=0, n=16, **kw):
    return Channel(ChannelConfig(**kw), n, seed=seed)


def test_transfer_batch_lossless_stream_identical_to_scalar():
    """With loss off, one batched call consumes the rng stream exactly like
    N sequential scalar transfers — seconds bit-identical."""
    ids = np.array([3, 0, 7, 7, 12])
    nbytes = np.array([1000, 50_000, 0, 777, 123_456])
    a, b = _chan(seed=5), _chan(seed=5)
    scalar = np.array([a.transfer(int(k), int(n), "up")
                       for k, n in zip(ids, nbytes)])
    batched = b.transfer_batch(ids, nbytes, "up")
    np.testing.assert_array_equal(scalar, batched)
    # and the NEXT draw still agrees (stream position identical)
    np.testing.assert_array_equal(
        a.transfer(1, 10, "down"), b.transfer_batch([1], [10], "down")[0]
    )


def test_transfer_batch_compat_matches_scalar_under_loss():
    """Under loss the batched geometric fold reorders the stream, so
    compat=True routes through the scalar path — bit-exact legacy runs."""
    kw = dict(loss_rate=0.3, chunk_bytes=1024)
    ids = np.array([0, 2, 5])
    nbytes = np.array([10_000, 3_000, 100_000])
    a, b = _chan(seed=9, **kw), _chan(seed=9, **kw)
    scalar = np.array([a.transfer(int(k), int(n), "up")
                       for k, n in zip(ids, nbytes)])
    np.testing.assert_array_equal(
        scalar, b.transfer_batch(ids, nbytes, "up", compat=True)
    )
    sa, sb = a.summary(), b.summary()
    assert sa["retrans_bytes"] == sb["retrans_bytes"]
    assert sa["retries"] == sb["retries"]


def test_transfer_batch_single_lossy_matches_scalar():
    """A size-1 lossy batch draws the same chunks as one scalar transfer."""
    kw = dict(loss_rate=0.4, chunk_bytes=512)
    a, b = _chan(seed=3, **kw), _chan(seed=3, **kw)
    for nb in (100, 512, 5000, 0):
        np.testing.assert_array_equal(
            a.transfer(4, nb, "up"),
            b.transfer_batch([4], [nb], "up")[0],
        )


def test_transfer_batch_ledger_merges_into_summary():
    ch = _chan(seed=1, loss_rate=0.2, chunk_bytes=256)
    ch.transfer(0, 4096, "up")                       # scalar event
    ch.transfer_batch([1, 2, 3], [4096] * 3, "up")   # batched ledger
    s = ch.summary()
    assert s["n_transfers"] == 4
    assert s["total_bytes"] == 4 * 4096
    assert 0 < s["goodput_fraction"] <= 1.0
    assert s["p95_seconds"] >= s["mean_seconds"] > 0


def test_transfer_batch_share_nic_caps_rate():
    """share_nic splits the server NIC across the batch: N simultaneous
    flows through a tight NIC take ~N× a lone transfer's data phase."""
    kw = dict(server_bandwidth_bytes_s=1e6, bandwidth_sigma=0.0,
              latency_jitter_s=0.0)
    lone = _chan(seed=2, **kw).transfer_batch([0], [1_000_000], "down",
                                              share_nic=True)[0]
    ch = _chan(seed=2, **kw)
    shared = ch.transfer_batch(np.arange(10), [1_000_000] * 10, "down",
                               share_nic=True)
    assert shared.min() > 5 * lone


def test_compute_time_batch_matches_scalar():
    ch = _chan(seed=7)
    ids = np.array([0, 3, 9])
    batched = ch.compute_time_batch(ids, np.array([100, 250, 400]))
    scalar = [ch.compute_time(int(k), n)
              for k, n in zip(ids, (100, 250, 400))]
    np.testing.assert_array_equal(batched, np.array(scalar))


# --------------------------------------------------------------------------
# Vectorized TraceReplay.
# --------------------------------------------------------------------------


def _mask_reference(trace, t):
    tf = t % trace.horizon_s
    return np.array([
        int(np.searchsorted(s, tf, side="right")) % 2 == 1
        for s in trace.schedules
    ])


def test_trace_replay_mask_matches_per_client_reference():
    trace = TraceReplay.generate(50, mean_on_s=30.0, mean_off_s=20.0,
                                 horizon_s=500.0, seed=4)
    for t in (0.0, 17.3, 250.0, 499.99, 731.4, 1500.0):
        np.testing.assert_array_equal(
            trace.available_mask(t), _mask_reference(trace, t), err_msg=str(t)
        )


def test_trace_replay_next_change_is_first_boundary():
    trace = TraceReplay([np.array([5.0, 10.0]), np.array([2.0, 8.0, 12.0])],
                        horizon_s=20.0)
    assert trace.next_change(0.0) == 2.0
    assert trace.next_change(2.0) == 5.0
    assert trace.next_change(12.0) == 20.0          # wrap is a change point
    assert trace.next_change(25.0) == 28.0          # folded: tf=5 → 8
    # the mask genuinely flips at every reported change point
    t = 0.0
    for _ in range(12):
        t2 = trace.next_change(t)
        assert not np.array_equal(trace.available_mask(t2),
                                  trace.available_mask(t2 - 1e-6)) or \
            (t2 % trace.horizon_s) == 0.0
        t = t2


def test_trace_replay_empty_schedule_client_never_online():
    trace = TraceReplay([np.array([1.0, 9.0]), np.empty(0)], horizon_s=10.0)
    mask = trace.available_mask(5.0)
    assert mask.tolist() == [True, False]


# --------------------------------------------------------------------------
# run_fleet end to end.
# --------------------------------------------------------------------------


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "dense": {"w": rng.standard_normal((64, 32)).astype(np.float32),
                  "b": np.zeros(32, np.float32)},
        "head": {"w": rng.standard_normal((32, 10)).astype(np.float32)},
    }


def _fed(**kw):
    base = dict(n_clients=2000, rounds=2, participation=0.05,
                availability=AvailabilityConfig(kind="diurnal"))
    base.update(kw)
    return FedConfig(**base)


def test_fleet_sync_flat_round():
    res = run_fleet(_params(), _fed())
    assert res.rounds_run == 2
    assert res.participants_per_round[0] + res.dropped_per_round[0] == 100
    assert res.upload_bytes > 0 and res.download_bytes > 0
    assert res.total_time_s > 0
    assert res.final_update is not None
    assert res.telemetry["transfer_summary"]["n_transfers"] > 0


def test_fleet_sync_tier_ledger_and_root_bytes():
    flat = run_fleet(_params(), _fed(seed=1))
    tier = run_fleet(_params(), _fed(seed=1,
                                     hierarchy=HierarchyConfig(n_edges=8)))
    hier = tier.telemetry["hierarchy"]
    assert hier["ledger_balanced"]
    assert hier["folds"] == 2
    # same seed → same participants/draws; the tier books the same
    # client→edge bytes as the flat run's total upload.
    assert hier["client_to_edge_bytes"] == flat.upload_bytes
    assert tier.upload_bytes == (hier["client_to_edge_bytes"]
                                 + hier["edge_to_root_bytes"])
    # the root hop is one record per ACTIVE edge — far under the fan-in.
    assert 0 < hier["edge_to_root_bytes"] < hier["client_to_edge_bytes"]
    assert sum(1 for c in hier["clients_per_edge"] if c) <= 8


def test_fleet_sync_compat_matches_vectorized_when_lossless():
    """Lossless draws are stream-compatible: the compat (scalar call order)
    fleet and the vectorized fleet produce identical rounds."""
    a = run_fleet(_params(), _fed(n_clients=200, participation=0.1),
                  FleetConfig(compat=False, share_nic=False))
    b = run_fleet(_params(), _fed(n_clients=200, participation=0.1),
                  FleetConfig(compat=True, share_nic=False))
    assert a.round_times == b.round_times
    assert a.upload_bytes == b.upload_bytes
    assert a.participants_per_round == b.participants_per_round


def test_fleet_sync_deadline_drops_stragglers():
    res = run_fleet(
        _params(),
        _fed(channel=ChannelConfig(deadline_s=0.3, bandwidth_sigma=2.0,
                                   compute_speed_sigma=1.0)),
    )
    assert sum(res.dropped_per_round) > 0
    assert all(p >= 1 for p in res.participants_per_round)


def test_fleet_async_folds_and_staleness():
    res = run_fleet(
        _params(),
        _fed(mode="async", rounds=3, buffer_k=16, max_concurrency=64,
             hierarchy=HierarchyConfig(n_edges=4)),
    )
    assert res.rounds_run == 3
    assert res.participants_per_round == [16, 16, 16]
    assert res.telemetry["hierarchy"]["ledger_balanced"]
    assert len(res.telemetry["staleness_hist"]) >= 1
    assert res.upload_bytes > 0


def test_fleet_async_staleness_drop_policy():
    res = run_fleet(
        _params(),
        _fed(mode="async", rounds=4, buffer_k=8, max_concurrency=128,
             max_staleness=1, staleness_policy="drop"),
    )
    dropped = res.telemetry["dropped_updates"]
    assert res.rounds_run == 4
    # arrivals lagging more than one fold are dropped but their wire
    # bytes are still billed
    assert res.telemetry["dropped_update_bytes"] >= dropped > 0


def test_fleet_trace_availability_runs():
    res = run_fleet(
        _params(),
        _fed(n_clients=300, availability=AvailabilityConfig(
            kind="trace", mean_on_s=60.0, mean_off_s=30.0, horizon_s=600.0)),
    )
    assert res.rounds_run == 2
    assert all(p >= 1 for p in res.participants_per_round)


def test_fleet_rejects_unknown_mode():
    with pytest.raises(ValueError, match="mode"):
        run_fleet(_params(), _fed(mode="semi-sync"))
