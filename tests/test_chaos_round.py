"""Chaos acceptance: with a FIXED fault seed injecting refused connects,
delays, mid-frame truncation (forcing reconnect + resume) and one client
crash, a quorum round must commit with a root aggregate sha256-identical to
the in-process reference restricted to the surviving client set — in both
sync and buffered-async modes — and the update-byte ledger must balance.

These rounds spawn real client OS processes through a real in-path
ChaosProxy, so they share test_mp_server's generous-but-finite budget."""

import multiprocessing as mp
import signal
import time

import pytest

from repro.fed.mp_server import (
    QuorumNotMetError,
    default_chaos,
    demo_params,
    params_hash,
    reap_processes,
    run_inprocess_reference,
    run_socket_round,
)

pytestmark = pytest.mark.skipif(
    "spawn" not in mp.get_all_start_methods(),
    reason="platform lacks multiprocessing spawn start method",
)

TIMEOUT_S = 300.0
N_CLIENTS = 6
SEED = 7
CHAOS_SEED = 19   # reachable mid-frame kills + a refused connect (see CLI)


@pytest.fixture(scope="module")
def chaos_sync_round():
    params = demo_params(seed=SEED)
    cfg = default_chaos(seed=CHAOS_SEED, n_clients=N_CLIENTS)
    res = run_socket_round(params, N_CLIENTS, seed=SEED, mode="sync",
                           quorum_frac=0.5, timeout_s=TIMEOUT_S,
                           fault_cfg=cfg)
    return params, res


def test_chaos_sync_byte_identical_to_surviving_reference(chaos_sync_round):
    params, res = chaos_sync_round
    assert res.n_survivors >= res.quorum_n
    ref = run_inprocess_reference(params, N_CLIENTS, seed=SEED, mode="sync",
                                  order=sorted(res.arrivals))
    assert params_hash(res.params) == params_hash(ref)


def test_chaos_survivor_set_is_deterministic(chaos_sync_round):
    """The fault schedule is keyed by (seed, client, attempt) at byte
    offsets — which clients land is a pure function of the seeds, not of
    thread timing. Seed 19's only casualty is the injected crash client."""
    _params, res = chaos_sync_round
    assert sorted(res.arrivals) == [0, 1, 2, 3, 4]
    assert res.outcomes[N_CLIENTS - 1] == "crashed"
    assert all(res.outcomes[cid] == "ok" for cid in range(N_CLIENTS - 1))


def test_chaos_exercised_retry_and_resume(chaos_sync_round):
    """Seed 19 has a reachable mid-frame kill followed by a clean attempt:
    the round must have actually used reconnect (retries) and mid-frame
    resume (resumed_bytes — upload bytes NOT re-sent after a truncation)."""
    _params, res = chaos_sync_round
    assert res.retries >= 1
    assert res.resumed_bytes > 0
    assert res.chaos is not None
    assert res.chaos["killed"] >= 1
    assert res.chaos["refused"] >= 1


def test_chaos_ledger_balances_and_books_the_crash(chaos_sync_round):
    _params, res = chaos_sync_round
    led = res.ledger()
    assert led["balance_ok"]
    assert led["committed"] == "quorum"
    # the crash client shipped a prefix of its update: paid-for, never used
    assert res.dropped_update_bytes > 0
    assert res.shipped_update_bytes \
        == res.ingested_update_bytes + res.dropped_update_bytes
    # outcomes cover every client exactly once
    assert sorted(led["outcomes"]) == [str(c) for c in range(N_CLIENTS)]


def test_chaos_buffered_byte_identical_in_arrival_order():
    params = demo_params(seed=SEED + 2)
    cfg = default_chaos(seed=CHAOS_SEED, n_clients=N_CLIENTS)
    res = run_socket_round(params, N_CLIENTS, seed=SEED + 2, mode="buffered",
                           buffer_k=3, eta=0.5, quorum_frac=0.5,
                           timeout_s=TIMEOUT_S, fault_cfg=cfg)
    assert res.n_survivors >= res.quorum_n
    ref = run_inprocess_reference(params, N_CLIENTS, seed=SEED + 2,
                                  mode="buffered", buffer_k=3, eta=0.5,
                                  order=res.arrivals)
    assert params_hash(res.params) == params_hash(ref)
    assert res.ledger()["balance_ok"]


def test_mixed_legacy_and_rejected_clients():
    """Version negotiation end-to-end: a v1 (PR-7) client still lands, a
    client announcing an unsupported proto is rejected (not retried into),
    and the aggregate matches the reference over the survivors."""
    from repro.comm.faults import FaultConfig

    params = demo_params(seed=SEED + 3)
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0,   # transparent proxy
                      bad_proto_clients=(2,))
    res = run_socket_round(params, 4, seed=SEED + 3, mode="sync",
                           quorum_frac=0.5, timeout_s=TIMEOUT_S,
                           fault_cfg=cfg, legacy_clients=(1,))
    assert sorted(res.arrivals) == [0, 1, 3]
    assert res.outcomes == {0: "ok", 1: "ok", 2: "rejected", 3: "ok"}
    ref = run_inprocess_reference(params, 4, seed=SEED + 3, mode="sync",
                                  order=sorted(res.arrivals))
    assert params_hash(res.params) == params_hash(ref)
    assert res.ledger()["balance_ok"]


def test_quorum_not_met_raises():
    """Every client crashing before upload with quorum_frac=1.0 must fail
    the round loudly (and promptly — the process watcher sees the exits,
    it does not wait out the deadline)."""
    from repro.comm.faults import FaultConfig

    params = demo_params(seed=SEED)
    cfg = FaultConfig(fault_good=0.0, fault_bad=0.0,
                      crash_clients=(0, 1), crash_after_frac=0.1)
    with pytest.raises(QuorumNotMetError, match="crashed"):
        run_socket_round(params, 2, seed=SEED, quorum_frac=1.0,
                         timeout_s=TIMEOUT_S, fault_cfg=cfg)


# --------------------------------------------------------------------------
# Process reaping (the orphan-leak fix).
# --------------------------------------------------------------------------


def _sleepy():
    time.sleep(120)


def _stubborn():
    signal.signal(signal.SIGTERM, signal.SIG_IGN)
    while True:
        time.sleep(1)


@pytest.mark.skipif("fork" not in mp.get_all_start_methods(),
                    reason="escalation test uses fork for plain targets")
def test_reap_escalates_terminate_then_kill():
    ctx = mp.get_context("fork")
    sleepy = ctx.Process(target=_sleepy, daemon=True)
    stubborn = ctx.Process(target=_stubborn, daemon=True)
    sleepy.start()
    stubborn.start()
    esc = reap_processes([sleepy, stubborn], grace_s=0.5)
    assert not sleepy.is_alive()
    assert not stubborn.is_alive()        # SIGKILL is not ignorable
    assert esc["terminated"] == 2         # neither exited in the grace
    assert esc["killed"] == 1             # only the SIGTERM-ignorer needed it


def test_reap_no_escalation_for_clean_children():
    ctx = mp.get_context("fork")
    procs = [ctx.Process(target=time.sleep, args=(0.01,)) for _ in range(3)]
    for p in procs:
        p.start()
    esc = reap_processes(procs, grace_s=10.0)
    assert esc == {"terminated": 0, "killed": 0}
    assert all(p.exitcode == 0 for p in procs)
