"""Wire robustness: a FROZEN v1 buffer that must keep decoding under the v2
codec, and a corruption fuzz — truncated / bit-flipped buffers must always
raise WireError (never a wrong tree, never a non-WireError exception)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import WireError, decode_update, encode_update
from repro.comm.wire import _HEADER, SUPPORTED_VERSIONS, WIRE_VERSION
from repro.core import CodecSpec, compress_pytree
from repro.core.ternary import encode_ternary

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "wire_v1_update.bin")


# --------------------------------------------------------------------------
# v1 compatibility.
# --------------------------------------------------------------------------


def test_frozen_v1_buffer_decodes_under_v2():
    """The committed v1 capture (RAW + TERNARY records, version field 1)
    must decode bit-exactly forever. Regenerating it is NOT a fix — a
    failure here means stored checkpoints/captures broke."""
    with open(FIXTURE, "rb") as f:
        blob = f.read()
    assert _HEADER.unpack_from(blob)[1] == 1  # genuinely a v1 buffer
    tree = decode_update(blob)

    # expected content, rebuilt with the fixture's generation seed
    rng = np.random.default_rng(42)
    i_t0 = rng.integers(-1, 2, size=(17, 9)).astype(np.int8)
    b0 = np.arange(7, dtype=np.float32) / 8.0
    i_t1 = rng.integers(-1, 2, size=(33,)).astype(np.int8)
    b1 = rng.normal(size=(3, 2)).astype(np.float32)
    head = rng.integers(0, 100, size=(4,)).astype(np.int32)

    np.testing.assert_array_equal(np.asarray(tree["blocks"][0]["w"].ternary()), i_t0)
    assert float(tree["blocks"][0]["w"].w_q) == 0.625
    np.testing.assert_array_equal(np.asarray(tree["blocks"][0]["b"]), b0)
    np.testing.assert_array_equal(np.asarray(tree["blocks"][1]["w"].ternary()), i_t1)
    assert tree["blocks"][1]["w"].dtype == "bfloat16"
    np.testing.assert_array_equal(np.asarray(tree["blocks"][1]["b"]), b1)
    np.testing.assert_array_equal(np.asarray(tree["head"]), head)


def test_v2_only_record_kinds_rejected_in_v1_buffer():
    """A v1 header carrying a v2-only record (DOWNCAST/TOPK) is malformed —
    old decoders would choke on it, so ours must refuse to produce it
    silently."""
    tree, _ = compress_pytree(
        {"b": jnp.arange(6.0)}, CodecSpec(kind="none", residual="fp16")
    )
    blob = encode_update(tree)
    magic, ver, fl, n, crc, bl = _HEADER.unpack_from(blob)
    assert ver == 2  # downcast still stamps its v2 minimum
    v1 = _HEADER.pack(magic, 1, fl, n, crc, bl) + blob[_HEADER.size:]
    with pytest.raises(WireError, match="requires wire v2"):
        decode_update(v1)


def test_supported_versions_contract():
    assert SUPPORTED_VERSIONS == (1, 2, 3)
    assert WIRE_VERSION == 3


def test_minimal_version_stamping():
    """RAW/TERNARY-only traffic stays v1 (old readers keep decoding it);
    the header bumps only as far as the newest record present requires
    (downcast → v2, delta-top-k → v3)."""
    raw_only = encode_update({"w": jnp.ones((4, 4))})
    assert _HEADER.unpack_from(raw_only)[1] == 1
    tern = encode_update({"w": encode_ternary(
        jnp.asarray([1, -1, 0, 1], jnp.int8), jnp.float32(0.5))})
    assert _HEADER.unpack_from(tern)[1] == 1
    half, _ = compress_pytree({"b": jnp.arange(6.0)},
                              CodecSpec(kind="none", residual="fp16"))
    assert _HEADER.unpack_from(encode_update(half))[1] == 2
    sparse, _ = compress_pytree({"b": jnp.arange(24.0)},
                                CodecSpec(kind="none", residual="topk"))
    assert _HEADER.unpack_from(encode_update(sparse))[1] == 3


# --------------------------------------------------------------------------
# TOPK_DELTA (v3): varint-delta indices.
# --------------------------------------------------------------------------


def _topk_leaf(indices, n, seed=3):
    from repro.core.compression import TopKTensor

    rng = np.random.default_rng(seed)
    idx = np.asarray(indices, np.uint32)
    return TopKTensor(
        indices=jnp.asarray(idx),
        values=jnp.asarray(rng.normal(size=idx.shape).astype(np.float32)),
        shape=(n,), dtype="float32",
    )


def test_topk_delta_roundtrip():
    """Sorted u32 indices → varint gaps → bit-exact decode, including index
    0, dense runs (gap 1), and gaps needing multi-byte varints."""
    idx = [0, 1, 2, 130, 16512, 2097300]
    t = _topk_leaf(idx, 1 << 22)
    blob = encode_update({"x": t})
    assert _HEADER.unpack_from(blob)[1] == 3
    back = decode_update(blob)["x"]
    np.testing.assert_array_equal(np.asarray(back.indices), np.asarray(t.indices))
    np.testing.assert_array_equal(np.asarray(back.values), np.asarray(t.values))
    assert back.shape == t.shape and back.dtype == t.dtype


def test_topk_delta_smaller_than_raw_u32():
    """At 10% density the gaps are small → ≪ 4 B/index on the wire."""
    rng = np.random.default_rng(7)
    n = 10_000
    idx = np.sort(rng.choice(n, size=n // 10, replace=False)).astype(np.uint32)
    t = _topk_leaf(idx, n)
    blob = encode_update({"x": t})
    raw_index_bytes = 4 * idx.size
    non_value_bytes = len(blob) - 4 * idx.size   # framing + varint stream
    assert non_value_bytes < raw_index_bytes // 2


def test_topk_delta_fuzz_roundtrip():
    """Random sorted index sets of every density round-trip bit-exactly."""
    rng = np.random.default_rng(11)
    for n, k in ((1, 1), (5, 3), (257, 17), (4096, 1000), (4096, 4096)):
        idx = np.sort(rng.choice(n, size=k, replace=False)).astype(np.uint32)
        t = _topk_leaf(idx, n, seed=int(k))
        back = decode_update(encode_update({"x": t}))["x"]
        np.testing.assert_array_equal(np.asarray(back.indices), idx)


def test_topk_delta_rejects_bad_indices_at_encode():
    """Non-ascending or duplicate indices violate the TopKTensor contract —
    the encoder fails fast instead of emitting an undecodable stream."""
    for bad in ([5, 2], [2, 2]):
        with pytest.raises(WireError, match="strictly ascending"):
            encode_update({"x": _topk_leaf(bad, 8)})


def _crc_fixed(blob, body):
    import struct
    import zlib

    magic, ver, fl, n, _, bl = _HEADER.unpack_from(blob)
    return _HEADER.pack(magic, ver, fl, n, zlib.crc32(bytes(body)), len(body)) \
        + bytes(body)


def test_topk_delta_malformed_streams_are_wireerror():
    """CRC-valid but semantically broken delta streams must still refuse:
    a zero gap (duplicate index) and an out-of-range index."""
    t = _topk_leaf([2, 5], 8)
    blob = encode_update({"x": t})
    body = bytearray(blob[_HEADER.size:])
    # locate the 2-byte varint stream (values 2, gap 3) right after the
    # k u32 + stream_len u64 fields; the stream is the bytes b"\x02\x03".
    pos = bytes(body).find(b"\x02\x03")
    assert pos > 0
    dup = bytearray(body)
    dup[pos + 1] = 0x00          # gap 0 → duplicate index
    with pytest.raises(WireError, match="ascending"):
        decode_update(_crc_fixed(blob, dup))
    oob = bytearray(body)
    oob[pos + 1] = 0x7F          # gap 127 → index 129 ≥ n=8
    with pytest.raises(WireError, match="out of range"):
        decode_update(_crc_fixed(blob, oob))


def test_legacy_topk_v2_buffer_still_decodes():
    """A v2 buffer framed with the raw-u32 TOPK record (kind 3) must keep
    decoding even though encoders now emit TOPK_DELTA."""
    import struct

    from repro.comm.wire import _PATH_SEP, _topk_body

    t = _topk_leaf([1, 4, 6], 9)
    path = "d:x".encode("utf-8")
    record = b"".join([
        struct.pack("<H", len(path)), path, struct.pack("<B", 3),
        _topk_body(t),
    ])
    import zlib

    blob = _HEADER.pack(b"TFW1", 2, 0, 1, zlib.crc32(record), len(record)) \
        + record
    back = decode_update(blob)["x"]
    np.testing.assert_array_equal(np.asarray(back.indices), np.asarray(t.indices))
    np.testing.assert_array_equal(np.asarray(back.values), np.asarray(t.values))


# --------------------------------------------------------------------------
# Corruption fuzz.
# --------------------------------------------------------------------------


def _mixed_blob():
    rng = np.random.default_rng(5)
    tree = {
        "dense": {
            "w": encode_ternary(
                jnp.asarray(rng.integers(-1, 2, (13, 7)).astype(np.int8)),
                jnp.float32(0.31),
            ),
            "b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32)),
        },
        "half": compress_pytree(
            {"x": jnp.asarray(rng.normal(size=(5, 4)).astype(np.float32))},
            CodecSpec(kind="fp16", residual="fp16"),
        )[0]["x"],
        "sparse": compress_pytree(
            {"x": jnp.asarray(rng.normal(size=(9, 6)).astype(np.float32))},
            CodecSpec(kind="topk", residual="topk", topk_fraction=0.3),
        )[0]["x"],
    }
    return encode_update(tree)


def test_fuzz_truncation_always_wireerror():
    blob = _mixed_blob()
    for cut in range(0, len(blob), 7):
        with pytest.raises(WireError):
            decode_update(blob[:cut])
    with pytest.raises(WireError):
        decode_update(blob[: len(blob) - 1])


def test_fuzz_bitflips_never_wrong_tree_never_stray_exception():
    """Flip single bits everywhere (header and body). Every outcome must be
    either a WireError or a decode identical to the original buffer (flips
    in ignored/reserved fields) — NEVER a silently different tree and NEVER
    a non-WireError exception."""
    blob = _mixed_blob()
    rng = np.random.default_rng(11)
    # all header byte positions + a random body sample
    positions = list(range(_HEADER.size)) + sorted(
        rng.choice(np.arange(_HEADER.size, len(blob)), size=200, replace=False)
    )
    survived = 0
    for pos in positions:
        for bit in range(8):
            bad = bytearray(blob)
            bad[pos] ^= 1 << bit
            try:
                out = decode_update(bytes(bad))
            except WireError:
                continue
            # decoded despite the flip: must be semantically the original
            survived += 1
            assert encode_update(out) == blob, (pos, bit)
    # a handful of reserved-field flips may legitimately survive, but the
    # overwhelming majority of corruptions must be caught
    assert survived <= 2 * 8  # flags field is the only ignored region


def test_fuzz_random_garbage_rejected():
    rng = np.random.default_rng(13)
    for n in (0, 1, 23, 24, 57, 512):
        with pytest.raises(WireError):
            decode_update(bytes(rng.integers(0, 256, size=n, dtype=np.uint8)))


# --------------------------------------------------------------------------
# Incremental / chunked reading (StreamDecoder) fuzz: partial reads and
# truncated frames must surface as WireError — never a hang, never a
# silent short read.
# --------------------------------------------------------------------------


def _chunked(blob, sizes):
    """Split blob into chunks following the (cycled) size pattern."""
    out, i, k = [], 0, 0
    while i < len(blob):
        n = sizes[k % len(sizes)]
        out.append(blob[i:i + n])
        i += n
        k += 1
    return out


def test_stream_decoder_reassembles_any_chunking():
    """Every chunking of the stream — byte-at-a-time, odd primes, one big
    read, header split across chunks — yields the identical buffer."""
    from repro.comm import StreamDecoder

    blob = _mixed_blob()
    ref = decode_update(blob)
    for sizes in ([1], [3], [7, 1, 13], [23], [len(blob)], [5, 1000]):
        dec = StreamDecoder()
        frames = []
        for chunk in _chunked(blob, sizes):
            frames.extend(dec.feed(chunk))
        dec.close()
        assert len(frames) == 1 and frames[0] == blob, sizes
        out = decode_update(frames[0])
        assert encode_update(out) == encode_update(ref)
    assert dec.bytes_in == len(blob) and dec.frames_out == 1


def test_stream_decoder_multiple_buffers_in_order():
    from repro.comm import StreamDecoder

    a = encode_update({"x": jnp.arange(6.0)})
    b = _mixed_blob()
    stream = a + b + a
    dec = StreamDecoder()
    frames = []
    for chunk in _chunked(stream, [11, 2, 59]):
        frames.extend(dec.feed(chunk))
    dec.close()
    assert frames == [a, b, a]
    assert dec.frames_out == 3


def test_stream_decoder_truncation_every_cut_is_wireerror():
    """EOF at ANY interior byte offset must raise at close() — a torn
    stream can never be mistaken for a complete short buffer."""
    from repro.comm import StreamDecoder

    blob = _mixed_blob()
    for cut in list(range(1, 40)) + list(range(40, len(blob), 37)):
        dec = StreamDecoder()
        for chunk in _chunked(blob[:cut], [13]):
            got = dec.feed(chunk)
            assert got == []  # nothing complete can come out of a prefix
        with pytest.raises(WireError):
            dec.close()
    # empty stream closes clean (no data ≠ torn data)
    StreamDecoder().close()


def test_stream_decoder_bad_header_fails_fast():
    """Magic/version/length problems raise the moment 24 header bytes are
    in — the reader must not wait for a body a garbage length promised."""
    import struct

    from repro.comm import MAX_BODY_BYTES, StreamDecoder

    blob = _mixed_blob()
    magic, ver, fl, n, crc, bl = _HEADER.unpack_from(blob)

    with pytest.raises(WireError, match="magic"):
        StreamDecoder().feed(b"NOPE" + blob[4:_HEADER.size])
    with pytest.raises(WireError, match="version"):
        StreamDecoder().feed(_HEADER.pack(magic, 99, fl, n, crc, bl))
    huge = _HEADER.pack(magic, ver, fl, n, crc, MAX_BODY_BYTES + 1)
    with pytest.raises(WireError, match="corrupted length"):
        StreamDecoder().feed(huge)
    # split the header across feeds: the error still fires on the feed
    # that completes byte 24, without any body
    dec = StreamDecoder()
    assert dec.feed(b"NO") == []
    with pytest.raises(WireError, match="magic"):
        dec.feed(b"PE" + blob[4:_HEADER.size])


def test_stream_decoder_frame_crc_still_verified_downstream():
    """StreamDecoder only frames; a body bitflip with an intact header must
    still die in decode_update's CRC check."""
    from repro.comm import StreamDecoder

    blob = bytearray(_mixed_blob())
    blob[_HEADER.size + 5] ^= 0x10
    dec = StreamDecoder()
    frames = dec.feed(bytes(blob))
    dec.close()
    assert len(frames) == 1  # framing is length-driven, so it passes...
    with pytest.raises(WireError):  # ...and decode catches the corruption
        decode_update(frames[0])


def test_decode_update_chunks_contract():
    from repro.comm import decode_update_chunks

    blob = _mixed_blob()
    ref = decode_update(blob)
    out = decode_update_chunks(_chunked(blob, [19]))
    assert encode_update(out) == encode_update(ref)
    with pytest.raises(WireError, match="ended"):
        decode_update_chunks(_chunked(blob[:-3], [19]))
    with pytest.raises(WireError, match="multiple"):
        decode_update_chunks([blob, blob])
    with pytest.raises(WireError):
        decode_update_chunks([])
    # trailing garbage after a complete buffer = torn second frame
    with pytest.raises(WireError):
        decode_update_chunks([blob, b"\x01\x02\x03"])


def test_nested_corrupt_record_kind_is_wireerror():
    blob = _mixed_blob()
    # force an unknown kind byte in the first record while fixing the CRC
    import struct
    import zlib

    body = bytearray(blob[_HEADER.size:])
    path_len = struct.unpack_from("<H", body, 0)[0]
    body[2 + path_len] = 0xEE  # kind byte of record 0
    magic, ver, fl, n, _, bl = _HEADER.unpack_from(blob)
    fixed = _HEADER.pack(magic, ver, fl, n, zlib.crc32(bytes(body)), bl) + bytes(body)
    with pytest.raises(WireError, match="unknown record kind"):
        decode_update(fixed)
