"""TCP framing layer: pack/decode round-trips under arbitrary chunking,
fail-fast on malformed headers, torn-connection discipline, and a real
loopback-socket echo with byte metering."""

import socket
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    FT_BCAST,
    FT_DONE,
    FT_HELLO,
    FT_UPDATE,
    FrameDecoder,
    TransportError,
    decode_update,
    encode_update,
    pack_frame,
    recv_frame,
    send_frame,
)
from repro.comm.transport import _FRAME, TRANSPORT_MAGIC


def _update_blob(seed=0):
    rng = np.random.default_rng(seed)
    return encode_update({
        "w": jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32)),
    })


def _chunked(blob, n):
    return [blob[i:i + n] for i in range(0, len(blob), n)]


# --------------------------------------------------------------------------
# In-memory framing.
# --------------------------------------------------------------------------


def test_frame_roundtrip_any_chunking():
    blob = _update_blob()
    wire = pack_frame(FT_UPDATE, blob, {"client_id": 7, "weight": 1.5}) \
        + pack_frame(FT_DONE) \
        + pack_frame(FT_HELLO, b"", {"client_id": 7})
    for n in (1, 3, 16, 17, 1000, len(wire)):
        dec = FrameDecoder()
        frames = []
        for chunk in _chunked(wire, n):
            frames.extend(dec.feed(chunk))
        dec.close()
        assert [f.ftype for f in frames] == [FT_UPDATE, FT_DONE, FT_HELLO]
        assert frames[0].meta == {"client_id": 7, "weight": 1.5}
        assert frames[0].payload == blob
        assert frames[1].meta == {} and frames[1].payload == b""
        assert dec.bytes_in == len(wire)
    # nbytes_framed is the exact on-wire size
    assert sum(f.nbytes_framed for f in frames) == len(wire)


def test_pop_drains_in_order_without_loss():
    """A single chunk carrying several frames must not lose the extras when
    consumed one at a time via pop()."""
    wire = b"".join(pack_frame(FT_UPDATE, bytes([i]) * 3, {"i": i})
                    for i in range(5))
    dec = FrameDecoder()
    dec.feed(wire)
    seen = []
    while (f := dec.pop()) is not None:
        seen.append(f.meta["i"])
    assert seen == [0, 1, 2, 3, 4]
    assert dec.pop() is None


def test_bad_header_fails_fast():
    good = pack_frame(FT_UPDATE, b"x" * 100)
    with pytest.raises(TransportError, match="magic"):
        FrameDecoder().feed(b"WAT?" + good[4:_FRAME.size])
    with pytest.raises(TransportError, match="unknown frame type"):
        FrameDecoder().feed(_FRAME.pack(TRANSPORT_MAGIC, 200, 0, 0, 0))
    with pytest.raises(TransportError, match="corrupted length"):
        FrameDecoder().feed(
            _FRAME.pack(TRANSPORT_MAGIC, FT_UPDATE, 0, 0, 1 << 60))
    with pytest.raises(TransportError, match="unknown frame type"):
        pack_frame(99, b"")


def test_malformed_meta_is_transport_error():
    import struct

    bad_meta = b"{not json"
    raw = _FRAME.pack(TRANSPORT_MAGIC, FT_HELLO, 0, len(bad_meta), 0) + bad_meta
    with pytest.raises(TransportError, match="meta"):
        FrameDecoder().feed(raw)
    arr = b"[1,2]"
    raw = _FRAME.pack(TRANSPORT_MAGIC, FT_HELLO, 0, len(arr), 0) + arr
    with pytest.raises(TransportError, match="JSON object"):
        FrameDecoder().feed(raw)
    del struct


def test_torn_connection_raises_on_close():
    frame = pack_frame(FT_UPDATE, b"z" * 64)
    for cut in (1, _FRAME.size - 1, _FRAME.size, _FRAME.size + 10,
                len(frame) - 1):
        dec = FrameDecoder()
        assert dec.feed(frame[:cut]) == []
        with pytest.raises(TransportError, match="mid-frame"):
            dec.close()
    FrameDecoder().close()  # clean EOF at a frame boundary is fine


# --------------------------------------------------------------------------
# Real loopback sockets.
# --------------------------------------------------------------------------


def test_loopback_roundtrip_with_byte_metering():
    """Client streams HELLO + UPDATE + DONE over a real TCP connection; the
    server-side decoder's bytes_in must equal the client's summed
    send_frame returns (upload bytes metered from actual socket traffic),
    and the update payload must decode with its CRC verified."""
    blob = _update_blob(3)
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    sent = {}

    def client():
        with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
            n = send_frame(s, FT_HELLO, meta={"client_id": 4})
            n += send_frame(s, FT_UPDATE, blob, {"client_id": 4, "weight": 2.0})
            n += send_frame(s, FT_DONE)
            sent["n"] = n

    t = threading.Thread(target=client)
    t.start()
    conn, _ = srv.accept()
    conn.settimeout(10)
    dec = FrameDecoder()
    hello = recv_frame(conn, dec)
    update = recv_frame(conn, dec)
    done = recv_frame(conn, dec)
    t.join(timeout=10)
    conn.close()
    srv.close()

    assert hello.ftype == FT_HELLO and hello.meta["client_id"] == 4
    assert update.ftype == FT_UPDATE and update.meta["weight"] == 2.0
    assert done.ftype == FT_DONE
    assert update.payload == blob
    decode_update(update.payload)  # CRC re-verified at the boundary
    assert dec.bytes_in == sent["n"]


def test_loopback_peer_disconnect_mid_frame():
    """A peer that dies mid-frame must surface as TransportError on the
    reader — never a hang, never a truncated frame delivered."""
    frame = pack_frame(FT_BCAST, b"q" * 4096)
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]

    def client():
        s = socket.create_connection(("127.0.0.1", port), timeout=10)
        s.sendall(frame[: len(frame) // 2])
        s.close()  # dies mid-frame

    t = threading.Thread(target=client)
    t.start()
    conn, _ = srv.accept()
    with pytest.raises(TransportError):
        recv_frame(conn, timeout_s=10)
    t.join(timeout=10)
    conn.close()
    srv.close()
