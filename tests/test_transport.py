"""TCP framing layer: pack/decode round-trips under arbitrary chunking,
fail-fast on malformed headers, torn-connection discipline, adversarial
decoder inputs, the typed failure taxonomy, retry/backoff policy, and a
real loopback-socket echo with byte metering."""

import socket
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    FT_BCAST,
    FT_DONE,
    FT_HELLO,
    FT_UPDATE,
    FrameDecoder,
    FrameError,
    ProtocolError,
    RetryExhausted,
    RetryPolicy,
    TornConnectionError,
    TransportError,
    TransportTimeout,
    call_with_retries,
    decode_update,
    encode_update,
    pack_frame,
    recv_frame,
    send_frame,
)
from repro.comm.transport import _FRAME, TRANSPORT_MAGIC


def _update_blob(seed=0):
    rng = np.random.default_rng(seed)
    return encode_update({
        "w": jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32)),
    })


def _chunked(blob, n):
    return [blob[i:i + n] for i in range(0, len(blob), n)]


# --------------------------------------------------------------------------
# In-memory framing.
# --------------------------------------------------------------------------


def test_frame_roundtrip_any_chunking():
    blob = _update_blob()
    wire = pack_frame(FT_UPDATE, blob, {"client_id": 7, "weight": 1.5}) \
        + pack_frame(FT_DONE) \
        + pack_frame(FT_HELLO, b"", {"client_id": 7})
    for n in (1, 3, 16, 17, 1000, len(wire)):
        dec = FrameDecoder()
        frames = []
        for chunk in _chunked(wire, n):
            frames.extend(dec.feed(chunk))
        dec.close()
        assert [f.ftype for f in frames] == [FT_UPDATE, FT_DONE, FT_HELLO]
        assert frames[0].meta == {"client_id": 7, "weight": 1.5}
        assert frames[0].payload == blob
        assert frames[1].meta == {} and frames[1].payload == b""
        assert dec.bytes_in == len(wire)
    # nbytes_framed is the exact on-wire size
    assert sum(f.nbytes_framed for f in frames) == len(wire)


def test_pop_drains_in_order_without_loss():
    """A single chunk carrying several frames must not lose the extras when
    consumed one at a time via pop()."""
    wire = b"".join(pack_frame(FT_UPDATE, bytes([i]) * 3, {"i": i})
                    for i in range(5))
    dec = FrameDecoder()
    dec.feed(wire)
    seen = []
    while (f := dec.pop()) is not None:
        seen.append(f.meta["i"])
    assert seen == [0, 1, 2, 3, 4]
    assert dec.pop() is None


def test_bad_header_fails_fast():
    good = pack_frame(FT_UPDATE, b"x" * 100)
    with pytest.raises(TransportError, match="magic"):
        FrameDecoder().feed(b"WAT?" + good[4:_FRAME.size])
    with pytest.raises(TransportError, match="unknown frame type"):
        FrameDecoder().feed(_FRAME.pack(TRANSPORT_MAGIC, 200, 0, 0, 0))
    with pytest.raises(TransportError, match="corrupted length"):
        FrameDecoder().feed(
            _FRAME.pack(TRANSPORT_MAGIC, FT_UPDATE, 0, 0, 1 << 60))
    with pytest.raises(TransportError, match="unknown frame type"):
        pack_frame(99, b"")


def test_malformed_meta_is_transport_error():
    import struct

    bad_meta = b"{not json"
    raw = _FRAME.pack(TRANSPORT_MAGIC, FT_HELLO, 0, len(bad_meta), 0) + bad_meta
    with pytest.raises(TransportError, match="meta"):
        FrameDecoder().feed(raw)
    arr = b"[1,2]"
    raw = _FRAME.pack(TRANSPORT_MAGIC, FT_HELLO, 0, len(arr), 0) + arr
    with pytest.raises(TransportError, match="JSON object"):
        FrameDecoder().feed(raw)
    del struct


def test_torn_connection_raises_on_close():
    frame = pack_frame(FT_UPDATE, b"z" * 64)
    for cut in (1, _FRAME.size - 1, _FRAME.size, _FRAME.size + 10,
                len(frame) - 1):
        dec = FrameDecoder()
        assert dec.feed(frame[:cut]) == []
        with pytest.raises(TransportError, match="mid-frame"):
            dec.close()
    FrameDecoder().close()  # clean EOF at a frame boundary is fine


# --------------------------------------------------------------------------
# Adversarial decoder inputs.
# --------------------------------------------------------------------------


def test_payload_cap_boundary_exact():
    """A payload of exactly max_payload_bytes must parse; ONE byte more must
    be rejected at header time (never wait for a body the cap forbids)."""
    cap = 1024
    ok = pack_frame(FT_UPDATE, b"p" * cap)
    dec = FrameDecoder(max_payload_bytes=cap)
    frames = dec.feed(ok)
    assert len(frames) == 1 and len(frames[0].payload) == cap

    over = _FRAME.pack(TRANSPORT_MAGIC, FT_UPDATE, 0, 0, cap + 1)
    with pytest.raises(FrameError, match="exceeds cap"):
        FrameDecoder(max_payload_bytes=cap).feed(over)
    # rejection happens with ONLY the header in hand — no body was needed
    dec3 = FrameDecoder(max_payload_bytes=cap)
    with pytest.raises(FrameError, match="exceeds cap"):
        dec3.feed(over[:_FRAME.size])


def test_feed_after_close_is_frame_error():
    dec = FrameDecoder()
    dec.feed(pack_frame(FT_DONE))
    dec.close()                      # clean close at a frame boundary
    with pytest.raises(FrameError, match="after close"):
        dec.feed(b"x")
    # a decoder that DIED mid-frame is closed too — feeding it is an error,
    # not a resurrection
    torn = FrameDecoder()
    frame = pack_frame(FT_UPDATE, b"z" * 64)
    torn.feed(frame[:10])
    with pytest.raises(TransportError, match="mid-frame"):
        torn.close()
    with pytest.raises(FrameError, match="after close"):
        torn.feed(frame[10:])


def test_byte_at_a_time_slow_sender_inmemory():
    """Three frames delivered one byte per feed(): every frame must pop out
    exactly once, bytes_in must count every byte, and no call may raise."""
    wire = (pack_frame(FT_HELLO, meta={"client_id": 1})
            + pack_frame(FT_UPDATE, b"u" * 257, {"weight": 2.0})
            + pack_frame(FT_DONE))
    dec = FrameDecoder()
    frames = []
    for i in range(len(wire)):
        frames.extend(dec.feed(wire[i:i + 1]))
    dec.close()
    assert [f.ftype for f in frames] == [FT_HELLO, FT_UPDATE, FT_DONE]
    assert frames[1].payload == b"u" * 257
    assert dec.bytes_in == len(wire)


def test_take_buffer_hands_off_partial_tail():
    """take_buffer() must return exactly the undecoded tail, leave the
    decoder clean (close() no longer raises), and keep bytes_in counting —
    the resume path moves these bytes into the session decoder."""
    f1 = pack_frame(FT_HELLO, meta={"client_id": 3})
    f2 = pack_frame(FT_UPDATE, b"y" * 128, {"weight": 1.0})
    cut = len(f2) // 2
    dec = FrameDecoder()
    frames = dec.feed(f1 + f2[:cut])
    assert [f.ftype for f in frames] == [FT_HELLO]
    tail = dec.take_buffer()
    assert tail == f2[:cut]
    assert dec.pending_bytes == 0
    assert dec.bytes_in == len(f1) + cut      # they WERE read off the socket
    dec.close()                               # clean: tail was handed off
    session = FrameDecoder()
    got = session.feed(tail) + session.feed(f2[cut:])
    assert len(got) == 1 and got[0].payload == b"y" * 128
    assert session.bytes_in == len(f2)


# --------------------------------------------------------------------------
# Failure taxonomy & retry policy.
# --------------------------------------------------------------------------


def test_taxonomy_is_rooted_at_transport_error():
    for exc in (FrameError, TornConnectionError, TransportTimeout,
                ProtocolError, RetryExhausted):
        assert issubclass(exc, TransportError)
    # timeouts stay catchable through the stdlib hierarchy too
    assert issubclass(TransportTimeout, TimeoutError)
    with pytest.raises(TimeoutError):
        raise TransportTimeout("late")


def test_backoff_grows_exponentially_and_caps():
    p = RetryPolicy(base_backoff_s=0.1, backoff_factor=2.0,
                    max_backoff_s=0.5, jitter_frac=0.0)
    assert [p.backoff_s(i) for i in range(4)] == [0.1, 0.2, 0.4, 0.5]
    # seeded jitter: deterministic for a given rng, bounded by jitter_frac
    pj = RetryPolicy(base_backoff_s=0.1, backoff_factor=2.0,
                     max_backoff_s=10.0, jitter_frac=0.25)
    a = pj.backoff_s(1, np.random.default_rng(7))
    b = pj.backoff_s(1, np.random.default_rng(7))
    assert a == b
    assert 0.2 * 0.75 <= a <= 0.2 * 1.25
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)


def test_call_with_retries_succeeds_after_transient_failures():
    calls, slept = [], []

    def fn(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise TornConnectionError("flaky link")
        return "landed"

    out = call_with_retries(fn, RetryPolicy(max_attempts=5, jitter_frac=0.0),
                            sleep=slept.append)
    assert out == "landed"
    assert calls == [0, 1, 2]          # attempt index is passed in
    assert len(slept) == 2             # backoff between attempts only


def test_call_with_retries_fatal_propagates_immediately():
    class Rejected(Exception):
        pass

    calls = []

    def fn(attempt):
        calls.append(attempt)
        raise Rejected("unsupported proto")

    with pytest.raises(Rejected):
        call_with_retries(fn, RetryPolicy(max_attempts=5),
                          fatal=(Rejected,), sleep=lambda s: None)
    assert calls == [0]                # a rejection is never retried into


def test_call_with_retries_exhaustion_chains_last_error():
    def fn(attempt):
        raise TornConnectionError(f"dead on attempt {attempt}")

    with pytest.raises(RetryExhausted) as ei:
        call_with_retries(fn, RetryPolicy(max_attempts=3, jitter_frac=0.0),
                          sleep=lambda s: None)
    assert ei.value.attempts == 3
    assert isinstance(ei.value.__cause__, TornConnectionError)
    assert "attempt 2" in str(ei.value.__cause__)


# --------------------------------------------------------------------------
# Real loopback sockets.
# --------------------------------------------------------------------------


def test_recv_frame_restores_prior_socket_timeout():
    """timeout_s applies to ONE call — the socket's prior timeout must be
    restored afterwards, on the success path AND the timeout path."""
    a, b = socket.socketpair()
    try:
        b.settimeout(123.0)
        a.sendall(pack_frame(FT_DONE))
        frame = recv_frame(b, timeout_s=5.0)
        assert frame.ftype == FT_DONE
        assert b.gettimeout() == 123.0
        with pytest.raises(TransportTimeout):
            recv_frame(b, timeout_s=0.1)
        assert b.gettimeout() == 123.0
    finally:
        a.close()
        b.close()


def test_loopback_slow_sender_byte_at_a_time():
    """A sender dribbling one byte at a time over a real socket must still
    deliver a complete frame to recv_frame (incremental reassembly), not a
    timeout or a torn read."""
    frame = pack_frame(FT_UPDATE, b"s" * 96, {"client_id": 9, "weight": 1.0})
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]

    def client():
        with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            for i in range(len(frame)):
                s.sendall(frame[i:i + 1])
                if i % 16 == 0:
                    time.sleep(0.001)    # let recv() observe partial frames

    t = threading.Thread(target=client)
    t.start()
    conn, _ = srv.accept()
    try:
        got = recv_frame(conn, timeout_s=30)
    finally:
        t.join(timeout=10)
        conn.close()
        srv.close()
    assert got.ftype == FT_UPDATE
    assert got.payload == b"s" * 96
    assert got.meta["client_id"] == 9


def test_loopback_roundtrip_with_byte_metering():
    """Client streams HELLO + UPDATE + DONE over a real TCP connection; the
    server-side decoder's bytes_in must equal the client's summed
    send_frame returns (upload bytes metered from actual socket traffic),
    and the update payload must decode with its CRC verified."""
    blob = _update_blob(3)
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    sent = {}

    def client():
        with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
            n = send_frame(s, FT_HELLO, meta={"client_id": 4})
            n += send_frame(s, FT_UPDATE, blob, {"client_id": 4, "weight": 2.0})
            n += send_frame(s, FT_DONE)
            sent["n"] = n

    t = threading.Thread(target=client)
    t.start()
    conn, _ = srv.accept()
    conn.settimeout(10)
    dec = FrameDecoder()
    hello = recv_frame(conn, dec)
    update = recv_frame(conn, dec)
    done = recv_frame(conn, dec)
    t.join(timeout=10)
    conn.close()
    srv.close()

    assert hello.ftype == FT_HELLO and hello.meta["client_id"] == 4
    assert update.ftype == FT_UPDATE and update.meta["weight"] == 2.0
    assert done.ftype == FT_DONE
    assert update.payload == blob
    decode_update(update.payload)  # CRC re-verified at the boundary
    assert dec.bytes_in == sent["n"]


def test_loopback_peer_disconnect_mid_frame():
    """A peer that dies mid-frame must surface as TransportError on the
    reader — never a hang, never a truncated frame delivered."""
    frame = pack_frame(FT_BCAST, b"q" * 4096)
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]

    def client():
        s = socket.create_connection(("127.0.0.1", port), timeout=10)
        s.sendall(frame[: len(frame) // 2])
        s.close()  # dies mid-frame

    t = threading.Thread(target=client)
    t.start()
    conn, _ = srv.accept()
    with pytest.raises(TransportError):
        recv_frame(conn, timeout_s=10)
    t.join(timeout=10)
    conn.close()
    srv.close()
