"""Fused packed fan-in aggregation: kernel-vs-oracle, streaming Aggregator
vs the list-based reference (``server_aggregate``), jit-trace bucketing, and
the C-sharded ``shard_map`` path (subprocess with 8 forced host devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.wire import encode_update
from repro.core import CodecSpec, FTTQConfig, compress_pytree
from repro.core import fttq as F
from repro.core.tfedavg import (
    TernaryUpdate, client_update_payload, server_aggregate,
)
from repro.fed.aggregator import Aggregator, bucket_for
from repro.kernels.aggregate import (
    LANES, packed_weighted_sum, packed_weighted_sum_ref,
)
from repro.models.paper_models import init_mlp_mnist

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CFG = FTTQConfig()


# --------------------------------------------------------------------------
# Kernel vs numpy oracle.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("c,rows", [(1, 32), (3, 32), (8, 64), (16, 96)])
def test_kernel_matches_oracle(c, rows):
    rng = np.random.default_rng(c * 100 + rows)
    stacked = rng.integers(0, 3, size=(c, rows, LANES), dtype=np.uint8)
    for j in range(1, 4):  # all four bit planes populated, code 3 never used
        stacked |= rng.integers(0, 3, stacked.shape, dtype=np.uint8) << (2 * j)
    coeffs = rng.normal(size=(c,)).astype(np.float32)
    out = np.asarray(packed_weighted_sum(
        jnp.asarray(stacked), jnp.asarray(coeffs), interpret=True
    ))
    np.testing.assert_allclose(
        out, packed_weighted_sum_ref(stacked, coeffs), atol=1e-5
    )


def test_zero_coeff_rows_contribute_nothing():
    """Padding clients carry coeff 0 — even all-ones garbage bytes vanish."""
    rng = np.random.default_rng(0)
    stacked = rng.integers(0, 256, size=(4, 32, LANES), dtype=np.uint8)
    coeffs = np.array([0.5, 0.0, 0.0, 0.25], np.float32)
    zeroed = stacked.copy()
    zeroed[1:3] = 0xFF
    a = np.asarray(packed_weighted_sum(jnp.asarray(stacked), jnp.asarray(coeffs),
                                       interpret=True))
    b = np.asarray(packed_weighted_sum(jnp.asarray(zeroed), jnp.asarray(coeffs),
                                       interpret=True))
    np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------
# Streaming Aggregator vs the reference loop.
# --------------------------------------------------------------------------


def _ragged_params(key):
    """Ragged + stacked shapes: n % 4 ≠ 0 weights, per-layer-scale stacks,
    biases, an int counter — every aggregation corner in one tree."""
    k = jax.random.split(key, 5)
    return {
        "enc": {"w": jax.random.normal(k[0], (17, 9)),
                "b": jax.random.normal(k[1], (9,))},
        "stack": {"w": jax.random.normal(k[2], (3, 8, 12))},  # per-layer w_q
        "head": {"w": jax.random.normal(k[3], (12, 5)),
                 "b": jax.random.normal(k[4], (5,))},
        "steps": jnp.asarray(7, jnp.int32),
    }


def _client_payload(key, spec=None):
    params = _ragged_params(key)
    wq = F.init_wq_tree(params, CFG)
    payload = client_update_payload(params, wq, CFG)
    if spec is not None:  # residual codec on the raw leaves
        payload, _ = compress_pytree(payload, spec)
    return payload


def _assert_trees_close(ref, got, atol=1e-6):
    r = jax.tree_util.tree_flatten_with_path(ref)[0]
    g = jax.tree_util.tree_flatten_with_path(got)[0]
    assert len(r) == len(g)
    for (pa, a), (pb, b) in zip(r, g):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        assert a.shape == b.shape, (pa, a.shape, b.shape)
        assert a.dtype == b.dtype, (pa, a.dtype, b.dtype)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=atol, rtol=1e-5, err_msg=str(pa),
        )


@pytest.mark.parametrize("n_clients", [1, 2, 3, 5, 16, 17, 33, 64])
def test_aggregator_matches_reference(n_clients):
    """Streaming output == list-based server_aggregate within 1e-6, across
    ragged leaf shapes, per-layer scales, and chunk/bucket boundaries
    (chunk_c=8: 17 → 8+8+1, 33 → 4 full chunks + 1, 64 → 8 full)."""
    blobs, updates = [], []
    for c in range(n_clients):
        payload = _client_payload(jax.random.PRNGKey(c % 8))
        blobs.append(encode_update(payload))
        updates.append(TernaryUpdate(payload=payload, n_samples=50 + 13 * c))
    ref = server_aggregate(updates)
    agg = Aggregator(chunk_c=8)
    for b, u in zip(blobs, updates):
        agg.add(b, u.n_samples)
    _assert_trees_close(ref, agg.finalize())


@pytest.mark.parametrize("spec", [
    CodecSpec(kind="ternary", residual="fp16", fttq=CFG),
    CodecSpec(kind="ternary", residual="topk", fttq=CFG, topk_fraction=0.5),
])
def test_aggregator_mixed_codec_leaves(spec):
    """Ternary weights take the fused kernel; downcast/top-k residual leaves
    stream through the codec-registry fallback — one pass, same mean."""
    blobs, updates = [], []
    for c in range(6):
        payload = _client_payload(jax.random.PRNGKey(10 + c), spec)
        blobs.append(encode_update(payload))
        updates.append(TernaryUpdate(payload=payload, n_samples=30 + 7 * c))
    ref = server_aggregate(updates)
    agg = Aggregator(chunk_c=4)
    for b, u in zip(blobs, updates):
        agg.add(b, u.n_samples)
    # fp16/topk residuals decode identically on both paths
    _assert_trees_close(ref, agg.finalize(), atol=2e-6)


def test_aggregator_weight_scale_invariance():
    """The mean is invariant to a global rescale of the |D_k| weights."""
    blobs = [encode_update(_client_payload(jax.random.PRNGKey(c)))
             for c in range(4)]
    outs = []
    for scale in (1.0, 1000.0):
        agg = Aggregator(chunk_c=2)
        for i, b in enumerate(blobs):
            agg.add(b, weight=(i + 1) * scale)
        outs.append(agg.finalize())
    _assert_trees_close(outs[0], outs[1], atol=1e-5)


def test_aggregator_single_client_is_dequant():
    payload = _client_payload(jax.random.PRNGKey(99))
    agg = Aggregator(chunk_c=16)
    agg.add(encode_update(payload), 42)
    ref = server_aggregate([TernaryUpdate(payload=payload, n_samples=42)])
    _assert_trees_close(ref, agg.finalize())


def test_aggregator_guards():
    agg = Aggregator(chunk_c=4)
    with pytest.raises(ValueError, match="no client updates"):
        agg.finalize()
    blob = encode_update(_client_payload(jax.random.PRNGKey(0)))
    with pytest.raises(ValueError, match="weight must be"):
        agg.add(blob, -1)
    agg.add(blob, 1)
    other = encode_update({"different": jnp.ones((4, 4))})
    with pytest.raises(ValueError, match="structure changed"):
        agg.add(other, 1)
    # an all-zero-weight buffer cannot normalize
    empty = Aggregator(chunk_c=4)
    empty.add(blob, 0)
    with pytest.raises(ValueError, match="total client weight"):
        empty.finalize()


def test_aggregator_zero_weight_client_rides_along():
    """An empty data shard (|D_k| = 0) contributes nothing, exactly like
    the reference's weight-0 entry — the round must not abort."""
    payloads = [_client_payload(jax.random.PRNGKey(c)) for c in range(3)]
    updates = [TernaryUpdate(payload=p, n_samples=w)
               for p, w in zip(payloads, (10, 0, 30))]
    ref = server_aggregate(updates)
    agg = Aggregator(chunk_c=2)
    for p, u in zip(payloads, updates):
        agg.add(encode_update(p), u.n_samples)
    _assert_trees_close(ref, agg.finalize())


def test_bucket_cap_non_power_of_two_chunk():
    assert bucket_for(10, 12) == 12     # cap holds for non-pow2 chunk_c
    assert bucket_for(13, 12) == 12
    assert bucket_for(7, 12) == 8


def test_duplicate_record_paths_rejected():
    """A CRC-valid blob repeating one record would double-count in an
    accumulator (decode_update last-wins it) — the aggregator refuses."""
    import struct
    import zlib

    from repro.comm.wire import _HEADER, WireError

    blob = encode_update({"w": jnp.ones((4,))})
    body = blob[_HEADER.size:]
    dup_body = body + body                     # same path twice
    magic, ver, fl, _, _, _ = _HEADER.unpack_from(blob)
    dup = _HEADER.pack(magic, ver, fl, 2, zlib.crc32(dup_body),
                       len(dup_body)) + dup_body
    agg = Aggregator(chunk_c=4)
    with pytest.raises(WireError, match="duplicate record paths"):
        agg.add(dup, 1)


def test_peak_memory_independent_of_client_count():
    """Chunked streaming: the stacked-buffer high-water mark is a function
    of chunk_c, not of how many clients flow through."""
    peaks = {}
    for n in (8, 32):
        agg = Aggregator(chunk_c=8)
        for c in range(n):
            agg.add(encode_update(_client_payload(jax.random.PRNGKey(c % 4))),
                    10 + c)
        agg.finalize()
        peaks[n] = agg.peak_intermediate_bytes
    assert peaks[8] == peaks[32] > 0


# --------------------------------------------------------------------------
# Trace bucketing: varying client counts must not retrace.
# --------------------------------------------------------------------------


def test_bucket_function():
    assert [bucket_for(c, 16) for c in (1, 2, 3, 5, 8, 9, 15, 16, 40)] == \
        [1, 2, 4, 8, 8, 16, 16, 16, 16]


def test_varying_client_count_no_new_traces():
    """Rounds with client counts all over 1..12 compile only the bucket set:
    after one warm round per bucket, further variation adds zero traces."""
    from repro.parallel.fanin import fanin_trace_count

    mlp_blobs = [encode_update(_client_payload(jax.random.PRNGKey(c)))
                 for c in range(4)]

    def round_with(n):
        agg = Aggregator(chunk_c=4)
        for i in range(n):
            agg.add(mlp_blobs[i % 4], 10 + i)
        agg.finalize()

    for n in (1, 2, 3, 4):   # warm every bucket (1, 2, 4, 4)
        round_with(n)
    before = fanin_trace_count()
    for n in (5, 7, 9, 11, 12, 3, 2, 10):   # new counts, same buckets
        round_with(n)
    assert fanin_trace_count() == before


# --------------------------------------------------------------------------
# Sharded fan-in (shard_map over the client axis).
# --------------------------------------------------------------------------


def test_sharded_fanin_matches_unsharded():
    """8 forced host devices: C-sharded psum fan-in == single-device kernel
    (and the Aggregator produces the reference mean on a mesh)."""
    code = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.parallel.fanin import fanin_weighted_sum
    from repro.kernels.aggregate import packed_weighted_sum_ref
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    st = rng.integers(0, 3, size=(16, 32, 128), dtype=np.uint8)
    for j in range(1, 4):
        st |= rng.integers(0, 3, st.shape, dtype=np.uint8) << (2 * j)
    co = rng.normal(size=(16,)).astype(np.float32)
    ref = packed_weighted_sum_ref(st, co)
    out = np.asarray(fanin_weighted_sum(st, co, mesh=mesh))
    np.testing.assert_allclose(out, ref, atol=1e-4)
    # C not divisible by the axis → graceful single-device fallback
    out5 = np.asarray(fanin_weighted_sum(st[:5], co[:5], mesh=mesh))
    np.testing.assert_allclose(out5, packed_weighted_sum_ref(st[:5], co[:5]),
                               atol=1e-4)
    print("FANIN_OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FANIN_OK" in out.stdout
