"""Checkpoint/restore, fault-tolerance, and elasticity tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import CodecSpec
from repro.models.transformer import ModelConfig
from repro.optim import adam
from repro.train import (
    TrainerConfig, init_train_state, make_train_step,
    latest_step, restore_checkpoint, save_checkpoint,
)
from repro.train.fault import StragglerDeadline, elastic_reshard, retrying


CFG = ModelConfig(name="ckpt-test", family="dense", n_layers=2, d_model=32,
                  vocab_size=64, n_heads=4, n_kv_heads=2, d_ff=64)


def _state_and_step():
    tcfg = TrainerConfig(qat=True, pod_compression=False)
    opt = adam(1e-3)
    state = init_train_state(CFG, tcfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, tcfg, opt))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 64),
    }
    return state, step, batch


def test_save_restore_roundtrip(tmp_path):
    state, step, batch = _state_and_step()
    state, _ = step(state, batch)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, state, metadata={"data_cursor": 17})
    restored, meta = restore_checkpoint(d, example_state=state)
    assert meta["data_cursor"] == 17
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_training_bitexact(tmp_path):
    """Crash/restart: resuming from the checkpoint reproduces the
    uninterrupted run exactly."""
    state, step, batch = _state_and_step()
    s1, _ = step(state, batch)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, s1)
    s2, _ = step(s1, batch)          # uninterrupted continuation

    restored, _ = restore_checkpoint(d, example_state=s1)
    s2r, _ = step(restored, batch)   # post-crash continuation
    for a, b in zip(jax.tree_util.tree_leaves(s2),
                    jax.tree_util.tree_leaves(s2r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_keep_and_latest(tmp_path):
    state, _, _ = _state_and_step()
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4):
        save_checkpoint(d, s, state, keep=2)
    assert latest_step(d) == 4
    steps = sorted(int(n[5:]) for n in os.listdir(d) if n.startswith("step_"))
    assert steps == [3, 4]
    assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_ternary_compressed_checkpoint(tmp_path):
    """Ternary on-disk codec: ~16× smaller weight payload, restorable."""
    state, step, batch = _state_and_step()
    state, _ = step(state, batch)
    d_fp = str(tmp_path / "fp")
    d_t = str(tmp_path / "tern")
    save_checkpoint(d_fp, 1, state.params)
    save_checkpoint(d_t, 1, state.params, compression=CodecSpec(kind="ternary"))

    def dir_size(d):
        return sum(os.path.getsize(os.path.join(r, f))
                   for r, _, fs in os.walk(d) for f in fs)

    assert dir_size(d_t) < 0.55 * dir_size(d_fp)  # embed stays fp32
    restored, _ = restore_checkpoint(
        d_t, example_state=state.params, compression=CodecSpec(kind="ternary")
    )
    # quantized leaves reconstruct approximately
    a = np.asarray(restored["blocks"]["attn"]["wq"])
    b = np.asarray(state.params["blocks"]["attn"]["wq"])
    assert a.shape == b.shape
    corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
    assert corr > 0.6


def test_retrying_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    assert retrying(flaky, max_attempts=5, backoff_s=0.0)() == "ok"
    assert calls["n"] == 3

    def always_fails():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        retrying(always_fails, max_attempts=2, backoff_s=0.0)()


def test_elastic_reshard_single_device():
    """Re-placement API works (single device: identity placement)."""
    state, _, _ = _state_and_step()
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = elastic_reshard(state.params, sharding)
    np.testing.assert_array_equal(
        np.asarray(out["embed"]["table"]), np.asarray(state.params["embed"]["table"])
    )


def test_straggler_deadline():
    d = StragglerDeadline(1000.0)
    assert not d.exceeded()
    assert d.remaining() > 0
    d2 = StragglerDeadline(0.0)
    assert d2.exceeded()
