"""Serving under load: the batched packed-ternary engine must produce the
same logits as the one-shot deploy path, keep its dequant-cache within its
byte budget, and the closed loop must report a sane latency surface."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FTTQConfig
from repro.launch.serve_loop import (
    LRUDequantCache,
    ServeEngine,
    demo_model,
    run_closed_loop,
)


@pytest.fixture(scope="module")
def tiny():
    return demo_model(d_model=32, n_layers=2)


@pytest.fixture(scope="module")
def engine(tiny):
    cfg, params = tiny
    return ServeEngine(cfg, params, max_batch=4)


# --------------------------------------------------------------------------
# LRU dequant-cache.
# --------------------------------------------------------------------------


def _wire_leaf(shape, seed=0):
    from repro.core.compression import DowncastTensor

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    return x, DowncastTensor(data=x.astype(jnp.float16), orig_dtype="float32")


def test_cache_hit_miss_eviction_accounting():
    dense_a, wire_a = _wire_leaf((8, 8), 1)   # 256 B dense
    dense_b, wire_b = _wire_leaf((8, 8), 2)
    cache = LRUDequantCache(capacity_bytes=300)   # holds exactly one

    out = cache.get("a", wire_a)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(wire_a.restore()))
    assert (cache.hits, cache.misses, cache.evictions) == (0, 1, 0)
    cache.get("a", wire_a)
    assert cache.hits == 1
    cache.get("b", wire_b)                        # evicts a
    assert cache.evictions == 1 and cache.live_bytes <= 300
    cache.get("a", wire_a)                        # miss again: was evicted
    assert cache.misses == 3
    stats = cache.stats()
    assert stats["entries"] == 1 and 0 < stats["hit_rate"] < 1


def test_cache_capacity_zero_never_retains():
    _dense, wire = _wire_leaf((4, 4))
    cache = LRUDequantCache(0)
    for _ in range(3):
        cache.get("k", wire)
    assert cache.hits == 0 and cache.misses == 3
    assert cache.live_bytes == 0 and cache.evictions == 3


def test_cache_oversized_leaf_still_served():
    _dense, wire = _wire_leaf((32, 32))           # 4 KiB dense
    cache = LRUDequantCache(16)
    out = cache.get("big", wire)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(wire.restore()))
    assert cache.live_bytes <= 16 and cache.evictions == 1


def test_cache_rejects_negative_capacity():
    with pytest.raises(ValueError, match="capacity_bytes"):
        LRUDequantCache(-1)


# --------------------------------------------------------------------------
# Engine correctness.
# --------------------------------------------------------------------------


def test_engine_logits_match_one_shot_deploy(tiny, engine):
    """The lazy-wire-leaf engine must serve the SAME function as
    launch.serve's ternary_deploy(packed=True) — same codec spec, same
    wire round-trip, same kernels."""
    from repro.launch.serve import ternary_deploy
    from repro.models.transformer import forward

    cfg, params = tiny
    served, wire_bytes, _, _ = ternary_deploy(
        params, FTTQConfig(), packed=True, residual="fp16")
    assert engine.wire_bytes == wire_bytes     # identical artifact
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0,
                              cfg.vocab_size)
    le = engine.forward(toks)
    lr, _, _ = forward(cfg, served, toks)
    np.testing.assert_allclose(np.asarray(le), np.asarray(lr),
                               rtol=1e-5, atol=1e-5)


def test_engine_packed_weights_stay_2bit(engine):
    # packed matmul weights occupy far less than their dense fp32 size
    assert 0 < engine.packed_weight_bytes < engine.lazy_wire_bytes_dense
    toks = jnp.zeros((1, 4), jnp.int32)
    engine.forward(toks)
    engine.forward(toks)            # second forward hits the warm cache
    s = engine.stats()
    assert s["cache"]["hits"] > 0


def test_engine_rejects_oversized_batch(engine, tiny):
    cfg, _ = tiny
    toks = jnp.zeros((engine.max_batch + 1, 4), jnp.int32)
    with pytest.raises(ValueError, match="max_batch"):
        engine.forward(toks)
    with pytest.raises(ValueError, match="max_batch"):
        ServeEngine(cfg, tiny[1], max_batch=0)


def test_engine_tight_cache_still_correct(tiny):
    """With a cache too small for even one leaf the engine decodes every
    forward — slower, never wrong, never over budget."""
    from repro.models.transformer import forward

    cfg, params = tiny
    tight = ServeEngine(cfg, params, max_batch=2, cache_capacity_bytes=64)
    roomy = ServeEngine(cfg, params, max_batch=2)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 5), 0,
                              cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(tight.forward(toks)),
                               np.asarray(roomy.forward(toks)),
                               rtol=1e-6, atol=1e-6)
    assert tight.cache.live_bytes <= 64
    assert tight.cache.evictions > 0


# --------------------------------------------------------------------------
# Closed-loop load generation.
# --------------------------------------------------------------------------


def test_closed_loop_report_sanity(engine):
    rep = run_closed_loop(engine, n_requests=6, offered_qps=500.0,
                          prompt_len=4, seed=1)
    assert rep.n_requests == 6
    assert rep.p99_ms >= rep.p50_ms > 0
    assert rep.mean_ms > 0 and rep.wall_s > 0
    assert 1.0 <= rep.mean_batch <= engine.max_batch
    assert rep.achieved_qps > 0
    row = rep.row()
    assert row["offered_qps"] == 500.0 and "cache" in row


def test_closed_loop_batches_under_pressure(tiny):
    """Offered load far past capacity must coalesce requests: the mean
    batch size exceeds 1 and approaches max_batch."""
    cfg, params = tiny
    eng = ServeEngine(cfg, params, max_batch=4)
    rep = run_closed_loop(eng, n_requests=8, offered_qps=10_000.0,
                          prompt_len=4, seed=2)
    assert rep.mean_batch > 1.5


def test_closed_loop_validates_args(engine):
    with pytest.raises(ValueError):
        run_closed_loop(engine, n_requests=0, offered_qps=1.0)
    with pytest.raises(ValueError):
        run_closed_loop(engine, n_requests=1, offered_qps=0.0)
