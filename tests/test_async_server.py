"""Buffered-asynchronous server tests: end-to-end learning parity with the
synchronous path, staleness bookkeeping, and channel-measured wall clock."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import ChannelConfig
from repro.core import FTTQConfig
from repro.data import partition_iid, synthetic_classification
from repro.fed import FedConfig, run_federated
from repro.models.paper_models import init_mlp_mnist, mlp_mnist
from repro.optim import adam


@pytest.fixture(scope="module")
def task():
    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 1500, 10, 784, noise=3.0, n_test=400
    )
    clients = partition_iid(x, y, 5)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        logp = jax.nn.log_softmax(logits, -1)
        loss = -jnp.mean(jnp.take_along_axis(logp, yt_j[:, None], -1))
        return float(acc), float(loss)

    return clients, params, eval_fn


def _cfg(mode, **kw):
    base = dict(algorithm="tfedavg", mode=mode, participation=1.0,
                local_epochs=3, batch_size=32, rounds=12, fttq=FTTQConfig())
    base.update(kw)
    return FedConfig(**base)


def test_async_matches_sync_accuracy(task):
    """Buffered async T-FedAvg reaches accuracy within noise of sync while
    logging per-client transfer times from the channel model."""
    clients, params, eval_fn = task
    res_s = run_federated(mlp_mnist, params, clients, _cfg("sync"),
                          adam(2e-3), eval_fn, eval_every=12)
    res_a = run_federated(mlp_mnist, params, clients, _cfg("async", buffer_k=3),
                          adam(2e-3), eval_fn, eval_every=12)
    assert res_a.accuracy[-1] > 0.5
    assert res_a.accuracy[-1] > res_s.accuracy[-1] - 0.1
    # channel bookkeeping: every dispatch logged a down + up transfer
    assert res_a.transfer_summary["n_transfers"] > 0
    assert res_a.transfer_summary["total_seconds"] > 0
    assert len(res_a.staleness_per_agg) >= res_a.rounds_run
    assert res_a.rounds_run == 12


def test_async_buffered_aggregation_counts(task):
    clients, params, eval_fn = task
    cfg = _cfg("async", rounds=4, buffer_k=2, local_epochs=1)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=2)
    assert res.rounds_run == 4
    assert res.participants_per_round == [2, 2, 2, 2]
    # bytes are measured from serialized buffers: a ternary client upload of
    # the 24,330-param MLP is ~6.3 KB framed; 4 aggs × K=2 arrivals plus the
    # in-flight tail must land in that ballpark, never at fp32 scale.
    n_arrivals = len(res.staleness_per_agg)
    assert n_arrivals >= 8
    per_upload = res.upload_bytes / n_arrivals
    assert 5_000 < per_upload < 12_000


def test_async_fedavg_runs(task):
    clients, params, eval_fn = task
    cfg = _cfg("async", algorithm="fedavg", rounds=3, buffer_k=2, local_epochs=1)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=3)
    assert res.rounds_run == 3
    assert res.upload_bytes > res.download_bytes * 0.5  # both directions metered


def test_async_staleness_discount_weights(task):
    """With a very heterogeneous channel, stale arrivals appear and are
    recorded; training still converges (discounted, not discarded)."""
    clients, params, eval_fn = task
    chan = ChannelConfig(mean_bandwidth_bytes_s=5e5, bandwidth_sigma=1.5,
                         compute_speed_sigma=1.0)
    cfg = _cfg("async", rounds=8, buffer_k=2, channel=chan,
               staleness_exponent=0.5, local_epochs=2)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(2e-3),
                        eval_fn, eval_every=8)
    assert max(res.staleness_per_agg) >= 1      # genuine staleness occurred
    assert res.accuracy[-1] > 0.5


def test_unknown_mode_rejected(task):
    clients, params, eval_fn = task
    with pytest.raises(ValueError, match="unknown federated mode"):
        run_federated(mlp_mnist, params, clients, _cfg("bogus"),
                      adam(1e-3), eval_fn)
