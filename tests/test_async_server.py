"""Buffered-asynchronous server tests: end-to-end learning parity with the
synchronous path, staleness bookkeeping, and channel-measured wall clock."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import ChannelConfig
from repro.core import FTTQConfig
from repro.data import partition_iid, synthetic_classification
from repro.fed import FedConfig, run_federated
from repro.models.paper_models import init_mlp_mnist, mlp_mnist
from repro.optim import adam


@pytest.fixture(scope="module")
def task():
    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 1500, 10, 784, noise=3.0, n_test=400
    )
    clients = partition_iid(x, y, 5)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        acc = jnp.mean(jnp.argmax(logits, -1) == yt_j)
        logp = jax.nn.log_softmax(logits, -1)
        loss = -jnp.mean(jnp.take_along_axis(logp, yt_j[:, None], -1))
        return float(acc), float(loss)

    return clients, params, eval_fn


def _cfg(mode, **kw):
    base = dict(algorithm="tfedavg", mode=mode, participation=1.0,
                local_epochs=3, batch_size=32, rounds=12, fttq=FTTQConfig())
    base.update(kw)
    return FedConfig(**base)


def test_async_matches_sync_accuracy(task):
    """Buffered async T-FedAvg reaches accuracy within noise of sync while
    logging per-client transfer times from the channel model."""
    clients, params, eval_fn = task
    res_s = run_federated(mlp_mnist, params, clients, _cfg("sync"),
                          adam(2e-3), eval_fn, eval_every=12)
    res_a = run_federated(mlp_mnist, params, clients, _cfg("async", buffer_k=3),
                          adam(2e-3), eval_fn, eval_every=12)
    assert res_a.accuracy[-1] > 0.5
    assert res_a.accuracy[-1] > res_s.accuracy[-1] - 0.1
    # channel bookkeeping: every dispatch logged a down + up transfer
    assert res_a.transfer_summary["n_transfers"] > 0
    assert res_a.transfer_summary["total_seconds"] > 0
    assert len(res_a.staleness_per_agg) >= res_a.rounds_run
    assert res_a.rounds_run == 12


def test_async_buffered_aggregation_counts(task):
    clients, params, eval_fn = task
    cfg = _cfg("async", rounds=4, buffer_k=2, local_epochs=1)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=2)
    assert res.rounds_run == 4
    assert res.participants_per_round == [2, 2, 2, 2]
    # bytes are measured from serialized buffers: a ternary client upload of
    # the 24,330-param MLP is ~6.3 KB framed; 4 aggs × K=2 arrivals plus the
    # in-flight tail must land in that ballpark, never at fp32 scale.
    n_arrivals = len(res.staleness_per_agg)
    assert n_arrivals >= 8
    per_upload = res.upload_bytes / n_arrivals
    assert 5_000 < per_upload < 12_000


def test_async_fedavg_runs(task):
    clients, params, eval_fn = task
    cfg = _cfg("async", algorithm="fedavg", rounds=3, buffer_k=2, local_epochs=1)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=3)
    assert res.rounds_run == 3
    assert res.upload_bytes > res.download_bytes * 0.5  # both directions metered


def test_async_staleness_discount_weights(task):
    """With a very heterogeneous channel, stale arrivals appear and are
    recorded; training still converges (discounted, not discarded)."""
    clients, params, eval_fn = task
    chan = ChannelConfig(mean_bandwidth_bytes_s=5e5, bandwidth_sigma=1.5,
                         compute_speed_sigma=1.0)
    cfg = _cfg("async", rounds=8, buffer_k=2, channel=chan,
               staleness_exponent=0.5, local_epochs=2)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(2e-3),
                        eval_fn, eval_every=8)
    assert max(res.staleness_per_agg) >= 1      # genuine staleness occurred
    assert res.accuracy[-1] > 0.5


def test_unknown_mode_rejected(task):
    clients, params, eval_fn = task
    with pytest.raises(ValueError, match="unknown federated mode"):
        run_federated(mlp_mnist, params, clients, _cfg("bogus"),
                      adam(1e-3), eval_fn)


# ---------------------------------------------------------------------------
# Scenario layer: availability, staleness cap, adaptive buffer_k, loss.
# ---------------------------------------------------------------------------


def test_async_under_diurnal_churn_and_loss(task):
    """The acceptance scenario: buffered-async T-FedAvg completes under
    diurnal churn + 1% packet loss and reports the scenario telemetry."""
    from repro.fed import AvailabilityConfig

    clients, params, eval_fn = task
    chan = ChannelConfig(loss_rate=0.01, chunk_bytes=1024)
    cfg = _cfg("async", rounds=6, buffer_k=2, local_epochs=1, channel=chan,
               availability=AvailabilityConfig(kind="diurnal", period_s=20.0,
                                               floor=0.2, n_cohorts=2))
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=6)
    assert res.rounds_run == 6
    tel = res.telemetry
    assert tel["availability"] == "diurnal"
    assert tel["retrans_bytes"] > 0                  # 1% loss left a trail
    assert 0 < tel["goodput_fraction"] < 1
    assert sum(tel["staleness_hist"]) == len(res.staleness_per_agg)
    # deterministic replay: the same seeds give the same run
    res2 = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                         eval_fn, eval_every=6)
    assert res2.upload_bytes == res.upload_bytes
    assert res2.accuracy == res.accuracy
    assert res2.telemetry["retrans_bytes"] == tel["retrans_bytes"]


def test_async_staleness_cap_drops_and_accounts(task):
    """With a hard cap of 1 on a very heterogeneous fleet, over-stale
    arrivals are dropped — and their wasted bytes are accounted."""
    clients, params, eval_fn = task
    chan = ChannelConfig(mean_bandwidth_bytes_s=3e5, bandwidth_sigma=2.0,
                         compute_speed_sigma=1.5)
    base = dict(rounds=10, buffer_k=1, local_epochs=1, channel=chan,
                staleness_exponent=0.5)
    uncapped = run_federated(
        mlp_mnist, params, clients, _cfg("async", **base), adam(1e-3),
        eval_fn, eval_every=10)
    assert max(uncapped.staleness_per_agg) > 1   # the fleet really is stale
    capped = run_federated(
        mlp_mnist, params, clients, _cfg("async", max_staleness=1, **base),
        adam(1e-3), eval_fn, eval_every=10)
    tel = capped.telemetry
    assert tel["dropped_updates"] > 0
    assert tel["dropped_update_bytes"] > 0
    assert capped.rounds_run == 10               # progress despite drops
    # dropped arrivals still appear in the staleness histogram and ledger
    assert sum(tel["staleness_hist"]) == len(capped.staleness_per_agg)


def test_async_staleness_downweight_policy(task):
    clients, params, eval_fn = task
    chan = ChannelConfig(mean_bandwidth_bytes_s=3e5, bandwidth_sigma=2.0,
                         compute_speed_sigma=1.5)
    cfg = _cfg("async", rounds=6, buffer_k=1, local_epochs=1, channel=chan,
               max_staleness=1, staleness_policy="downweight")
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=6)
    assert res.rounds_run == 6
    assert res.telemetry["dropped_updates"] == 0  # down-weighted, not dropped


def test_async_invalid_staleness_policy_rejected(task):
    clients, params, eval_fn = task
    with pytest.raises(ValueError, match="staleness_policy"):
        run_federated(mlp_mnist, params, clients,
                      _cfg("async", staleness_policy="bogus"),
                      adam(1e-3), eval_fn)


def test_async_adaptive_buffer_tracks_target(task):
    """The controller retunes buffer_k from the observed arrival rate and
    records its trajectory; an explicit target with slow arrivals should
    push K up toward concurrency."""
    clients, params, eval_fn = task
    cfg = _cfg("async", rounds=8, buffer_k=1, local_epochs=1,
               adaptive_buffer=True, target_mix_latency_s=10.0)
    res = run_federated(mlp_mnist, params, clients, cfg, adam(1e-3),
                        eval_fn, eval_every=8)
    traj = res.telemetry["buffer_k_per_agg"]
    assert len(traj) == res.rounds_run
    assert traj[0] == 1                      # starts at the configured K
    assert max(traj) > 1                     # 10 s ≫ inter-arrival gap: K grows
    assert all(1 <= k <= 5 for k in traj)    # clamped to [1, concurrency]


def test_async_nic_cap_slows_uploads_but_not_bytes(task):
    """Async uploads now contend for the server NIC: capping it stretches
    simulated time while every byte count stays identical."""
    clients, params, eval_fn = task
    base = dict(rounds=4, buffer_k=2, local_epochs=1)
    wide = run_federated(
        mlp_mnist, params, clients, _cfg("async", **base), adam(1e-3),
        eval_fn, eval_every=4)
    chan = ChannelConfig(server_bandwidth_bytes_s=2e4)
    narrow = run_federated(
        mlp_mnist, params, clients, _cfg("async", channel=chan, **base),
        adam(1e-3), eval_fn, eval_every=4)
    assert narrow.upload_bytes == wide.upload_bytes
    assert narrow.download_bytes == wide.download_bytes
    assert narrow.transfer_summary["total_seconds"] > \
        wide.transfer_summary["total_seconds"]
