"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes + no NaNs.
(The FULL configs are exercised via the dry-run only — ShapeDtypeStruct,
no allocation.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models.transformer import (
    forward, init_cache, init_params, loss_fn, decode_step, param_count,
)
from repro.optim import adam
from repro.train import TrainerConfig, init_train_state, make_train_step


def _batch_for(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.family == "audio":
        batch["embeds"] = jax.random.normal(ks[0], (b, s, cfg.d_model)) * 0.02
    else:
        batch["tokens"] = jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vision_embeds"] = (
            jax.random.normal(ks[2], (b, cfg.n_patches, cfg.d_model)) * 0.02
        )
    return batch


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = C.get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    logits, _, aux = forward(
        cfg, params, batch.get("tokens"),
        embeds=batch.get("embeds"), vision_embeds=batch.get("vision_embeds"),
    )
    b = 2; s = 16
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = C.get_reduced(arch)
    tcfg = TrainerConfig(qat=True, pod_compression=False, grad_clip=1.0)
    opt = adam(3e-3)
    state = init_train_state(cfg, tcfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg, opt))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    state, m0 = step(state, batch)
    for _ in range(4):
        state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < float(m0["loss"])  # memorizes one batch
    assert int(state.step) == 5


@pytest.mark.parametrize("arch", [a for a in C.ARCH_IDS
                                  if C.get_reduced(a).causal])
def test_decode_matches_prefill(arch):
    # MoE: capacity drops differ between batched prefill and step-wise
    # decode (expected — GShard semantics); test the cache path without
    # drops by over-provisioning capacity.
    overrides = {"capacity_factor": 16.0} if C.get_reduced(arch).n_experts else {}
    cfg = C.get_reduced(arch, **overrides)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 10
    batch = _batch_for(cfg, jax.random.PRNGKey(1), b=b, s=s)
    vk = {"vision_embeds": batch.get("vision_embeds")} if cfg.family == "vlm" else {}
    full, _, _ = forward(cfg, params, batch["tokens"], **vk)
    cache = init_cache(cfg, b, s + 2)
    outs = []
    for t in range(s):
        lg, cache = decode_step(cfg, params, batch["tokens"][:, t:t + 1],
                                cache, t, **vk)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=3e-3, atol=3e-3,
    )


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_shape_cell_applicability(arch):
    cfg = C.get_config(arch)
    runnable = [s for s in C.SHAPES if C.applicable(cfg, s)[0]]
    assert "train_4k" in runnable
    assert "prefill_32k" in runnable
    if arch == "hubert-xlarge":
        assert "decode_32k" not in runnable
    if cfg.family in ("ssm", "hybrid") or cfg.sliding_window:
        assert "long_500k" in runnable
    else:
        assert "long_500k" not in runnable


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_input_specs_no_allocation(arch):
    cfg = C.get_config(arch)  # FULL config — specs only, no arrays
    for shape_name in C.SHAPES:
        if not C.applicable(cfg, shape_name)[0]:
            continue
        specs = C.input_specs(cfg, shape_name)
        for leaf in jax.tree_util.tree_leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_full_param_counts_match_labels():
    """Full configs hit their published sizes (±15%)."""
    expected = {
        "granite-20b": 20e9, "gemma3-4b": 4e9, "olmo-1b": 1.2e9,
        "yi-9b": 8.8e9, "zamba2-1.2b": 1.2e9, "mamba2-370m": 0.37e9,
        "llama-3.2-vision-11b": 11e9, "qwen3-moe-30b-a3b": 30e9,
        "deepseek-moe-16b": 16e9, "hubert-xlarge": 0.96e9,
    }
    for arch, target in expected.items():
        n = param_count(C.get_config(arch))
        assert abs(n - target) / target < 0.15, (arch, n, target)
