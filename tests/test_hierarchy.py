"""Edge-aggregation tier: flat-equivalence (lossless 2-tier == one flat
Aggregator — bit-identical under exact arithmetic), edge-requantize
statistics, the byte ledger, and both servers running with the tier on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.wire import encode_update
from repro.core import CodecSpec, FTTQConfig, compress_pytree
from repro.core import fttq as F
from repro.core.tfedavg import client_update_payload, server_requantize
from repro.data import partition_iid, synthetic_classification
from repro.fed import FedConfig, run_federated
from repro.fed.aggregator import Aggregator
from repro.fed.hierarchy import EdgeTier, HierarchyConfig, edge_of, edges_of
from repro.models.paper_models import init_mlp_mnist, mlp_mnist
from repro.optim import adam

CFG = FTTQConfig()


def _tree_equal(a, b, *, atol=0.0):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb)
    for (pa, la), (pb, lb) in zip(fa, fb):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        assert la.dtype == lb.dtype, (pa, la.dtype, lb.dtype)
        if atol == 0.0:
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb), err_msg=str(pa)
            )
        else:
            np.testing.assert_allclose(
                np.asarray(la, np.float32), np.asarray(lb, np.float32),
                atol=atol, rtol=1e-5, err_msg=str(pa),
            )


# --------------------------------------------------------------------------
# Edge assignment.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("assignment", ["mod", "block"])
def test_edges_of_matches_scalar(assignment):
    cfg = HierarchyConfig(n_edges=7, assignment=assignment)
    ids = np.arange(100)
    vec = edges_of(ids, 100, cfg)
    assert vec.tolist() == [edge_of(int(k), 100, cfg) for k in ids]
    assert vec.min() >= 0 and vec.max() < 7


def test_hierarchy_config_guards():
    assert not HierarchyConfig().enabled
    assert HierarchyConfig(n_edges=4).enabled
    with pytest.raises(ValueError, match="n_edges"):
        EdgeTier(HierarchyConfig(n_edges=0), CFG, 10)
    with pytest.raises(ValueError, match="assignment"):
        edge_of(0, 10, HierarchyConfig(n_edges=2, assignment="nope"))


# --------------------------------------------------------------------------
# Tier equivalence: lossless 2-tier == flat.
# --------------------------------------------------------------------------


def _exact_tree(rng):
    """Integer-valued fp32 leaves: every sum/mean below stays exact in fp32
    (values bounded, counts powers of two), so flat-vs-tier equality can be
    asserted BIT-IDENTICAL, not approximately. Ragged (n % 4 ≠ 0), stacked,
    bias, and int-counter leaves cover every aggregation corner."""
    def ints(shape):
        return rng.integers(-8, 9, size=shape).astype(np.float32)

    return {
        "enc": {"w": jnp.asarray(ints((17, 9))), "b": jnp.asarray(ints((9,)))},
        "stack": {"w": jnp.asarray(ints((3, 8, 12)))},
        "head": {"w": jnp.asarray(ints((12, 5)))},
        "steps": jnp.asarray(7, jnp.int32),
    }


@pytest.mark.parametrize("n_edges,assignment", [
    (1, "mod"), (2, "mod"), (4, "mod"), (2, "block"),
])
def test_lossless_tier_bit_identical_to_flat(n_edges, assignment):
    """requantize_at_edge=False: the 2-tier weighted mean over exact
    fp32 inputs equals one flat Aggregator over the union of clients,
    bit for bit — weights compose as W_e = Σ_{k∈e} w_k."""
    rng = np.random.default_rng(0)
    n_clients = 8                        # power of two per edge for 1/2/4
    blobs = [encode_update(_exact_tree(rng)) for _ in range(n_clients)]

    flat = Aggregator(chunk_c=4)
    tier = EdgeTier(
        HierarchyConfig(n_edges=n_edges, requantize_at_edge=False,
                        assignment=assignment, edge_chunk_c=4),
        CFG, n_clients,
    )
    for k, b in enumerate(blobs):
        flat.add(b, weight=1.0)
        tier.add(k, b, weight=1.0)
    mean_tier, info = tier.fold()
    assert info["edges_active"] == n_edges
    _tree_equal(flat.finalize(), mean_tier, atol=0.0)


def test_lossless_tier_close_to_flat_general_inputs():
    """General fp inputs + real ternary client payloads + a mixed-codec
    (fp16 residual) variant: 2-tier mean within fp tolerance of flat."""
    spec = CodecSpec(kind="ternary", residual="fp16", fttq=CFG)
    blobs = []
    for c in range(6):
        k = jax.random.split(jax.random.PRNGKey(c), 3)
        params = {
            "enc": {"w": jax.random.normal(k[0], (17, 9))},
            "stack": {"w": jax.random.normal(k[1], (3, 8, 12))},
            "head": {"b": jax.random.normal(k[2], (5,))},
        }
        payload = client_update_payload(params, F.init_wq_tree(params, CFG),
                                        CFG)
        if c % 2:
            payload, _ = compress_pytree(payload, spec)
        blobs.append(encode_update(payload))

    flat = Aggregator(chunk_c=4)
    tier = EdgeTier(HierarchyConfig(n_edges=3, requantize_at_edge=False),
                    CFG, len(blobs))
    for k, b in enumerate(blobs):
        flat.add(b, weight=10.0 + 3 * k)
        tier.add(k, b, weight=10.0 + 3 * k)
    _tree_equal(flat.finalize(), tier.fold()[0], atol=1e-5)


def test_cohort_add_equals_individual_adds():
    """add_cohort(w=Σw_k, n) folds exactly like n individual adds of the
    byte-identical blob (power-of-two weights keep the sum exact), while
    booking n× the wire bytes."""
    rng = np.random.default_rng(3)
    blob = encode_update(_exact_tree(rng))
    other = encode_update(_exact_tree(rng))

    a = EdgeTier(HierarchyConfig(n_edges=2), CFG, 8)
    for k in (0, 2, 4, 6):
        a.add(k, blob, weight=2.0)
    a.add(1, other, weight=4.0)
    b = EdgeTier(HierarchyConfig(n_edges=2), CFG, 8)
    b.add_cohort(0, blob, weight=8.0, n_clients=4)
    b.add(1, other, weight=4.0)

    _tree_equal(a.fold()[0], b.fold()[0], atol=0.0)
    ta, tb = a.telemetry(), b.telemetry()
    assert ta["client_to_edge_bytes"] == tb["client_to_edge_bytes"]
    assert ta["clients_per_edge"] == tb["clients_per_edge"] == [4, 1]


# --------------------------------------------------------------------------
# Edge requantization.
# --------------------------------------------------------------------------


def test_requantize_tier_single_edge_matches_server_requantize():
    """One edge, requantize on: the tier's fold is exactly
    server_requantize(edge mean) shipped over the wire and dequantized by
    the root aggregator."""
    rng = np.random.default_rng(1)
    blobs = [encode_update(_exact_tree(rng)) for _ in range(4)]
    flat = Aggregator(chunk_c=4)
    tier = EdgeTier(HierarchyConfig(n_edges=1), CFG, 4)
    for k, b in enumerate(blobs):
        flat.add(b, weight=1.0)
        tier.add(k, b, weight=1.0)
    root = Aggregator(chunk_c=16)
    root.add(encode_update(server_requantize(flat.finalize(), CFG)),
             weight=4.0)
    _tree_equal(root.finalize(), tier.fold()[0], atol=0.0)


def test_requantize_shrinks_upstream_bytes():
    """The edge→root hop ships 2-bit codes instead of fp32: upstream bytes
    per edge come in far under the dense record."""
    k = jax.random.split(jax.random.PRNGKey(5), 2)
    params = {"w1": jax.random.normal(k[0], (64, 64)),
              "w2": jax.random.normal(k[1], (64, 32))}
    blob = encode_update(params)
    outs = {}
    for requant in (False, True):
        tier = EdgeTier(HierarchyConfig(n_edges=1,
                                        requantize_at_edge=requant),
                        CFG, 4)
        for c in range(4):
            tier.add(c, blob, weight=1.0)
        tier.fold()
        outs[requant] = int(tier.upstream_bytes.sum())
    assert outs[True] < outs[False] / 3, outs


def test_requantize_unbiased_over_seeds():
    """FTTQ requantization error is (approximately) zero-mean over seeds:
    averaging edge-requantized regional means across many seeded fleets
    does not drift from the average of the dense means. This is what keeps
    a tier of lossy edges from biasing the global model."""
    err_sum, dense_scale, n = 0.0, 0.0, 0
    for seed in range(12):
        k = jax.random.split(jax.random.PRNGKey(seed), 2)
        params = {"a": jax.random.normal(k[0], (32, 24)),
                  "b": jax.random.normal(k[1], (24, 16))}
        blob = encode_update(params)
        tier = EdgeTier(HierarchyConfig(n_edges=1), CFG, 2)
        tier.add(0, blob, weight=1.0)
        requant, _ = tier.fold()
        for leaf_d, leaf_q in zip(jax.tree_util.tree_leaves(params),
                                  jax.tree_util.tree_leaves(requant)):
            d = np.asarray(leaf_d, np.float64)
            q = np.asarray(leaf_q, np.float64)
            err_sum += float((q - d).sum())
            dense_scale += float(np.abs(d).sum())
            n += d.size
    # |mean signed error| ≪ mean magnitude — no systematic drift.
    assert abs(err_sum / n) < 0.02 * (dense_scale / n), (err_sum / n)


# --------------------------------------------------------------------------
# Byte ledger.
# --------------------------------------------------------------------------


def test_ledger_balances_and_accumulates_across_folds():
    rng = np.random.default_rng(2)
    blob = encode_update(_exact_tree(rng))
    tier = EdgeTier(HierarchyConfig(n_edges=2), CFG, 8)
    for round_ in range(3):
        for k in range(6):
            tier.add(k, blob, weight=1.0, staleness=float(round_))
        tier.fold()
    t = tier.telemetry()
    assert t["ledger_balanced"]
    assert t["client_to_edge_bytes"] == 3 * 6 * len(blob)
    assert t["edge_to_root_bytes"] == t["root_ingest_bytes"] > 0
    assert t["folds"] == 3
    assert sum(t["clients_per_edge"]) == 18
    assert sum(t["bytes_per_edge"]) == t["client_to_edge_bytes"]
    assert sum(t["upstream_bytes_per_edge"]) == t["edge_to_root_bytes"]
    # mod assignment over k∈0..5: edges see staleness means equal by
    # symmetry — rounds 0,1,2 → mean 1.0 on both edges.
    assert t["mean_staleness_per_edge"] == [1.0, 1.0]
    with pytest.raises(ValueError, match="no client updates"):
        tier.fold()


# --------------------------------------------------------------------------
# Both servers with the tier enabled.
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def task():
    x, y, _xt, _yt = synthetic_classification(
        jax.random.PRNGKey(0), 800, 10, 784, noise=3.0, n_test=100
    )
    clients = partition_iid(x, y, 6)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    return clients, params


def _eval_none(_p):
    return 0.0, 0.0


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_servers_run_with_hierarchy(task, mode):
    clients, params = task
    cfg = FedConfig(
        algorithm="tfedavg", mode=mode, participation=1.0, local_epochs=1,
        batch_size=32, rounds=3, buffer_k=3,
        hierarchy=HierarchyConfig(n_edges=2),
    )
    res = run_federated(mlp_mnist, params, clients, cfg, adam(2e-3),
                        _eval_none, eval_every=10)
    hier = res.telemetry["hierarchy"]
    assert hier["ledger_balanced"]
    assert hier["n_edges"] == 2
    assert hier["folds"] == 3
    assert hier["client_to_edge_bytes"] > 0
    # root ingress (the edge→root hop) is metered into upload_bytes on top
    # of the client→edge bytes.
    assert res.upload_bytes == (hier["client_to_edge_bytes"]
                                + hier["edge_to_root_bytes"])


def test_sync_hierarchy_learns(task):
    """The tier is not a bytes-only stunt: a 2-tier requantizing run still
    trains (loss moves the same direction as flat)."""
    clients, params = task
    cfg = FedConfig(algorithm="tfedavg", participation=1.0, local_epochs=2,
                    batch_size=32, rounds=6,
                    hierarchy=HierarchyConfig(n_edges=3))
    x = jnp.asarray(np.concatenate([c.x[:50] for c in clients]))
    y = jnp.asarray(np.concatenate([c.y[:50] for c in clients]))

    def eval_fn(p):
        logits = mlp_mnist(p, x)
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return float(acc), 0.0

    res = run_federated(mlp_mnist, params, clients, cfg, adam(2e-3),
                        eval_fn, eval_every=6)
    assert res.accuracy[-1] > 0.3, res.accuracy
