"""Adaptive compression controller: error-feedback residual round trip,
controller-off bit-exactness vs the pre-controller servers, determinism
under fixed seeds, and mixed-codec rounds through the Aggregator's dense
fallback (mean exactness + the robust-rule refusal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import decode_update, encode_update
from repro.core import CodecSpec, compress_pytree, decompress_pytree
from repro.fed import (
    Aggregator,
    ControllerConfig,
    DefenseConfig,
    FedConfig,
    FleetConfig,
    run_federated,
    run_fleet,
)
from repro.fed.controller import LADDER, CompressionController, make_controller


def _tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "layer": {
            "w": jax.random.normal(k1, (48, 24)),
            "bias": jax.random.normal(k2, (24,)) * 0.1,
        },
        "norm_scale": jnp.arange(8.0) / 8.0,
    }


def _l2(tree):
    return sum(
        float(jnp.sum(jnp.asarray(x, jnp.float32) ** 2))
        for x in jax.tree_util.tree_leaves(tree)
    ) ** 0.5


@pytest.fixture(scope="module")
def fed_task():
    from repro.data import partition_iid, synthetic_classification
    from repro.models.paper_models import init_mlp_mnist, mlp_mnist

    x, y, xt, yt = synthetic_classification(
        jax.random.PRNGKey(0), 600, 10, 784, noise=3.0, n_test=100
    )
    clients = partition_iid(x, y, 4)
    params = init_mlp_mnist(jax.random.PRNGKey(1))
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def eval_fn(p):
        logits = mlp_mnist(p, xt_j)
        return float(jnp.mean(jnp.argmax(logits, -1) == yt_j)), 0.0

    return clients, params, mlp_mnist, eval_fn


def _run(fed_task, ctrl, *, mode="sync", rounds=3, seed=3, **kw):
    from repro.optim import adam

    clients, params, apply_fn, eval_fn = fed_task
    cfg = FedConfig(algorithm="tfedavg", mode=mode, participation=1.0,
                    local_epochs=1, batch_size=32, rounds=rounds, seed=seed,
                    controller=ctrl, **kw)
    return run_federated(apply_fn, params, clients, cfg, adam(1e-3),
                         eval_fn, eval_every=rounds)


# --------------------------------------------------------------------------
# Error-feedback residual round trip.
# --------------------------------------------------------------------------


def test_error_feedback_residual_roundtrip():
    """The STC telescoping property: encoding the SAME tree repeatedly with
    the residual folded back makes the running mean of the decodes converge
    to the true tree (Σ decode_t = n·tree − residual_n), so the mean beats
    any one-shot lossy encode — and the carried residual stays bounded
    rather than accumulating."""
    tree = _tree(2)
    ef_spec = CodecSpec(kind="topk", topk_fraction=0.1, error_feedback=True)

    acc = jax.tree_util.tree_map(jnp.zeros_like, tree)
    res = None
    n = 6
    for _ in range(n):
        wire, res = compress_pytree(tree, ef_spec, residual=res)
        acc = jax.tree_util.tree_map(
            lambda a, d: a + d, acc, decompress_pytree(wire)
        )
    mean = jax.tree_util.tree_map(lambda a: a / n, acc)

    def rel_err(got):
        return _l2(jax.tree_util.tree_map(
            lambda a, b: a - b, got, tree)) / _l2(tree)

    one_shot, no_res = compress_pytree(
        tree, CodecSpec(kind="topk", topk_fraction=0.1)
    )
    assert no_res is None
    assert rel_err(mean) < rel_err(decompress_pytree(one_shot))
    # feedback drains: a dropped coordinate waits at most ~1/topk_fraction
    # encodes before its accumulated residual makes the top-k cut, so the
    # residual plateaus near ‖tree‖/fraction instead of growing without
    # bound — keep encoding and check it stays under that ceiling.
    for _ in range(2 * n):
        _, res = compress_pytree(tree, ef_spec, residual=res)
    assert _l2(res) < _l2(tree) / ef_spec.topk_fraction


def test_error_feedback_off_matches_legacy_bytes():
    """EF-off (the default spec) returns no residual and its serialized
    wire bytes are deterministic call to call."""
    tree = _tree(5)
    spec = CodecSpec(kind="topk16", topk_fraction=0.2)
    wire_a, res_a = compress_pytree(tree, spec)
    wire_b, res_b = compress_pytree(tree, spec)
    assert res_a is None and res_b is None
    assert encode_update(wire_a) == encode_update(wire_b)


def test_residual_tree_shapes_match_input():
    """EF residual trees stay structure-aligned with the input so they can
    be carried round to round (zeros for losslessly-shipped leaves)."""
    tree = _tree(7)
    _, res = compress_pytree(
        tree, CodecSpec(kind="ternary", error_feedback=True)
    )
    for got, want in zip(jax.tree_util.tree_leaves(res),
                         jax.tree_util.tree_leaves(tree)):
        assert np.shape(got) == np.shape(want)
    # the raw-shipped norm_scale leaf round-trips exactly: zero residual
    assert float(jnp.max(jnp.abs(res["norm_scale"]))) == 0.0


# --------------------------------------------------------------------------
# Controller-off bit-exactness + determinism.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_controller_off_bitexact(fed_task, mode):
    """controller=None (default) and ControllerConfig(enabled=False) both
    reproduce the pre-controller servers exactly — same bytes, same
    accuracy trajectory, and no controller telemetry key."""
    r_none = _run(fed_task, None, mode=mode)
    r_off = _run(fed_task, ControllerConfig(enabled=False), mode=mode)
    assert r_none.upload_bytes == r_off.upload_bytes
    assert r_none.download_bytes == r_off.download_bytes
    assert r_none.accuracy == r_off.accuracy
    assert "controller" not in r_none.telemetry
    assert "controller" not in r_off.telemetry
    assert make_controller(FedConfig(controller=None)) is None
    assert make_controller(FedConfig(
        controller=ControllerConfig(enabled=False))) is None


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_controller_deterministic_under_fixed_seed(fed_task, mode):
    ctrl = ControllerConfig(warmup_encodes=1, divergence_high=1e9)
    a = _run(fed_task, ctrl, mode=mode)
    b = _run(fed_task, ctrl, mode=mode)
    assert a.upload_bytes == b.upload_bytes
    assert a.accuracy == b.accuracy
    assert a.telemetry["controller"] == b.telemetry["controller"]
    # divergence_high=1e9 forces the aggressive rung after warmup, so the
    # adaptive run must ship fewer upstream bytes than static ternary
    static = _run(fed_task, None, mode=mode)
    assert a.upload_bytes < static.upload_bytes
    counts = a.telemetry["controller"]["rung_counts_per_round"]
    assert any("topk16" in c for c in counts)
    assert any("ternary" in c for c in counts)  # the warmup encodes


def test_controller_policy_is_pure_function_of_observations():
    """Same observation sequence → same rung sequence; no RNG anywhere."""
    fed = FedConfig(controller=ControllerConfig(
        warmup_encodes=1, divergence_high=0.05, slow_factor=0.5))

    def drive():
        c = CompressionController(fed.controller, fed)
        rungs = []
        for r in range(5):
            c.note_round(r)
            c.observe_upload(0, 10_000, 1.0)     # slow client
            c.observe_upload(1, 10_000, 0.01)    # fast client
            rungs.append((c.select(0), c.select(1)))
            for k in (0, 1):
                c._encodes[k] = c._encodes.get(k, 0) + 1
        return rungs

    first = drive()
    assert first == drive()
    assert first[0] == ("ternary", "ternary")          # warmup
    assert first[-1] == ("topk16", "ternary")          # slow link → sparse
    for pair in first:
        assert all(rung in LADDER for rung in pair)


def test_fleet_controller_deterministic_and_off_bitexact():
    from repro.models.paper_models import init_mlp_mnist

    params = init_mlp_mnist(jax.random.PRNGKey(1))
    base = dict(algorithm="tfedavg", mode="sync", n_clients=64,
                participation=0.25, rounds=4, seed=0)
    off = run_fleet(params, FedConfig(**base), FleetConfig(update_pool=2))
    ctrl_cfg = FedConfig(**base, controller=ControllerConfig(
        warmup_encodes=1, slow_factor=10.0))
    on1 = run_fleet(params, ctrl_cfg, FleetConfig(update_pool=2))
    on2 = run_fleet(params, ctrl_cfg, FleetConfig(update_pool=2))
    assert on1.upload_bytes == on2.upload_bytes
    assert on1.telemetry["controller"] == on2.telemetry["controller"]
    rungs = on1.telemetry["controller"]["rung_per_round"]
    assert rungs[0] == "ternary"                       # warmup round
    assert "topk16" in rungs                           # slow_factor=10 fires
    assert on1.upload_bytes < off.upload_bytes
    # controller disabled → byte-identical to the legacy fleet path
    off2 = run_fleet(
        params,
        FedConfig(**base, controller=ControllerConfig(enabled=False)),
        FleetConfig(update_pool=2),
    )
    assert off.upload_bytes == off2.upload_bytes
    assert "controller" not in off2.telemetry


# --------------------------------------------------------------------------
# Mixed-codec rounds through the Aggregator.
# --------------------------------------------------------------------------


def _client_blob(tree, kind):
    wire, _ = compress_pytree(tree, CodecSpec(kind=kind, topk_fraction=0.25))
    return encode_update(wire)


def _dense(blob):
    return decompress_pytree(decode_update(blob))


def test_mixed_codec_round_mean_matches_dense_reference():
    """One ternary + one topk16 client on the same leaf paths: the fused
    path's fallback detour must equal the dense weighted mean."""
    trees = [
        {"w": jax.random.normal(jax.random.PRNGKey(i), (16, 8)),
         "bias": jax.random.normal(jax.random.PRNGKey(10 + i), (8,))}
        for i in range(2)
    ]
    blobs = [_client_blob(trees[0], "ternary"),
             _client_blob(trees[1], "topk16")]
    weights = [1.0, 3.0]

    agg = Aggregator(chunk_c=4, rule="mean")
    for blob, w in zip(blobs, weights):
        agg.add(blob, weight=w)
    out = agg.finalize()

    dense = [_dense(b) for b in blobs]
    tot = sum(weights)
    ref = jax.tree_util.tree_map(
        lambda a, b: (weights[0] * a + weights[1] * b) / tot, *dense)
    for key in ("w", "bias"):
        np.testing.assert_allclose(np.asarray(out[key]),
                                   np.asarray(ref[key]), rtol=1e-5,
                                   atol=1e-6)


def test_mixed_codec_reset_keeps_pure_ternary_rounds_exact():
    """A reused Aggregator that saw a mixed round must produce bit-identical
    output for a later pure-ternary round (reset clears the fallback-touched
    state; stale zeroed accumulators never re-enter the sum)."""
    trees = [
        {"w": jax.random.normal(jax.random.PRNGKey(i), (16, 8))}
        for i in range(2)
    ]
    t_blobs = [_client_blob(t, "ternary") for t in trees]

    fresh = Aggregator(chunk_c=4, rule="mean")
    for b in t_blobs:
        fresh.add(b, weight=1.0)
    want = fresh.finalize()

    reused = Aggregator(chunk_c=4, rule="mean")
    reused.add(t_blobs[0], weight=1.0)
    reused.add(_client_blob(trees[1], "fp16"), weight=1.0)
    reused.finalize(reset=True)
    for b in t_blobs:
        reused.add(b, weight=1.0)
    got = reused.finalize()
    assert np.asarray(want["w"]).tobytes() == np.asarray(got["w"]).tobytes()


def test_mixed_codec_robust_rules_refuse():
    agg = Aggregator(chunk_c=4, rule="majority")
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}
    agg.add(_client_blob(tree, "ternary"), weight=1.0)
    with pytest.raises(ValueError, match="mixed wire kinds"):
        agg.add(_client_blob(tree, "fp16"), weight=1.0)


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_controller_requires_mean_rule(fed_task, mode):
    with pytest.raises(ValueError, match="adaptive compression requires"):
        _run(fed_task, ControllerConfig(), mode=mode,
             defense=DefenseConfig(enabled=True, rule="majority"))


def test_controller_config_validation():
    with pytest.raises(ValueError, match="ladder"):
        ControllerConfig(aggressive_rung="gzip")
    with pytest.raises(ValueError, match="ewma"):
        ControllerConfig(ewma=1.5)
    with pytest.raises(ValueError, match="residual_codec"):
        ControllerConfig(residual_codec="nope")
