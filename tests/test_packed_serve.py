"""Zero-copy serve tests: wire bytes → (K//4, N) kernel layout with no
unpacked-int8 / dense-fp32 weight materialization, and packed-kernel logits
matching the dequantized reference path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import decode_update, encode_update
from repro.core import CodecSpec, FTTQConfig
from repro.core import compression as comp
from repro.core.ternary import encode_ternary
from repro.kernels.repack import (
    PackedTernary,
    packed_matmul,
    packed_params_from_wire,
    repack_to_kernel_layout,
)


# --------------------------------------------------------------------------
# Repack correctness, aligned fast path + unaligned fallback.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("k,n", [(64, 48), (32, 16), (128, 128),
                                 (100, 26), (10, 6), (7, 5)])
def test_repack_matches_kernel_reference_layout(k, n):
    """repack(wire bytes) must equal pack2bit_ref of the unpacked codes —
    the exact layout ternary_matmul consumes."""
    from repro.kernels import ref

    rng = np.random.default_rng(k * 1000 + n)
    it = jnp.asarray(rng.integers(-1, 2, (k, n)), jnp.int8)
    t = encode_ternary(it, jnp.float32(0.4))
    p = repack_to_kernel_layout(t)
    k_pad = (k + 3) // 4 * 4
    assert p.packed.shape == (k_pad // 4, n)
    assert p.k == k
    it_pad = jnp.concatenate([it, jnp.zeros((k_pad - k, n), jnp.int8)]) \
        if k_pad != k else it
    np.testing.assert_array_equal(
        np.asarray(p.packed), np.asarray(ref.pack2bit_ref(it_pad)))


@pytest.mark.parametrize("k,n", [(64, 48), (100, 26), (10, 6)])
def test_packed_matmul_equals_dequantized(k, n):
    rng = np.random.default_rng(n)
    it = jnp.asarray(rng.integers(-1, 2, (k, n)), jnp.int8)
    t = encode_ternary(it, jnp.float32(0.37))
    p = repack_to_kernel_layout(t)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, k))
    y = packed_matmul(x, p)
    y_ref = x @ t.dequantize()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_repack_stacked_scan_leaf_per_layer_scales():
    rng = np.random.default_rng(9)
    it = jnp.asarray(rng.integers(-1, 2, (3, 32, 16)), jnp.int8)
    wq = jnp.asarray([0.2, 0.3, 0.4], jnp.float32).reshape(3, 1, 1)
    p = repack_to_kernel_layout(encode_ternary(it, wq))
    assert p.packed.shape == (3, 8, 16) and p.w_q.shape == (3, 1, 1)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
    for layer in range(3):
        per_layer = jax.tree_util.tree_map(lambda a: a[layer], p)
        y = packed_matmul(x, per_layer)
        y_ref = x @ (it[layer].astype(jnp.float32) * wq[layer, 0, 0])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


def test_aligned_repack_never_materializes_unpacked_codes():
    """The aligned fast path is pure byte-plane arithmetic: its transient
    buffers stay at packed size (k·n/4), not unpacked int8 (k·n)."""
    from repro.kernels.repack import _repack2d_aligned

    k, n = 256, 256
    rng = np.random.default_rng(0)
    it = jnp.asarray(rng.integers(-1, 2, (k, n)), jnp.int8)
    t = encode_ternary(it, jnp.float32(1.0))
    flat = np.asarray(t.packed)
    out = _repack2d_aligned(flat, k, n)
    assert out.nbytes == k * n // 4  # kernel layout is still 2-bit packed
    # numerical equivalence with the int8 route, without taking it
    from repro.kernels import ref
    np.testing.assert_array_equal(out, np.asarray(ref.pack2bit_ref(it)))


# --------------------------------------------------------------------------
# Wire → packed params → transformer forward (the acceptance check).
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.models.transformer import ModelConfig, init_params

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                      vocab_size=64, n_heads=4, n_kv_heads=2, d_ff=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_packed_params_from_wire_leaf_types(tiny_lm):
    cfg, params = tiny_lm
    wire, _ = comp.compress_pytree(
        params, CodecSpec(kind="ternary", residual="fp16", fttq=FTTQConfig()))
    decoded = decode_update(encode_update(wire))
    packed = packed_params_from_wire(decoded)
    leaves = jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedTernary))
    kinds = {type(l).__name__ for l in leaves}
    assert "PackedTernary" in kinds            # matmul weights stayed 2-bit
    assert not any(comp.is_wire_leaf(l) for l in leaves
                   if not isinstance(l, PackedTernary))  # rest decoded dense
    n_packed = sum(isinstance(l, PackedTernary) for l in leaves)
    assert n_packed == 7  # wq wk wv wo w_in w_gate w_out (stacked)


def test_packed_serve_logits_match_dequantized_path(tiny_lm):
    """serve --ternary --packed equivalence: full prefill + cached decode
    through kernels.ternary_matmul matches the dense-dequant reference."""
    from repro.launch.serve import ternary_deploy
    from repro.models.transformer import decode_step, forward, init_cache

    cfg, params = tiny_lm
    packed, nbytes_p, _, _ = ternary_deploy(params, FTTQConfig(), packed=True)
    dense, nbytes_d, _, _ = ternary_deploy(params, FTTQConfig(), packed=False)
    assert nbytes_p == nbytes_d  # same wire artifact feeds both paths

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    lp, _, _ = forward(cfg, packed, toks)
    lr, _, _ = forward(cfg, dense, toks)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr),
                               rtol=1e-4, atol=1e-4)

    cache_p, cache_r = init_cache(cfg, 2, 16), init_cache(cfg, 2, 16)
    lp, cache_p, _ = forward(cfg, packed, toks, cache=cache_p, pos=0)
    lr, cache_r, _ = forward(cfg, dense, toks, cache=cache_r, pos=0)
    tok = jnp.argmax(lp[:, -1:], -1).astype(jnp.int32)
    s_p, _ = decode_step(cfg, packed, tok, cache_p, 8)
    s_r, _ = decode_step(cfg, dense, tok, cache_r, 8)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_packed_hbm_bytes_are_2bit(tiny_lm):
    """The served weight leaves occupy ~1/16 of the fp32 footprint in
    memory — the deploy path holds packed bytes, not dense copies."""
    cfg, params = tiny_lm
    from repro.launch.serve import ternary_deploy

    packed, _, _, _ = ternary_deploy(params, FTTQConfig(), packed=True)

    def leaf_bytes(tree):
        total = 0
        for l in jax.tree_util.tree_leaves(
                tree, is_leaf=lambda x: isinstance(x, PackedTernary)):
            if isinstance(l, PackedTernary):
                total += int(l.packed.size) + int(np.asarray(l.w_q).nbytes)
            else:
                total += int(np.asarray(l).nbytes)
        return total

    quantizable = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        from repro.core import fttq
        if fttq.is_quantizable(path, leaf, FTTQConfig()):
            quantizable += leaf.nbytes
    # served bytes ≈ fp32_total − quantizable·(1 − 1/16)
    fp32_total = sum(l.nbytes for l in jax.tree_util.tree_leaves(params))
    expected = fp32_total - quantizable * (1 - 1 / 16)
    assert leaf_bytes(packed) < expected * 1.05


def test_packed_matmul_bad_k_raises():
    it = jnp.asarray(np.random.default_rng(0).integers(-1, 2, (16, 8)), jnp.int8)
    p = repack_to_kernel_layout(encode_ternary(it, jnp.float32(1.0)))
    with pytest.raises(ValueError, match="contraction dim"):
        packed_matmul(jnp.ones((2, 12)), p)
    it3 = jnp.asarray(np.random.default_rng(1).integers(-1, 2, (2, 16, 8)), jnp.int8)
    p3 = repack_to_kernel_layout(encode_ternary(it3, jnp.float32(1.0)))
    with pytest.raises(ValueError, match="scan over the leading axis"):
        packed_matmul(jnp.ones((2, 16)), p3)
