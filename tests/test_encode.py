"""Fused upstream encode: kernel-vs-oracle, fused-vs-reference BYTE
identity of wire buffers (the acceptance property), unified pack-padding
semantics, the streaming serializer, and the long-lived Aggregator reuse."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.wire import _RECORDS, decode_update, encode_update
from repro.core import CodecSpec, FTTQConfig, compress_pytree
from repro.core import fttq as F
from repro.core.tfedavg import client_update_payload, server_requantize
from repro.core.ternary import TernaryTensor, encode_ternary, pack2bit, unpack2bit
from repro.kernels.pack2bit import pad_to_packable
from repro.kernels.quantize_pack import (
    LANES,
    moments_ref,
    quantize_pack,
    quantize_pack_ref,
    quantize_pack_segments,
    quantize_pack_stacked,
    stage_encode,
)

CFG = FTTQConfig()


# --------------------------------------------------------------------------
# Kernel vs pure-jnp oracle.
# --------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 3, 511, 512, 513, 32768, 32769, 100_001])
def test_kernel_bytes_match_wire_oracle(n):
    """The fused kernel's flattened output IS the wire byte stream: equal to
    ternarize→core-pack2bit for sizes on both sides of every padding
    boundary (byte, lane chunk, block tile)."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    denom = jnp.max(jnp.abs(x)) + 1e-8
    delta = 0.7 * jnp.mean(jnp.abs(x / denom))
    packed, moments, count = quantize_pack(x, denom, delta, interpret=True)
    assert count == n
    ref = np.asarray(quantize_pack_ref(x, denom, delta))
    got = np.asarray(packed).reshape(-1)[: ref.size]
    np.testing.assert_array_equal(got, ref)
    # moments: bit-identical to the canonical lax.map reference
    np.testing.assert_array_equal(
        np.asarray(moments), np.asarray(moments_ref(x, denom, delta))
    )


def test_kernel_multi_segment_launch():
    """Per-block SMEM scalars: two segments with different (denom, Δ) in ONE
    launch equal two single-segment launches."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(600,)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(900,)).astype(np.float32) * 3.0)
    bs = 8
    parts, scals = [], []
    for x in (a, b):
        staged, _ = stage_encode(x, bs)
        denom = jnp.max(jnp.abs(x)) + 1e-8
        delta = 0.7 * jnp.mean(jnp.abs(x / denom))
        g = staged.shape[0] // bs
        parts.append(staged)
        scals.append(jnp.broadcast_to(
            jnp.stack([denom, delta]).astype(jnp.float32)[None, :], (g, 2)))
    packed, _ = quantize_pack_segments(
        jnp.concatenate(parts), jnp.concatenate(scals), block_s=bs,
        interpret=True,
    )
    flat = np.asarray(packed).reshape(-1)
    off = 0
    for x in (a, b):
        denom = jnp.max(jnp.abs(x)) + 1e-8
        delta = 0.7 * jnp.mean(jnp.abs(x / denom))
        ref = np.asarray(quantize_pack_ref(x, denom, delta))
        np.testing.assert_array_equal(flat[off:off + ref.size], ref)
        staged, _ = stage_encode(x, bs)
        off += staged.shape[0] // 4 * LANES


def test_vmapped_stacked_matches_single_layer_calls():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(3, 16, 8)).astype(np.float32))
    denoms = jax.vmap(lambda t: jnp.max(jnp.abs(t)) + 1e-8)(x)
    deltas = jax.vmap(lambda t: 0.7 * jnp.mean(jnp.abs(t / (jnp.max(jnp.abs(t)) + 1e-8))))(x)
    packed, moments, n_layer = quantize_pack_stacked(
        x, denoms, deltas, interpret=True
    )
    assert n_layer == 16 * 8
    for i in range(3):
        p1, m1, _ = quantize_pack(x[i], denoms[i], deltas[i], interpret=True)
        np.testing.assert_array_equal(np.asarray(packed[i]), np.asarray(p1))
        np.testing.assert_array_equal(np.asarray(moments[i]), np.asarray(m1))


# --------------------------------------------------------------------------
# Fused vs reference: BYTE-IDENTICAL wire buffers (the acceptance property).
# --------------------------------------------------------------------------


def _ragged_params(key, dtype=jnp.float32):
    """Every encode corner in one tree: ragged 2-D (n % 4 ≠ 0), sizes
    crossing the pad_to_packable 512-element chunk and the BLOCK_S tile,
    stacked clean (layer % 4 == 0) and ragged stacked leaves, biases, and
    an int counter."""
    k = jax.random.split(key, 7)
    return {
        "enc": {"w": jax.random.normal(k[0], (17, 9), dtype),
                "b": jax.random.normal(k[1], (9,), dtype)},
        "mid": {"w": jax.random.normal(k[2], (128, 4), dtype)},      # 512 exact
        "odd": {"w": jax.random.normal(k[3], (129, 4), dtype)},      # 516 > 512
        "stack": {"w": jax.random.normal(k[4], (3, 8, 12), dtype)},  # clean
        "ragged_stack": {"w": jax.random.normal(k[5], (3, 9, 13), dtype)},
        "head": {"w": jax.random.normal(k[6], (100, 260), dtype)},   # > BLOCK_S
        "steps": jnp.asarray(7, jnp.int32),
    }


@pytest.mark.parametrize("rule", ["mean", "max"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_client_payload_fused_bitexact(rule, dtype):
    cfg = F.FTTQConfig(threshold_rule=rule)
    params = _ragged_params(jax.random.PRNGKey(0), dtype)
    wq = F.init_wq_tree(params, cfg)
    ref = encode_update(client_update_payload(params, wq, cfg, fused=False))
    fus = encode_update(client_update_payload(params, wq, cfg, fused=True))
    assert ref == fus


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_server_requantize_fused_bitexact(dtype):
    params = _ragged_params(jax.random.PRNGKey(1), dtype)
    ref = encode_update(server_requantize(params, CFG, fused=False))
    fus = encode_update(server_requantize(params, CFG, fused=True))
    assert ref == fus


@pytest.mark.parametrize("rule", ["mean", "max"])
def test_codec_compress_fused_bitexact(rule):
    cfg = F.FTTQConfig(threshold_rule=rule)
    params = _ragged_params(jax.random.PRNGKey(2))
    spec = CodecSpec(kind="ternary", residual="fp16", fttq=cfg)
    ref_spec = dataclasses.replace(spec, fused_encode=False)
    ref = encode_update(compress_pytree(params, ref_spec)[0])
    fus = encode_update(compress_pytree(params, spec)[0])
    assert ref == fus


def test_fused_bitexact_property_sweep():
    """Randomized shapes (hypothesis-style sweep without the dependency):
    fused and reference buffers must match for every draw."""
    rng = np.random.default_rng(7)
    for trial in range(8):
        m = int(rng.integers(1, 70))
        n = int(rng.integers(1, 70))
        key = jax.random.PRNGKey(trial)
        params = {"w": jax.random.normal(key, (m, n)) * float(rng.uniform(0.1, 9))}
        wq = F.init_wq_tree(params, CFG)
        ref = encode_update(client_update_payload(params, wq, CFG, fused=False))
        fus = encode_update(client_update_payload(params, wq, CFG, fused=True))
        assert ref == fus, (m, n)
        rr = encode_update(server_requantize(params, CFG, fused=False))
        rf = encode_update(server_requantize(params, CFG, fused=True))
        assert rr == rf, (m, n)


@pytest.mark.parametrize("rows,cols", [
    # every pad-boundary residue of the per-layer element count
    # layer_n = rows·cols: % 4 ∈ {1, 2, 3} plus the clean case
    (5, 5), (3, 6), (9, 3), (4, 4),
])
@pytest.mark.parametrize("n_layers", [1, 2, 3, 5])
def test_ragged_stacked_fused_bitexact(n_layers, rows, cols):
    """The stacked repack path (layer_n % 4 ≠ 0 → per-layer code streams
    sliced, concatenated, re-padded with code 1): byte-identical to the
    reference per-layer chain at every pad residue × layer count."""
    key = jax.random.PRNGKey(n_layers * 100 + rows * 10 + cols)
    params = {"stack": {"w": jax.random.normal(key, (n_layers, rows, cols))}}
    wq = F.init_wq_tree(params, CFG)
    ref = encode_update(client_update_payload(params, wq, CFG, fused=False))
    fus = encode_update(client_update_payload(params, wq, CFG, fused=True))
    assert ref == fus, (n_layers, rows, cols)
    rr = encode_update(server_requantize(params, CFG, fused=False))
    rf = encode_update(server_requantize(params, CFG, fused=True))
    assert rr == rf, (n_layers, rows, cols)


def test_ragged_stacked_mixed_tree_property_sweep():
    """Randomized trees mixing clean and ragged stacks with 2-D leaves:
    the fused encoder must never fall back or drift at any boundary."""
    rng = np.random.default_rng(11)
    for trial in range(6):
        layers = int(rng.integers(1, 5))
        r, c = int(rng.integers(2, 12)), int(rng.integers(2, 12))
        key = jax.random.split(jax.random.PRNGKey(40 + trial), 3)
        params = {
            "stack": {"w": jax.random.normal(key[0], (layers, r, c))},
            "clean": {"w": jax.random.normal(key[1], (2, 4, 8))},
            "flat": {"w": jax.random.normal(key[2], (17, 3))},
        }
        wq = F.init_wq_tree(params, CFG)
        ref = encode_update(client_update_payload(params, wq, CFG, fused=False))
        fus = encode_update(client_update_payload(params, wq, CFG, fused=True))
        assert ref == fus, (layers, r, c)


def test_fused_payload_decodes_to_reference_codes():
    """Sanity beyond byte equality: decoded fused codes equal the reference
    ternarization."""
    params = _ragged_params(jax.random.PRNGKey(3))
    wq = F.init_wq_tree(params, CFG)
    fus = decode_update(encode_update(client_update_payload(params, wq, CFG)))
    t = fus["head"]["w"]
    assert isinstance(t, TernaryTensor)
    leaf = params["head"]["w"]
    ts = F.scale_layer(leaf)
    i_ref = F.ternarize(ts, F.fttq_threshold(ts, CFG.t_k, CFG.threshold_rule))
    np.testing.assert_array_equal(
        np.asarray(t.ternary()), np.asarray(i_ref, np.int8)
    )


# --------------------------------------------------------------------------
# Padding semantics: code 1 (value 0) everywhere.
# --------------------------------------------------------------------------


def test_pack_padding_unified_on_code_1():
    """core.ternary.pack2bit pads partial bytes with code 1 (decodes to 0),
    matching kernels.pack2bit.pad_to_packable — a consumer reading past n
    (e.g. the fan-in kernel before its tail slice) must see zeros, not −1."""
    packed = np.asarray(pack2bit(jnp.asarray([1], jnp.int8)))
    # byte = code2 | code1<<2 | code1<<4 | code1<<6 = 2 + 4 + 16 + 64
    assert packed.tolist() == [86]
    # decoding the padding slots yields VALUE 0
    full = np.asarray(unpack2bit(jnp.asarray(packed), 4))
    np.testing.assert_array_equal(full, [1, 0, 0, 0])
    # the kernels-side helper pads identically (value 0 == code 1)
    tiled, n = pad_to_packable(jnp.asarray([1, -1, 0], jnp.int8))
    assert n == 3
    flat = np.asarray(tiled).reshape(-1)
    np.testing.assert_array_equal(flat[3:], np.zeros(flat.size - 3, np.int8))


def test_padding_consistent_with_fused_kernel():
    """Reference pack and fused kernel emit the SAME final partial byte."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(21,)).astype(np.float32))  # 21 % 4 = 1
    denom = jnp.max(jnp.abs(x)) + 1e-8
    delta = 0.7 * jnp.mean(jnp.abs(x / denom))
    ts = x / denom
    i_t = jnp.where(jnp.abs(ts) > delta, jnp.sign(ts), 0.0).astype(jnp.int8)
    ref = np.asarray(pack2bit(i_t))
    packed, _, _ = quantize_pack(x, denom, delta, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(packed).reshape(-1)[: ref.size], ref
    )


# --------------------------------------------------------------------------
# Streaming serializer.
# --------------------------------------------------------------------------


def test_all_emitting_records_have_streaming_writers():
    """No per-record bytes concatenation: every record kind an encoder can
    emit carries a native prepare (size pre-pass + in-place writer); only
    the decode-only legacy TOPK record may rely on the fallback."""
    for rec in _RECORDS.values():
        if rec.encode:
            assert rec.prepare is not None, rec.name


def test_streaming_encode_matches_join_reference():
    """The preallocated single-buffer writer is byte-identical to the
    legacy join-based builder (reconstructed from the registry's pack
    functions) on a payload exercising every record kind."""
    import struct
    import zlib

    from repro.comm.wire import (
        _HEADER, _PATH_SEP, _path_entries, _record_for_leaf, _leaf_types,
    )

    rng = np.random.default_rng(11)
    tree = {
        "w": encode_ternary(
            jnp.asarray(rng.integers(-1, 2, (13, 7)).astype(np.int8)),
            jnp.float32(0.31),
        ),
        "b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32)),
        "half": compress_pytree(
            {"x": jnp.asarray(rng.normal(size=(5, 4)).astype(np.float32))},
            CodecSpec(kind="fp16", residual="fp16"),
        )[0]["x"],
        "sparse": compress_pytree(
            {"x": jnp.asarray(rng.normal(size=(9, 6)).astype(np.float32))},
            CodecSpec(kind="topk", residual="topk", topk_fraction=0.3),
        )[0]["x"],
    }
    lt = _leaf_types()
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, lt)
    )[0]
    records, version = [], 1
    for path, leaf in leaves:
        p = _PATH_SEP.join(_path_entries(path)).encode("utf-8")
        rec = _record_for_leaf(leaf)
        version = max(version, rec.min_version)
        records.append(b"".join([
            struct.pack("<H", len(p)), p,
            struct.pack("<B", rec.kind), rec.pack(leaf),
        ]))
    body = b"".join(records)
    join_blob = _HEADER.pack(
        b"TFW1", version, 0, len(records), zlib.crc32(body), len(body)
    ) + body
    assert encode_update(tree) == join_blob


def test_streaming_encode_noncontiguous_leaf():
    """A transposed (non-C-contiguous) numpy leaf serializes correctly."""
    arr = np.arange(12, dtype=np.float32).reshape(3, 4).T
    assert not arr.flags["C_CONTIGUOUS"]
    back = decode_update(encode_update({"w": arr}))["w"]
    np.testing.assert_array_equal(np.asarray(back), arr)


# --------------------------------------------------------------------------
# Long-lived Aggregator (async-server satellite).
# --------------------------------------------------------------------------


def _client_blob(seed):
    params = {"enc": {"w": jax.random.normal(jax.random.PRNGKey(seed), (17, 9)),
                      "b": jax.random.normal(jax.random.PRNGKey(seed + 50), (9,))}}
    wq = F.init_wq_tree(params, CFG)
    return encode_update(client_update_payload(params, wq, CFG))


def test_aggregator_reset_reuses_buffers_across_rounds():
    from repro.fed.aggregator import Aggregator

    blobs = [_client_blob(s) for s in range(4)]
    fresh = []
    for r in range(2):
        a = Aggregator(chunk_c=2)
        for i, b in enumerate(blobs):
            a.add(b, 10 + i + r)
        fresh.append(a.finalize())

    agg = Aggregator(chunk_c=2)
    for i, b in enumerate(blobs):
        agg.add(b, 10 + i)
    out0 = agg.finalize(reset=True)
    buffers_after_round0 = dict(agg._buffers)
    peak0 = agg.peak_intermediate_bytes
    assert agg.n_clients == 0
    for i, b in enumerate(blobs):
        agg.add(b, 11 + i)
    out1 = agg.finalize(reset=True)
    # same results as fresh instances...
    for ref, got in ((fresh[0], out0), (fresh[1], out1)):
        for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(ref)[0],
            jax.tree_util.tree_flatten_with_path(got)[0],
        ):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
            )
    # ...with the SAME staging buffers (no reallocation, flat high-water)
    assert dict(agg._buffers) == buffers_after_round0
    assert agg.peak_intermediate_bytes == peak0


def test_aggregator_reset_rejects_structure_change_consistently():
    from repro.fed.aggregator import Aggregator

    agg = Aggregator(chunk_c=2)
    agg.add(_client_blob(0), 5)
    agg.finalize(reset=True)
    # plans survive the reset: a different update structure still refuses
    with pytest.raises(ValueError, match="structure changed"):
        agg.add(encode_update({"other": jnp.ones((4, 4))}), 1)


# --------------------------------------------------------------------------
# nbytes_wire metadata derivation (no per-leaf host sync).
# --------------------------------------------------------------------------


def test_nbytes_wire_handles_plain_python_scalar():
    t = encode_ternary(jnp.asarray([1, -1, 0, 1], jnp.int8), 0.5)
    # python float scale → np default float64 on the wire
    assert t.nbytes_wire() == int(t.packed.size) + 8


def test_nbytes_wire_numpy_packed_leaf():
    """Fused-encoded tensors carry numpy packed views — accounting still
    derives from metadata."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (33, 5))}
    wq = F.init_wq_tree(params, CFG)
    t = client_update_payload(params, wq, CFG)["w"]
    assert isinstance(t.packed, np.ndarray)
    assert t.nbytes_wire() == (33 * 5 + 3) // 4 + 4
