"""Availability-trace tests: determinism, churn structure, trace replay,
and the rng-stream compatibility contract of the participant draws."""

import numpy as np
import pytest

from repro.fed.availability import (
    AlwaysOn,
    AvailabilityConfig,
    DiurnalChurn,
    TraceReplay,
    draw_one,
    draw_participants,
    make_availability,
)


def test_always_on_everyone_forever():
    a = AlwaysOn(7)
    assert a.available_mask(0.0).all()
    assert a.available_mask(1e9).all()
    assert a.next_change(123.0) == float("inf")


def test_diurnal_is_deterministic_and_churns():
    a = DiurnalChurn(200, period_s=100.0, floor=0.1, n_cohorts=4, seed=3)
    b = DiurnalChurn(200, period_s=100.0, floor=0.1, n_cohorts=4, seed=3)
    ts = np.linspace(0.0, 200.0, 17)
    for t in ts:
        np.testing.assert_array_equal(a.available_mask(t), b.available_mask(t))
    counts = [int(a.available_mask(t).sum()) for t in ts]
    assert min(counts) < max(counts)          # the fleet actually churns
    assert min(counts) > 0                    # floor keeps a tail online
    # a different seed permutes the propensities
    c = DiurnalChurn(200, period_s=100.0, floor=0.1, n_cohorts=4, seed=4)
    assert any(
        not np.array_equal(a.available_mask(t), c.available_mask(t)) for t in ts
    )


def test_diurnal_cohorts_peak_at_phase_offsets():
    """Each timezone cohort's online count peaks when its sinusoid does:
    cohort c's peak sits a quarter period after cohort c+1's (phase
    2πc/n)."""
    av = DiurnalChurn(400, period_s=100.0, floor=0.0, n_cohorts=4, seed=0)
    cohort0 = av._cohort == 0

    def frac_online(t):
        return av.available_mask(t)[cohort0].mean()

    peak_t = 25.0   # sin(2πt/T) = 1 at t = T/4 for phase 0
    trough_t = 75.0
    assert frac_online(peak_t) > 0.95
    assert frac_online(trough_t) < 0.10


def test_diurnal_expected_online_tracks_level():
    av = DiurnalChurn(1000, period_s=60.0, floor=0.2, n_cohorts=3, seed=1)
    lvl = av.expected_online(10.0)
    online = av.available_mask(10.0).mean()
    assert abs(lvl - online) < 0.07   # propensity thresholding ≈ its mean


def test_trace_replay_schedule_membership():
    # one client: online on [0, 10) and [20, 30), horizon 40
    tr = TraceReplay([np.array([0.0, 10.0, 20.0, 30.0])], horizon_s=40.0)
    assert tr.available_mask(5.0)[0]
    assert not tr.available_mask(15.0)[0]
    assert tr.available_mask(25.0)[0]
    assert not tr.available_mask(35.0)[0]
    assert tr.available_mask(45.0)[0]          # tiles past the horizon
    nxt = tr.next_change(5.0)
    assert 5.0 < nxt <= 10.0 + 1e-6
    # wrap regression: from t=35 (offline) the next change is the horizon
    # fold at t=40 (back online), not a boundary a whole horizon later
    assert tr.next_change(35.0) == pytest.approx(40.0)
    assert tr.available_mask(tr.next_change(35.0) + 1e-9)[0]


def test_trace_replay_generate_deterministic():
    a = TraceReplay.generate(20, mean_on_s=30, mean_off_s=20, horizon_s=500,
                             seed=9)
    b = TraceReplay.generate(20, mean_on_s=30, mean_off_s=20, horizon_s=500,
                             seed=9)
    for t in np.linspace(0, 600, 23):
        np.testing.assert_array_equal(a.available_mask(t), b.available_mask(t))
    # sessions exist and end: some client toggles within the horizon
    m0 = a.available_mask(0.0)
    assert any(
        not np.array_equal(m0, a.available_mask(t)) for t in (50.0, 150.0, 350.0)
    )


def test_trace_replay_rejects_bad_schedules():
    with pytest.raises(ValueError, match="ascending"):
        TraceReplay([np.array([5.0, 1.0])], horizon_s=10.0)
    with pytest.raises(ValueError, match="horizon"):
        TraceReplay([np.array([0.0, 1.0])], horizon_s=0.0)


def test_make_availability_kinds_and_unknown():
    cfgs = {
        "always_on": AlwaysOn,
        "diurnal": DiurnalChurn,
        "trace": TraceReplay,
    }
    for kind, cls in cfgs.items():
        av = make_availability(AvailabilityConfig(kind=kind), 10, seed=0)
        assert isinstance(av, cls)
        assert av.available_mask(0.0).shape == (10,)
    with pytest.raises(ValueError, match="unknown availability"):
        make_availability(AvailabilityConfig(kind="bogus"), 10)


def test_draw_participants_rng_stream_matches_historical_uniform():
    """The bit-exactness contract: with everyone online, the draws consume
    the rng stream EXACTLY like the pre-scenario uniform sampling."""
    av = AlwaysOn(50)
    r1 = np.random.default_rng(42)
    r2 = np.random.default_rng(42)
    got = draw_participants(av, 0.0, 5, 50, r1)
    want = r2.choice(50, size=5, replace=False)
    np.testing.assert_array_equal(got, want)
    assert draw_one(av, 0.0, 50, r1) == int(r2.integers(50))
    # and the streams are still aligned afterwards
    assert r1.uniform() == r2.uniform()


def test_draw_participants_only_online_clients():
    av = DiurnalChurn(100, period_s=100.0, floor=0.05, n_cohorts=2, seed=0)
    rng = np.random.default_rng(0)
    for t in (0.0, 30.0, 60.0, 90.0):
        online = set(np.flatnonzero(av.available_mask(t)).tolist())
        picked = draw_participants(av, t, 10, 100, rng)
        assert set(picked.tolist()) <= online
        assert len(set(picked.tolist())) == len(picked)  # no repeats
        k = draw_one(av, t, 100, rng)
        assert k in online


def test_draw_empty_fleet():
    tr = TraceReplay([np.array([10.0, 20.0])], horizon_s=30.0)  # offline at 0
    rng = np.random.default_rng(0)
    assert draw_participants(tr, 0.0, 3, 1, rng).size == 0
    assert draw_one(tr, 0.0, 1, rng) == -1
