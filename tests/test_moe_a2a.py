"""Regression tests for the optimized MoE dispatch (models/moe_a2a.py) —
the §Perf A optimization: shard_map + all_to_all with optional int8 wire.

Run in an 8-device subprocess (like test_parallel.py)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_a2a_matches_gspmd_dropfree():
    """At drop-free capacity the a2a dispatch must equal the GSPMD scatter
    dispatch EXACTLY (same expert math, same routing)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.compat import set_mesh
    from repro.models.transformer import init_params, forward
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = dict(capacity_factor=16.0, mesh_batch_axes=("data",),
                mesh_ep_axis="model")
    cfg_g = C.get_reduced("qwen3-moe-30b-a3b", moe_impl="gspmd", **base)
    cfg_a = C.get_reduced("qwen3-moe-30b-a3b", moe_impl="a2a", **base)
    params = init_params(cfg_g, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg_g.vocab_size)
    with set_mesh(mesh):
        lg, _, _ = jax.jit(lambda p, t: forward(cfg_g, p, t))(params, toks)
        la, _, _ = jax.jit(lambda p, t: forward(cfg_a, p, t))(params, toks)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(la, np.float32), rtol=2e-3, atol=2e-3)
    print("A2A_EXACT_OK", float(jnp.max(jnp.abs(lg - la))))
    """
    assert "A2A_EXACT_OK" in run_with_devices(code)


def test_a2a_int8_wire_close_and_trains():
    """int8 dispatch wire stays close to the bf16 wire and training steps
    converge (grads flow through quantized_all_to_all's custom VJP)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.compat import set_mesh
    from repro.models.transformer import init_params, forward
    from repro.train import TrainerConfig, init_train_state, make_train_step
    from repro.optim import adam
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = dict(capacity_factor=16.0, mesh_batch_axes=("data",),
                mesh_ep_axis="model", moe_impl="a2a")
    cfg_bf = C.get_reduced("deepseek-moe-16b", moe_wire="bf16", **base)
    cfg_q8 = C.get_reduced("deepseek-moe-16b", moe_wire="int8", **base)
    params = init_params(cfg_bf, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg_bf.vocab_size)
    with set_mesh(mesh):
        lb, _, _ = jax.jit(lambda p, t: forward(cfg_bf, p, t))(params, toks)
        lq, _, _ = jax.jit(lambda p, t: forward(cfg_q8, p, t))(params, toks)
    rel = float(jnp.linalg.norm(lb - lq) / (jnp.linalg.norm(lb) + 1e-9))
    assert rel < 0.05, rel  # int8 per-slot scales: ≲1% typical

    tcfg = TrainerConfig(qat=True, pod_compression=False)
    opt = adam(2e-3)
    state = init_train_state(cfg_q8, tcfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg_q8, tcfg, opt, mesh)
    batch = {"tokens": toks, "labels": jax.random.randint(
        jax.random.PRNGKey(2), (4, 16), 0, cfg_q8.vocab_size)}
    with set_mesh(mesh):
        js = jax.jit(step)
        s, m0 = js(state, batch)
        for _ in range(4):
            s, m = js(s, batch)
    assert float(m["loss"]) < float(m0["loss"])
    print("Q8_WIRE_OK", rel, float(m0["loss"]), float(m["loss"]))
    """
    assert "Q8_WIRE_OK" in run_with_devices(code)


def test_quantized_all_to_all_roundtrip_error():
    """Unit bound: per-slot int8 quantization error ≤ scale/2 elementwise."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.models.moe_a2a import quantized_all_to_all
    mesh = jax.make_mesh((4,), ("model",))
    # per-device block (4, 8, 32): dim 0 divisible by the 4-way a2a.
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8, 32))

    def f(x):
        return quantized_all_to_all(x, "model")

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("model"),
                            out_specs=P("model"), axis_names={"model"},
                            check_vma=False))(x)
    # tiled a2a permutes blocks between devices; with 1 block/device the
    # global array is a permutation of slot groups — check VALUES survive
    # quantization: every output row matches SOME input row within bound.
    xs = np.asarray(x).reshape(-1, 32)
    os_ = np.asarray(out).reshape(-1, 32)
    scale = np.abs(xs).max(-1) / 127.0
    for row, o in enumerate(os_):
        d = np.abs(xs - o).max(-1)
        assert (d <= scale * 0.51 + 1e-6).any(), row
    print("QA2A_BOUND_OK")
    """
    assert "QA2A_BOUND_OK" in run_with_devices(code)
